"""Open-loop traffic generation + SLO definitions.

Open-loop means arrivals do NOT wait for the server: timestamps are drawn
from an arrival process at a configured offered load and the scheduler must
absorb (or shed) whatever lands.  This is the regime where closed-loop
benchmarks silently understate tail latency (coordinated omission), and the
regime the SLO policy is built for.

Two processes:

``poisson_arrivals``
    Homogeneous Poisson: exponential i.i.d. gaps at ``rate`` req/s.
``bursty_arrivals``
    Markov-modulated Poisson: ON windows at ``burst_factor`` x the base
    rate, OFF windows quiet, duty-cycled so the *mean* offered load still
    equals ``rate`` — same average load as the Poisson stream but with the
    burst structure that actually breaks fifo schedulers.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

__all__ = ["SLO", "ReqState", "Request", "poisson_arrivals",
           "bursty_arrivals", "make_requests"]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request deadlines: first token within ``ttft_s`` of arrival,
    then ``tpot_s`` per additional output token."""

    ttft_s: float = 0.5
    tpot_s: float = 0.1

    def ttft_deadline(self, arrival_s: float) -> float:
        return arrival_s + self.ttft_s


class ReqState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    SHED = "shed"


@dataclasses.dataclass
class Request:
    """One serving request plus its runtime bookkeeping.

    The generator fills the identity fields; the scheduler drives ``state``
    through WAITING -> PREFILL -> DECODE -> DONE (or SHED) and stamps the
    timing fields on the virtual clock."""

    rid: int
    arrival_s: float
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int
    slo: SLO | None = None

    # runtime (scheduler-owned)
    state: ReqState = ReqState.WAITING
    slot: int = -1
    blocks: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0                        # tokens in cache (prompt + generated)
    tokens: list[int] = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    finish_s: float | None = None
    stalled_steps: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def ttft(self) -> float | None:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot(self) -> float | None:
        """Mean per-token latency after the first token."""
        if self.finish_s is None or self.first_token_s is None:
            return None
        n = len(self.tokens) - 1
        if n <= 0:
            return 0.0
        return (self.finish_s - self.first_token_s) / n


def poisson_arrivals(rate: float, horizon_s: float,
                     seed: int = 0) -> list[float]:
    """Arrival timestamps of a Poisson process at ``rate`` req/s on
    [0, horizon_s)."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= horizon_s:
            return out
        out.append(t)


def bursty_arrivals(rate: float, horizon_s: float, seed: int = 0, *,
                    burst_factor: float = 8.0, duty: float = 0.125,
                    period_s: float = 2.0) -> list[float]:
    """ON/OFF modulated Poisson with mean offered load == ``rate``.

    Each ``period_s`` window starts with an ON phase of ``duty`` fraction at
    ``burst_factor * rate``; the OFF phase runs at the residual rate that
    keeps the average at ``rate`` (requires burst_factor * duty <= 1)."""
    if not 0 < duty < 1:
        raise ValueError("duty must be in (0, 1)")
    if burst_factor * duty > 1.0:
        raise ValueError("burst_factor * duty must be <= 1 to keep the "
                         "mean offered load at `rate`")
    off_rate = rate * (1.0 - burst_factor * duty) / (1.0 - duty)
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while t < horizon_s:
        phase = (t % period_s) / period_s
        r = burst_factor * rate if phase < duty else off_rate
        if r <= 0:  # skip to the next ON edge
            t = (t // period_s + 1) * period_s
            continue
        t += rng.exponential(1.0 / r)
        if t < horizon_s:
            out.append(t)
    return out


def make_requests(arrivals: list[float], *, vocab: int,
                  prompt_len: int | tuple[int, int] = 32,
                  gen_len: int | tuple[int, int] = 16,
                  slo: SLO | None = None, seed: int = 0) -> list[Request]:
    """Attach prompts/output lengths to arrival timestamps.

    ``prompt_len`` / ``gen_len`` may be (lo, hi) ranges (inclusive) for
    variable-length traffic — the continuous-batching case that lock-step
    batching handles worst."""
    rng = np.random.default_rng(seed)

    def draw(spec):
        if isinstance(spec, tuple):
            return int(rng.integers(spec[0], spec[1] + 1))
        return int(spec)

    reqs = []
    for i, t in enumerate(arrivals):
        L = max(1, draw(prompt_len))
        reqs.append(Request(
            rid=i, arrival_s=float(t),
            prompt=rng.integers(0, vocab, (L,)).astype(np.int32),
            max_new_tokens=max(1, draw(gen_len)), slo=slo))
    return reqs
