"""Paged KV-cache bookkeeping: host-side block allocator + block tables.

The device side lives in ``repro.models.transformer`` (``init_paged_pools``,
``decode_step_paged``, ``scatter_prefill_cache``) — fixed pools of
(n_blocks, block_size, Hkv, hd) per layer run, written/read through a block
table.  This module owns the *host* state: which physical blocks are free,
which belong to which request, and how the (B_slots, max_blocks) int32 table
handed to the jitted step is built.

Physical block 0 is reserved as the null block: the allocator never hands it
out, inactive batch slots keep all-zero tables, and their (masked) scatter
writes land there without touching live data.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["OutOfBlocks", "BlockAllocator", "blocks_needed",
           "build_block_tables"]


class OutOfBlocks(RuntimeError):
    """Raised by :meth:`BlockAllocator.alloc` when the pool is exhausted."""


def blocks_needed(n_tokens: int, block_size: int) -> int:
    """Physical blocks required to hold ``n_tokens`` cache entries."""
    return max(1, math.ceil(n_tokens / block_size))


@dataclasses.dataclass
class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks.

    ``n_blocks`` counts the whole pool *including* the reserved null block 0,
    so ``capacity == n_blocks - 1`` blocks are actually allocatable — keep
    that in mind when sizing equal-memory paged-vs-dense comparisons.
    """

    n_blocks: int
    block_size: int

    def __post_init__(self) -> None:
        if self.n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        # LIFO free list, low ids first out — deterministic for tests
        self._free = list(range(self.n_blocks - 1, 0, -1))

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Take ``n`` blocks or raise :class:`OutOfBlocks` (all-or-nothing)."""
        if n > len(self._free):
            raise OutOfBlocks(f"want {n} blocks, {len(self._free)} free")
        taken = [self._free.pop() for _ in range(n)]
        return taken

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if not 0 < b < self.n_blocks:
                raise ValueError(f"block id {b} out of range")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
        # refill so freshly freed blocks come out low-id-first again
        self._free.extend(sorted(blocks, reverse=True))


def build_block_tables(tables: list[list[int]], max_blocks: int,
                       n_slots: int | None = None) -> np.ndarray:
    """Pack per-request block lists into the (n_slots, max_blocks) int32
    device table, padding unused entries (and whole inactive slots) with the
    null block 0."""
    n_slots = len(tables) if n_slots is None else n_slots
    out = np.zeros((n_slots, max_blocks), np.int32)
    for i, blks in enumerate(tables):
        if len(blks) > max_blocks:
            raise ValueError(f"request {i} has {len(blks)} blocks, table "
                             f"holds {max_blocks}")
        out[i, :len(blks)] = blks
    return out
