"""Production-style serving subsystem: continuous batching over a paged KV
cache with open-loop load and SLO-aware scheduling.

Host-side pieces live here (request state machine, block allocator, traffic
generation); the device-side paged attention path is in
``repro.models.transformer`` / ``repro.models.layers``; the end-to-end driver
is ``repro.launch.serve``."""
from .kv_cache import BlockAllocator, OutOfBlocks, blocks_needed, \
    build_block_tables
from .loadgen import SLO, Request, ReqState, bursty_arrivals, make_requests, \
    poisson_arrivals
from .scheduler import Executor, JaxExecutor, Scheduler, ServeReport, \
    SimExecutor, default_compute_model, summarize

__all__ = [
    "BlockAllocator", "OutOfBlocks", "blocks_needed", "build_block_tables",
    "SLO", "Request", "ReqState", "poisson_arrivals", "bursty_arrivals",
    "make_requests", "Executor", "SimExecutor", "JaxExecutor", "Scheduler",
    "ServeReport", "summarize", "default_compute_model",
]
