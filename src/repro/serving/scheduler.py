"""Continuous-batching scheduler with paged KV memory and SLO-aware admission.

Requests join and leave the running batch every step (no lock-step batches):
each step admits waiting requests under a prefill token budget, decodes one
token for every running request, and retires finished ones — the per-request
state machine is WAITING -> PREFILL -> DECODE -> DONE (or SHED).

Time is a virtual clock: each step costs ``max(compute_s, network_s)`` —
compute from a roofline-style model over the tokens processed, network from
the PR 5 priority :class:`~repro.core.engine.Engine` pricing the step's
per-request decode gathers against the periodic fat weight broadcast on the
shared multilevel topology.  The engine is where the paper's machinery meets
serving: under the "priority"/"slo" policies the small latency-bound gathers
preempt the broadcast on shared links (with ageing bounding its starvation)
instead of halving its bandwidth for its whole lifetime.

Memory is paged (``serving.kv_cache``): KV lives in fixed-size blocks handed
out on demand and freed on finish.  ``mode="dense"`` keeps the same
scheduler but reserves every request's worst-case ceil(s_max/block)
blocks at admission — the dense B x s_max allocation expressed in block
units, which is what makes paged-vs-dense capacity comparable at an equal
byte budget.

Policies:

``"fifo"``      FCFS admission, fair-shared network.
``"priority"``  FCFS admission, priority network (decode gathers preempt).
``"slo"``       Earliest-TTFT-deadline-first admission + shed-on-overload
                (a request whose TTFT deadline already passed is dropped
                instead of poisoning the queue behind it), priority network.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Protocol, Sequence

import numpy as np

from ..obs.metrics import MetricsRegistry, percentile
from ..obs.trace import PID_REQUESTS
from .kv_cache import BlockAllocator, blocks_needed
from .loadgen import Request, ReqState

__all__ = ["SchedPolicy", "Executor", "SimExecutor", "JaxExecutor",
           "Scheduler", "ServeReport", "summarize", "default_compute_model"]

SchedPolicy = ("fifo", "priority", "slo")


def default_compute_model(n_params: float, *, flops_per_s: float = 50e12,
                          model_size: int = 1):
    """Roofline step-time model: 2*N FLOPs per token forward, split over the
    tensor-parallel group."""

    def step_s(prefill_tokens: int, decode_tokens: int) -> float:
        tok = prefill_tokens + decode_tokens
        return 2.0 * n_params * tok / (flops_per_s * model_size)

    return step_s


class Executor(Protocol):
    """Model-side of a serve step.  The scheduler owns time, memory, and
    ordering; the executor owns tokens (and, for the jax one, the device
    state behind them)."""

    block_size: int

    def prefill(self, slot: int, blocks: Sequence[int],
                tokens: np.ndarray) -> int:
        """Run the prompt for one request, populate its KV blocks, return
        the greedy first token."""
        ...

    def extend(self, slot: int, block: int) -> None:
        """Append a newly allocated physical block to a slot's table."""
        ...

    def decode(self, slots: Sequence[int], tokens: Sequence[int],
               pos: Sequence[int]) -> list[int]:
        """One decode token for each listed slot (cache already holds
        ``pos[i]`` tokens); returns the greedy next tokens."""
        ...

    def release(self, slot: int) -> None:
        """Forget a finished request's slot (its blocks go back to the
        allocator on the scheduler side)."""
        ...


class SimExecutor:
    """Token-fabricating executor for scale sweeps: no device work, fully
    deterministic tokens — the bench sweeps schedulers and memory policies,
    not model quality."""

    def __init__(self, vocab: int = 512, block_size: int = 16):
        self.vocab = vocab
        self.block_size = block_size

    def prefill(self, slot, blocks, tokens):
        return int((int(tokens[-1]) * 2654435761 + len(tokens)) % self.vocab)

    def extend(self, slot, block):
        pass

    def decode(self, slots, tokens, pos):
        return [int((int(t) * 2654435761 + p) % self.vocab)
                for t, p in zip(tokens, pos)]

    def release(self, slot):
        pass


class JaxExecutor:
    """Real greedy decoding over the paged pools on a device mesh.

    Prefill runs per request at its block-aligned padded length (jit cached
    per length) with ``full_local_cache=True`` and the dense result is
    scattered into the request's physical blocks; decode is one
    ``decode_step_paged`` over the whole slot table with per-slot positions
    — idle slots write to the null block and are ignored."""

    def __init__(self, cfg, mesh, *, n_blocks: int, block_size: int,
                 max_slots: int, max_blocks: int, seed: int = 0):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer as T

        T.paged_arch_check(cfg)
        self._jnp = jnp
        self._T = T
        self.cfg = cfg
        self.mesh = mesh
        self.block_size = block_size
        self.max_slots = max_slots
        self.max_blocks = max_blocks
        self.params = T.init_model(jax.random.PRNGKey(seed), cfg)
        self.pools = T.init_paged_pools(cfg, n_blocks, block_size)
        self.tables = np.zeros((max_slots, max_blocks), np.int32)
        self._prefills: dict[int, object] = {}
        import functools
        self._decode = jax.jit(functools.partial(T.decode_step_paged, cfg=cfg))

    def _prefill_fn(self, S_p: int):
        fn = self._prefills.get(S_p)
        if fn is None:
            import jax
            T, cfg = self._T, self.cfg
            fn = jax.jit(lambda params, toks, last: T.prefill(
                params, cfg, {"tokens": toks}, S_p, last_pos=last,
                full_local_cache=True))
            self._prefills[S_p] = fn
        return fn

    def prefill(self, slot, blocks, tokens):
        jnp = self._jnp
        L = int(tokens.shape[0])
        S_p = len(blocks) * self.block_size
        padded = np.zeros((1, S_p), np.int32)
        padded[0, :L] = tokens
        logits, cache, _ = self._prefill_fn(S_p)(
            self.params, jnp.asarray(padded), jnp.asarray([L - 1]))
        self.pools = self._T.scatter_prefill_cache(
            self.pools, cache, list(blocks), self.block_size, row=0)
        self.tables[slot, :] = 0
        self.tables[slot, :len(blocks)] = blocks
        return int(np.argmax(np.asarray(logits[0, -1])))

    def extend(self, slot, block):
        row = self.tables[slot]
        free = np.nonzero(row == 0)[0]
        if not len(free):
            raise ValueError(f"slot {slot} block table full")
        row[free[0]] = block

    def decode(self, slots, tokens, pos):
        jnp = self._jnp
        tok = np.zeros((self.max_slots, 1), np.int32)
        posv = np.zeros((self.max_slots,), np.int32)
        for s, t, p in zip(slots, tokens, pos):
            tok[s, 0] = t
            posv[s] = p
        logits, self.pools = self._decode(
            params=self.params, pools=self.pools,
            block_tables=jnp.asarray(self.tables), tokens=jnp.asarray(tok),
            pos=jnp.asarray(posv))
        out = np.asarray(jnp.argmax(logits[:, 0], -1))
        return [int(out[s]) for s in slots]

    def release(self, slot):
        self.tables[slot, :] = 0


@dataclasses.dataclass
class ServeReport:
    """Outcome of one :meth:`Scheduler.run`."""

    requests: list[Request]
    steps: int
    now: float
    max_concurrent: int
    stalled_steps: int

    def summary(self) -> dict:
        return summarize(self.requests) | {
            "steps": self.steps, "sim_s": self.now,
            "max_concurrent": self.max_concurrent,
            "stalled_steps": self.stalled_steps,
        }


def _pct(xs: list[float], q: float) -> float:
    # one percentile rule repo-wide: the obs registry's (numpy linear
    # interpolation, NaN on empty) — summarize() keys are schema-guarded,
    # so the delegation must not change values, only their provenance
    return percentile(xs, q)


def summarize(requests: list[Request]) -> dict:
    done = [r for r in requests if r.state is ReqState.DONE]
    shed = [r for r in requests if r.state is ReqState.SHED]
    ttft = [r.ttft for r in done if r.ttft is not None]
    tpot = [r.tpot for r in done if r.tpot is not None]
    tokens = sum(len(r.tokens) for r in done)
    span = max((r.finish_s for r in done), default=0.0)
    out = {
        "n_requests": len(requests), "n_done": len(done), "n_shed": len(shed),
        "ttft_p50_s": _pct(ttft, 50), "ttft_p99_s": _pct(ttft, 99),
        "tpot_p50_s": _pct(tpot, 50), "tpot_p99_s": _pct(tpot, 99),
        "throughput_tok_s": tokens / span if span > 0 else 0.0,
    }
    slo_reqs = [r for r in done if r.slo is not None]
    if slo_reqs:
        ok = [r for r in slo_reqs
              if r.ttft <= r.slo.ttft_s and (r.tpot or 0) <= r.slo.tpot_s]
        out["slo_attainment"] = len(ok) / len(requests)
    return out


class Scheduler:
    """See module docstring.  One instance runs one trace via :meth:`run`.

    ``engine``/``replicas``/``weight_bytes``/``gather_bytes`` wire the
    network plane: each step issues one small per-request collective
    (``gather_op``: "allgather" models column-parallel activation
    gathering, "allreduce" row-parallel output reduction) on the request's
    tensor-parallel replica group at priority 1.0 and, every
    ``bcast_every`` steps, the fat weight broadcast over all ranks (default
    priority ``-nbytes`` — it only wins a link when nothing small wants it,
    aged so it cannot starve).  Without an engine the step cost is pure
    compute."""

    def __init__(self, executor, *, n_blocks: int, block_size: int,
                 max_slots: int, s_max: int, policy: str = "fifo",
                 mode: str = "paged", prefill_token_budget: int = 512,
                 compute_model=None, engine=None,
                 replicas: Sequence[tuple[int, ...]] | None = None,
                 weight_bytes: float = 0.0, gather_bytes: float = 1.0,
                 gather_op: str = "allgather", bcast_every: int = 0,
                 tracer=None, metrics: MetricsRegistry | None = None,
                 monitor=None):
        if policy not in SchedPolicy:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {SchedPolicy}")
        if mode not in ("paged", "dense"):
            raise ValueError(f"unknown mode {mode!r}")
        if gather_op not in ("allgather", "allreduce"):
            raise ValueError(f"unknown gather_op {gather_op!r}")
        if s_max % block_size:
            raise ValueError("s_max must be a multiple of block_size")
        self.ex = executor
        self.alloc = BlockAllocator(n_blocks, block_size)
        self.block_size = block_size
        self.max_slots = max_slots
        self.s_max = s_max
        self.max_blocks = s_max // block_size
        self.policy = policy
        self.mode = mode
        self.budget = prefill_token_budget
        self.compute_model = compute_model or (lambda pre, dec: 0.0)
        self.engine = engine
        self.replicas = list(replicas or [])
        self.weight_bytes = float(weight_bytes)
        self.gather_bytes = float(gather_bytes)
        self.gather_op = gather_op
        self.bcast_every = bcast_every
        # a traced engine traces its scheduler too (one trace per serve run)
        self.tracer = tracer if tracer is not None \
            else getattr(engine, "tracer", None)
        # a monitored engine monitors its scheduler too (request outcomes
        # and the per-step health check ride the same object)
        self.monitor = monitor if monitor is not None \
            else getattr(engine, "monitor", None)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_done = self.metrics.counter("serve.done")
        self._m_shed = self.metrics.counter("serve.shed")
        self._m_stalled = self.metrics.counter("serve.stalled_steps")
        self._m_ttft = self.metrics.histogram("serve.ttft_s")
        self._m_tpot = self.metrics.histogram("serve.tpot_s")

    # -- admission ------------------------------------------------------- #
    def _padded_len(self, req: Request) -> int:
        n = blocks_needed(req.prompt_len, self.block_size)
        return n * self.block_size

    def _admit_blocks(self, req: Request) -> int:
        """Blocks to reserve at admission: paged = just the prompt (grow on
        demand), dense = the full worst-case s_max footprint."""
        if self.mode == "dense":
            return self.max_blocks
        return blocks_needed(req.prompt_len, self.block_size)

    def _admit(self, waiting: deque, running: list, now: float):
        """Admit under the token budget (mutates ``waiting``/``running``);
        returns (prefill tokens spent, admitted requests)."""
        q = list(waiting)
        waiting.clear()
        if self.policy == "slo":
            q.sort(key=lambda r: (r.slo.ttft_deadline(r.arrival_s)
                                  if r.slo else float("inf")))
        budget = self.budget
        admitted, keep = [], []
        free_slots = sorted(set(range(self.max_slots))
                            - {r.slot for r in running})
        for i, r in enumerate(q):
            if (self.policy == "slo" and r.slo is not None
                    and now > r.slo.ttft_deadline(r.arrival_s)):
                r.state = ReqState.SHED
                r.finish_s = now
                self._m_shed.inc()
                if self.monitor is not None:
                    self.monitor.observe_request(r)
                if self.tracer is not None:
                    self.tracer.instant(PID_REQUESTS, f"req{r.rid}", "shed",
                                        now, {"reason": "ttft deadline past",
                                              "waited_s": now - r.arrival_s})
                continue
            need = self._admit_blocks(r)
            S_p = self._padded_len(r)
            # an over-budget prompt still enters on an otherwise-idle step,
            # else it could never be admitted at all
            over = S_p > budget and admitted
            # paged watermark: keep one growth block in reserve per running
            # request so admission doesn't immediately OOM-stall the batch
            headroom = 0 if self.mode == "dense" \
                else len(running) + len(admitted)
            fits = need + headroom <= self.alloc.n_free
            if not free_slots or over or not fits:
                keep.append(r)
                # FCFS head-of-line blocking is the point of fifo; EDF keeps
                # scanning so a small late-deadline request can't block an
                # urgent one behind it
                if self.policy != "slo":
                    keep.extend(q[i + 1:])
                    break
                continue
            budget -= S_p
            r.slot = free_slots.pop(0)
            r.blocks = self.alloc.alloc(need)
            r.state = ReqState.PREFILL
            admitted.append(r)
            running.append(r)
        waiting.extend(keep)
        return self.budget - budget, admitted

    # -- network --------------------------------------------------------- #
    def _network_step(self, running: list, now: float, step: int) -> float:
        if self.engine is None or not running:
            return 0.0
        handles = []
        for r in running:
            members = (self.replicas[r.slot % len(self.replicas)]
                       if self.replicas else None)
            handles.append(self.engine.issue(
                self.gather_op, self.gather_bytes, members=members,
                at=now, priority=1.0))
        if (self.bcast_every and self.weight_bytes
                and step % self.bcast_every == 0):
            # fat broadcast: default priority -nbytes ranks below every
            # request gather; its completion is NOT on the step's critical
            # path (it trails across steps), only its contention is priced
            self.engine.issue("bcast", self.weight_bytes, at=now)
        self.engine.wait_all()
        return max(h.finished for h in handles) - now

    # -- main loop ------------------------------------------------------- #
    def run(self, requests: list[Request]) -> ServeReport:
        pending = deque(sorted(requests, key=lambda r: r.arrival_s))
        waiting: deque[Request] = deque()
        running: list[Request] = []
        now, step, max_conc, stalls = 0.0, 0, 0, 0
        tr = self.tracer
        admit_s: dict[int, float] = {}  # rid -> admission time (spans)

        while pending or waiting or running:
            while pending and pending[0].arrival_s <= now:
                waiting.append(pending.popleft())
            if not waiting and not running:
                now = pending[0].arrival_s
                continue

            prefill_tokens, admitted = self._admit(waiting, running, now)
            if tr is not None:
                for r in admitted:
                    admit_s[r.rid] = now
                    if now > r.arrival_s:
                        tr.span(PID_REQUESTS, f"req{r.rid}", "waiting",
                                r.arrival_s, now)
            if not running and waiting:
                # nothing runs and the head request can't ever be admitted
                # (every block is free right now): fail loudly, don't spin
                raise RuntimeError(
                    f"request {waiting[0].rid} needs more memory/slots than "
                    f"the scheduler has (capacity {self.alloc.capacity} "
                    f"blocks, {self.max_slots} slots)")
            max_conc = max(max_conc, len(running))

            # decode plane: requests already holding a first token
            deciding, stalled = [], []
            for r in running:
                if r.state is not ReqState.DECODE:
                    continue
                need = blocks_needed(r.pos + 1, self.block_size)
                if need > len(r.blocks):
                    if need > self.max_blocks:
                        raise RuntimeError(f"request {r.rid} overran s_max")
                    if self.alloc.can_alloc(1):
                        blk = self.alloc.alloc(1)[0]
                        r.blocks.append(blk)
                        self.ex.extend(r.slot, blk)
                    else:
                        stalled.append(r)   # OOM: skip this step, retry
                        r.stalled_steps += 1
                        continue
                deciding.append(r)
            stalls += len(stalled)
            if stalled:
                self._m_stalled.inc(len(stalled))
            if stalled and not deciding and not admitted:
                # every live request is OOM-stalled: nobody will ever free a
                # block.  Evict the youngest to break the deadlock (its
                # blocks recycle into the survivors).
                victim = max(stalled, key=lambda r: r.arrival_s)
                victim.state = ReqState.SHED
                victim.finish_s = now
                self._m_shed.inc()
                if self.monitor is not None:
                    self.monitor.observe_request(victim, evicted=True)
                if tr is not None:
                    tr.instant(PID_REQUESTS, f"req{victim.rid}", "evicted",
                               now, {"reason": "OOM deadlock, youngest "
                                               "victim recycled"})
                self.alloc.free(victim.blocks)
                victim.blocks = []
                self.ex.release(victim.slot)
                victim.slot = -1
                running.remove(victim)
                continue

            compute_s = self.compute_model(prefill_tokens, len(deciding))
            net_s = self._network_step(running, now, step)
            now += max(compute_s, net_s)

            # commit tokens at the step's completion time
            for r in admitted:
                tok = self.ex.prefill(r.slot, r.blocks, r.prompt)
                r.pos = r.prompt_len
                r.tokens.append(tok)
                r.first_token_s = now
                r.state = ReqState.DECODE
                if tr is not None:
                    tr.span(PID_REQUESTS, f"req{r.rid}", "prefill",
                            admit_s[r.rid], now,
                            {"prompt_len": r.prompt_len,
                             "ttft_s": now - r.arrival_s})
            if deciding:
                toks = self.ex.decode([r.slot for r in deciding],
                                      [r.tokens[-1] for r in deciding],
                                      [r.pos for r in deciding])
                for r, t in zip(deciding, toks):
                    r.tokens.append(int(t))
                    r.pos += 1

            for r in list(running):
                if len(r.tokens) >= r.max_new_tokens:
                    r.state = ReqState.DONE
                    r.finish_s = now
                    self._m_done.inc()
                    if r.ttft is not None:
                        self._m_ttft.observe(r.ttft)
                    if r.tpot is not None:
                        self._m_tpot.observe(r.tpot)
                    if self.monitor is not None:
                        self.monitor.observe_request(r)
                    if tr is not None:
                        tr.span(PID_REQUESTS, f"req{r.rid}", "decode",
                                r.first_token_s, now,
                                {"tokens": len(r.tokens),
                                 "ttft_s": r.ttft, "tpot_s": r.tpot})
                    self.alloc.free(r.blocks)
                    r.blocks = []
                    self.ex.release(r.slot)
                    r.slot = -1
                    running.remove(r)
            step += 1
            if self.monitor is not None:
                self.monitor.on_step(now, step)

        return ServeReport(requests, step, now, max_conc, stalls)
