"""Deterministic synthetic data pipeline, sharded by host.

Production shape: each host materialises only its slice of the global batch
(``host_id`` / ``num_hosts``), tokens are a cheap stateless hash of
(step, global position) so any host can regenerate any shard — which is what
makes checkpoint-restart and elastic rescaling trivial: the pipeline state is
just the step counter.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.configs.shapes import ShapeSpec, AUDIO_SRC_FRACTION, VISION_PATCHES

__all__ = ["DataPipeline"]

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _hash_tokens(step: int, lo: int, hi: int, vocab: int, salt: int = 0) -> np.ndarray:
    """Deterministic tokens for flat global indices [lo, hi)."""
    idx = np.arange(lo, hi, dtype=np.uint64)
    with np.errstate(over="ignore"):  # intentional mod-2^64 hashing
        x = (idx + np.uint64(step + 1) * _MIX
             + np.uint64(salt) * np.uint64(0xDA442D24)) \
            * np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(31)
    return (x % np.uint64(vocab)).astype(np.int32)


@dataclasses.dataclass
class DataPipeline:
    cfg: ModelConfig
    shape: ShapeSpec
    host_id: int = 0
    num_hosts: int = 1

    def _local_rows(self) -> tuple[int, int]:
        B = self.shape.global_batch
        per = B // self.num_hosts
        return self.host_id * per, per

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        """This host's slice of the global batch for ``step`` (numpy)."""
        row0, rows = self._local_rows()
        S = self.shape.seq_len
        cfg = self.cfg
        out: dict[str, np.ndarray] = {}
        if cfg.enc_dec:
            s_src = int(S * AUDIO_SRC_FRACTION)
            s_tgt = S - s_src
            t = self._tok(step, row0, rows, s_tgt + 1, salt=1)
            out["src_embeds"] = self._embeds(step, row0, rows, s_src)
            out["tokens"], out["labels"] = t[:, :-1], t[:, 1:]
        elif cfg.frontend == "vision":
            n_img = min(VISION_PATCHES, S // 4)
            t = self._tok(step, row0, rows, S - n_img + 1, salt=1)
            out["embeds"] = self._embeds(step, row0, rows, n_img)
            out["tokens"], out["labels"] = t[:, :-1], t[:, 1:]
        else:
            t = self._tok(step, row0, rows, S + 1, salt=1)
            out["tokens"], out["labels"] = t[:, :-1], t[:, 1:]
        return out

    def _tok(self, step, row0, rows, width, salt=0) -> np.ndarray:
        lo = row0 * width
        t = _hash_tokens(step, lo, lo + rows * width, self.cfg.vocab, salt)
        return t.reshape(rows, width)

    def _embeds(self, step, row0, rows, s) -> np.ndarray:
        base = _hash_tokens(step, row0 * s, (row0 + rows) * s, 1 << 16, salt=7)
        x = (base.reshape(rows, s, 1).astype(np.float32) / (1 << 15)) - 1.0
        d = self.cfg.d_model
        phase = np.arange(d, dtype=np.float32) / d
        return (np.sin(x * 6.28318 + phase) / np.sqrt(d)).astype(np.float32)

    # ------------------------------------------------------------------ #
    def global_batch(self, step: int, mesh, pspec) -> dict:
        """Device-resident global batch (single-process path: all rows)."""
        from jax.sharding import NamedSharding
        full = DataPipeline(self.cfg, self.shape, 0, 1).host_batch(step)
        def put(name, arr):
            sh = NamedSharding(mesh, pspec)
            return jax.device_put(arr, sh)
        return {k: put(k, v) for k, v in full.items()}
