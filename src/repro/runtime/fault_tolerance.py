"""Fault tolerance: failure detection, elastic re-meshing, straggler
mitigation.

At 1000+ nodes the design assumptions are:
  * failures are the steady state — MTBF of a 512-chip job is hours;
  * the control plane must react without a global barrier: detection via
    heartbeat timeout, recovery via checkpoint-restart onto a SHRUNK mesh
    (drop the failed pod / data slice), re-expansion when capacity returns;
  * stragglers are handled with bounded staleness, not synchronous waits.

On this CPU container, failures are injected by tests/drivers through
``FailureInjector``; the recovery logic itself (mesh shrink maps, restore,
pipeline fast-forward) is the real code path that would run on hardware —
only the detector's input (heartbeats vs injected events) differs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

__all__ = ["HeartbeatTracker", "FailureInjector", "ElasticPlan",
           "plan_recovery", "StragglerMonitor", "has_quorum",
           "pod_member_ranks"]


# ---------------------------------------------------------------------- #
# Detection
# ---------------------------------------------------------------------- #

class HeartbeatTracker:
    """Coordinator-side liveness table.  Hosts ping; silence past
    ``timeout_s`` marks every device on that host failed."""

    def __init__(self, hosts: list[str], timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.timeout_s = timeout_s
        now = clock()
        self._last: dict[str, float] = {h: now for h in hosts}

    def ping(self, host: str) -> None:
        self._last[host] = self._clock()

    def dead_hosts(self) -> list[str]:
        now = self._clock()
        return [h for h, t in self._last.items()
                if now - t > self.timeout_s]


class FailureInjector:
    """Deterministic failure schedule for tests: (step -> failed pod ids).

    Events are CONSUMED on read — a pod fails once; after the driver
    recovers and replays from the last checkpoint, re-reaching the same
    step number must not re-fire the event (that would loop forever)."""

    def __init__(self, schedule: dict[int, list[int]]):
        self.schedule = dict(schedule)

    def failed_pods_at(self, step: int) -> list[int]:
        return self.schedule.pop(step, [])


def has_quorum(total: int, n_failed: int, quorum: float = 0.5) -> bool:
    """True when strictly more than ``quorum`` of ``total`` members survive
    ``n_failed`` losses — the threshold between in-place communicator
    repair (carry live state, no replay) and checkpoint-restart."""
    return (total - n_failed) > quorum * total


def pod_member_ranks(mesh_shape: tuple[int, ...],
                     axis_names: tuple[str, ...],
                     pods: list[int]) -> list[int]:
    """Data-parallel member ranks living on the given pods, in the flat
    row-major (pod, data) rank space shared by ``launch.mesh.dp_topology``
    and the jax backend — what :meth:`Communicator.repair` takes."""
    shape = dict(zip(axis_names, mesh_shape))
    data = shape.get("data", 1)
    n_pods = shape.get("pod", 1)
    return [p * data + i for p in sorted(set(pods)) if p < n_pods
            for i in range(data)]


# ---------------------------------------------------------------------- #
# Elastic recovery planning
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """What the launcher does after failures: the new mesh shape and how the
    global batch re-maps onto it."""
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    lost_pods: tuple[int, ...]
    # grad-accumulation factor so the GLOBAL batch stays constant after the
    # dp degree shrank (bit-for-bit identical training trajectory)
    accum_factor: int

    @property
    def changed(self) -> bool:
        return self.old_shape != self.new_shape


def plan_recovery(mesh_shape: tuple[int, ...], axis_names: tuple[str, ...],
                  failed_pods: list[int]) -> ElasticPlan:
    """Shrink the 'pod' axis by the failed pods; keep intra-pod axes whole
    (a pod either works or is drained — ICI failures take out the slice).
    The dp degree drops, so grad accumulation rises to hold the global batch
    constant."""
    shape = dict(zip(axis_names, mesh_shape))
    n_pods = shape.get("pod", 1)
    lost = sorted(set(p for p in failed_pods if p < n_pods))
    new_pods = max(n_pods - len(lost), 1)
    new_shape = tuple(new_pods if a == "pod" else shape[a] for a in axis_names)
    accum = max(1, n_pods // new_pods)
    return ElasticPlan(mesh_shape, new_shape, axis_names, tuple(lost), accum)


# ---------------------------------------------------------------------- #
# Straggler mitigation
# ---------------------------------------------------------------------- #

class StragglerMonitor:
    """Bounded-staleness straggler policy.

    Tracks per-step wall times; a worker whose step exceeds
    ``threshold x running-median`` is declared a straggler.  The driver's
    response (at scale): drop that worker's microbatch from the current
    all-reduce (the multilevel tree makes this cheap — its subtree simply
    contributes zero and the mean renormalises) and rebalance its shard at
    the next accumulation boundary.  Here we record + expose decisions so
    drivers/tests can act on them.
    """

    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self._times: list[float] = []
        self.dropped_steps: list[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; True -> this step was straggler-slow."""
        med = float(np.median(self._times)) if self._times else seconds
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        is_straggler = len(self._times) >= 8 and seconds > self.threshold * med
        if is_straggler:
            self.dropped_steps.append(step)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


def plan_expansion(mesh_shape: tuple[int, ...], axis_names: tuple[str, ...],
                   available_pods: int) -> ElasticPlan:
    """Re-expand the pod axis when drained capacity returns: the inverse of
    ``plan_recovery``.  Grad accumulation drops so the global batch stays
    constant; the checkpoint restores onto the wider mesh unchanged (params
    are pod-replicated; ZeRO shards live on the intra-pod data axis)."""
    shape = dict(zip(axis_names, mesh_shape))
    cur = shape.get("pod", 1)
    new_pods = max(available_pods, cur)
    new_shape = tuple(new_pods if a == "pod" else shape[a] for a in axis_names)
    # dp degree grows back -> accumulation returns to 1 (global batch const)
    return ElasticPlan(mesh_shape, new_shape, axis_names, (), 1)
