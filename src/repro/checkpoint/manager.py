"""Sharded checkpointing with atomic commits and an async writer thread.

Layout:  <dir>/step_<N>/   arrays.npz-per-leaf + manifest.json
Commit protocol: write into ``step_<N>.tmp``, fsync, atomic rename — a crash
mid-write can never corrupt the latest durable checkpoint (restore scans for
the newest *committed* directory).  ``keep`` bounds disk usage.

On a real multi-host fleet each host writes only the shards it owns
(``process_index`` in the leaf filename) — here single-process writes all.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any

import numpy as np
import ml_dtypes
import jax

__all__ = ["CheckpointManager"]

_BF16 = np.dtype(ml_dtypes.bfloat16)


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """np.save silently degrades bfloat16 to a void dtype — store it as a
    uint16 view and record the logical dtype in the manifest."""
    if arr.dtype == _BF16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16":
        return arr.view(_BF16)
    return arr


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        out.append((name, leaf))
    return out


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_write: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously (consistent point), write
        to disk asynchronously."""
        host_state = jax.tree.map(np.asarray, state)  # device -> host copy
        if self.async_write and not blocking:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Any) -> None:
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for name, leaf in _leaf_paths(host_state):
            arr, dtype_str = _to_savable(np.asarray(leaf))
            fn = f"{name}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][fn] = {"shape": list(arr.shape),
                                      "dtype": dtype_str}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.directory, d,
                                                    "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Load checkpoint ``step`` into the structure of ``like``."""
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            name = "_".join(
                str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
            arr = np.load(os.path.join(d, f"{name}.npy"))
            arr = _from_saved(arr, manifest["leaves"][f"{name}.npy"]["dtype"])
            if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
