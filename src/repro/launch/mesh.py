"""Production meshes.

Topology-aware axis placement (the paper's rule applied to mesh design): the
`model` (TP) axis — one collective per layer — maps to the innermost,
fastest device dimension; `data` spans a pod's ICI; `pod` is the outermost
DCN level and carries exactly one (multilevel-decomposed) gradient exchange
per step.  No tensor-parallel collective ever crosses a pod boundary.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_topology",
           "dp_topology", "dp_decomposition", "mesh_communicator"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(pods: int = 1, data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_topology(mesh) -> "object":
    """The core.Topology matching a mesh: strata = [pod, data-row]; used to
    build the paper's explicit trees over the flattened device order."""
    import numpy as np
    from repro.core.topology import Topology, DCN, ICI_FAR, ICI

    pods = mesh.shape.get("pod", 1)
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)
    P = pods * data * model
    idx = np.arange(P)
    coords = np.stack([idx // (data * model), idx // model], axis=1)
    return Topology(coords, [DCN, ICI_FAR, ICI])


def dp_topology(mesh) -> "object":
    """The core.Topology over the DATA-PARALLEL ranks only (pod x data),
    matching the jax backend's flat (slow, *fast) index space — model-axis
    peers hold distinct parameter shards and are not collective members."""
    import numpy as np
    from repro.core.topology import Topology, DCN, ICI

    pods = mesh.shape.get("pod", 1)
    data = mesh.shape.get("data", 1)
    coords = (np.arange(pods * data) // data)[:, None]
    return Topology(coords, [DCN, ICI])


def dp_decomposition(mesh) -> tuple:
    """(slow_axis, fast_axes) of the data-parallel axes: the multilevel
    gradient exchange reduce-scatters over ``fast_axes`` and crosses
    ``slow_axis`` (the DCN) exactly once per step."""
    slow = "pod" if "pod" in mesh.shape else None
    fast = ("data",) if "data" in mesh.shape else ()
    return slow, fast


def mesh_communicator(mesh, *, backend: str = "jax", policy="paper", **kw):
    """The :class:`repro.core.Communicator` for a device mesh.

    backend "jax": axis-decomposed collectives over the dp axes.
    backend "ppermute": explicit tree rounds over a single flattened axis
        (pass ``axis=``, or use a 1-axis mesh).
    backend "sim": postal-model planning/estimation on the mesh's topology.
    """
    from repro.core import Communicator

    topo = mesh_topology(mesh)
    if backend == "jax":
        # rank space = (pod, data) only: use the dp-scoped topology so
        # member/root indices agree with the backend's axis_index space
        topo = dp_topology(mesh)
        slow, fast = dp_decomposition(mesh)
        kw.setdefault("slow_axis", slow)
        kw.setdefault("fast_axes", fast)
    elif backend == "ppermute" and "axis" not in kw:
        if len(mesh.axis_names) != 1:
            raise ValueError("ppermute backend needs axis= on multi-axis "
                             "meshes")
        kw["axis"] = mesh.axis_names[0]
    return Communicator(topo, backend=backend, policy=policy, **kw)
