"""Production meshes.

Topology-aware axis placement (the paper's rule applied to mesh design): the
`model` (TP) axis — one collective per layer — maps to the innermost,
fastest device dimension; `data` spans a pod's ICI; `pod` is the outermost
DCN level and carries exactly one (multilevel-decomposed) gradient exchange
per step.  No tensor-parallel collective ever crosses a pod boundary.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_topology"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(pods: int = 1, data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_topology(mesh) -> "object":
    """The core.Topology matching a mesh: strata = [pod, data-row]; used to
    build the paper's explicit trees over the flattened device order."""
    import numpy as np
    from repro.core.topology import Topology, DCN, ICI_FAR, ICI

    pods = mesh.shape.get("pod", 1)
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)
    P = pods * data * model
    idx = np.arange(P)
    coords = np.stack([idx // (data * model), idx // model], axis=1)
    return Topology(coords, [DCN, ICI_FAR, ICI])
