"""Serving driver: batched prefill + decode with a continuous-batching-style
request queue, using the multilevel tree broadcast for weight distribution.

CPU demo:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-100m --requests 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T


def serve(arch: str, n_requests: int, prompt_len: int, gen_len: int,
          mesh_spec: str = "1x2x2", smoke: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    pods, data, model = (int(x) for x in mesh_spec.split("x"))
    mesh = make_test_mesh(pods, data, model)
    s_max = prompt_len + gen_len

    params = T.init_model(jax.random.PRNGKey(0), cfg)
    from repro.models.sharding import param_shardings
    params = jax.device_put(params, param_shardings(params, mesh))

    # Weight-distribution plan through the single collectives entry point:
    # the multilevel tree broadcast of updated params crosses each slow link
    # exactly once (paper §3.2); on a one-host demo we surface the plan and
    # its postal-model estimate rather than shipping real bytes.
    from repro.launch.mesh import mesh_communicator
    wcomm = mesh_communicator(mesh, backend="sim", policy="paper")
    wbytes = float(sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(params)))
    print(f"[serve] {wcomm.describe()}; weight bcast "
          f"({wbytes/1e6:.1f} MB): est "
          f"{wcomm.bcast(wbytes, root=0).time*1e3:.2f} ms, "
          f"{wcomm.slow_crossings('bcast', nbytes=wbytes)} slow-link "
          f"crossing(s)")

    # Concurrent traffic through the async engine: the fat weight broadcast
    # and every request's (tensor-parallel) activation gather live on the
    # network AT ONCE; under the "priority" policy the small per-request
    # collectives preempt the broadcast on shared links instead of stalling
    # behind it.  Requests land round-robin on the data-parallel replicas.
    from repro.core.engine import Engine
    replicas = [tuple(range(g * model, (g + 1) * model))
                for g in range(pods * data)]
    req_bytes = float(prompt_len * cfg.d_model * 2)  # bf16 activations
    lat = {}
    for policy in ("fifo", "priority"):
        eng = Engine(wcomm, policy=policy)
        eng.issue("bcast", wbytes, root=0)
        reqs = [eng.issue("allgather", req_bytes / model,
                          members=replicas[r % len(replicas)], priority=1.0)
                for r in range(n_requests)]
        eng.wait_all()
        lat[policy] = (eng.now,
                       sum(h.finished for h in reqs) / max(len(reqs), 1))
    serial = wcomm.bcast(wbytes, root=0).time + sum(
        Engine(wcomm).issue("allgather", req_bytes / model,
                            members=replicas[r % len(replicas)]).wait().time
        for r in range(n_requests))
    print(f"[serve] engine batch (1 weight bcast + {n_requests} request "
          f"gathers): makespan {lat['priority'][0]*1e3:.2f} ms vs "
          f"{serial*1e3:.2f} ms serialized; mean request latency "
          f"{lat['priority'][1]*1e3:.3f} ms (priority) vs "
          f"{lat['fifo'][1]*1e3:.3f} ms (fifo)")

    prefill = STEP.make_prefill_step(cfg, mesh, s_max)
    decode = STEP.make_decode_step(cfg, mesh)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (n_requests, prompt_len)).astype(np.int32)

    t0 = time.monotonic()
    inputs = {"tokens": jnp.asarray(prompts)}
    if cfg.enc_dec:
        inputs["src_embeds"] = jnp.zeros((n_requests, prompt_len, cfg.d_model),
                                         jnp.bfloat16)
    with compat.set_mesh(mesh):
        logits, cache, pos = prefill(params, inputs)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens = [np.asarray(tok)]
        p = jnp.int32(pos)
        for i in range(gen_len - 1):
            logits, cache = decode(params, cache, tok, p + i)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok))
    dt = time.monotonic() - t0
    gen = np.concatenate(out_tokens, axis=1)
    return {"generated": gen, "seconds": dt,
            "tokens_per_s": n_requests * gen_len / dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-100m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh", default="1x2x2")
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.prompt_len, args.gen_len,
                args.mesh)
    print(f"[serve] generated {out['generated'].shape} tokens in "
          f"{out['seconds']:.2f}s ({out['tokens_per_s']:.1f} tok/s)")
    print("[serve] first request:", out["generated"][0][:16])


if __name__ == "__main__":
    main()
