"""Serving driver: continuous batching over a paged KV cache, with the
multilevel engine pricing per-request collectives against weight broadcasts.

CPU demo:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-100m --requests 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh, mesh_communicator
from repro.models import transformer as T
from repro.obs import Tracer, get_logger, set_json
from repro.serving import (JaxExecutor, Scheduler, SLO, make_requests,
                           poisson_arrivals, default_compute_model)

log = get_logger("serve")


def _weight_bytes(params) -> float:
    return float(sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree.leaves(params)))


def _engine_demo(wcomm, wbytes: float, cfg, prompt_len: int, model: int,
                 replicas: list, n_requests: int) -> None:
    """Price 1 weight bcast + N request gathers under fifo vs priority."""
    from repro.core.engine import Engine
    act_itemsize = jnp.dtype(cfg.dtype).itemsize
    req_bytes = float(prompt_len * cfg.d_model * act_itemsize)
    lat = {}
    for policy in ("fifo", "priority"):
        eng = Engine(wcomm, policy=policy, age_rate=wbytes)
        eng.issue("bcast", wbytes, root=0)
        issue_t = eng.now
        reqs = [eng.issue("allgather", req_bytes / model,
                          members=replicas[r % len(replicas)], priority=1.0)
                for r in range(n_requests)]
        eng.wait_all()
        mean_lat = (sum(h.finished - issue_t for h in reqs)
                    / max(len(reqs), 1))
        lat[policy] = (eng.now, mean_lat)
    serial = wcomm.bcast(wbytes, root=0).time + sum(
        Engine(wcomm).issue("allgather", req_bytes / model,
                            members=replicas[r % len(replicas)]).wait().time
        for r in range(n_requests))
    log.info(f"engine batch (1 weight bcast + {n_requests} request "
             f"gathers): makespan {lat['priority'][0]*1e3:.2f} ms vs "
             f"{serial*1e3:.2f} ms serialized; mean request latency "
             f"{lat['priority'][1]*1e3:.3f} ms (priority) vs "
             f"{lat['fifo'][1]*1e3:.3f} ms (fifo)",
             event="engine_demo",
             makespan_ms=lat["priority"][0] * 1e3,
             serial_ms=serial * 1e3,
             mean_latency_priority_ms=lat["priority"][1] * 1e3,
             mean_latency_fifo_ms=lat["fifo"][1] * 1e3)


def serve(arch: str, n_requests: int, prompt_len: int, gen_len: int,
          mesh_spec: str = "1x2x2", smoke: bool = True, *,
          policy: str = "priority", block_size: int = 8,
          rate: float | None = None, trace: str | None = None,
          monitor: bool = False, metrics_out: str | None = None) -> dict:
    """Run ``n_requests`` through the continuous-batching scheduler on a
    host-device demo mesh (paged KV cache, real greedy decoding).

    ``rate``: open-loop Poisson arrival rate (req/s of *simulation* time);
    default: all requests arrive at t=0 (closed batch).  ``trace`` writes
    a Chrome trace (request lifecycles, engine spans, link occupancy).
    ``monitor`` attaches a :class:`~repro.obs.HealthMonitor` to the engine
    (drift detection + auto-refit, periodic health snapshots in the log);
    ``metrics_out`` writes the run's Prometheus text exposition — a
    scrape-file path that needs no tracer at all."""
    cfg = get_config(arch, smoke=smoke)
    pods, data, model = (int(x) for x in mesh_spec.split("x"))
    mesh = make_test_mesh(pods, data, model)
    tracer = Tracer() if trace else None
    s_max = prompt_len + gen_len
    s_max += (-s_max) % block_size

    params_probe = jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg))
    wbytes = _weight_bytes(params_probe)

    # Weight-distribution plan through the single collectives entry point:
    # the multilevel tree broadcast of updated params crosses each slow link
    # exactly once (paper §3.2); on a one-host demo we surface the plan and
    # its postal-model estimate rather than shipping real bytes.
    wcomm = mesh_communicator(mesh, backend="sim", policy="paper")
    if tracer is not None:
        wcomm.tracer = tracer
    bcast_est = wcomm.bcast(wbytes, root=0).time
    crossings = wcomm.slow_crossings('bcast', nbytes=wbytes)
    log.info(f"{wcomm.describe()}; weight bcast "
             f"({wbytes/1e6:.1f} MB): est {bcast_est*1e3:.2f} ms, "
             f"{crossings} slow-link crossing(s)",
             event="setup", weight_mb=wbytes / 1e6,
             bcast_est_ms=bcast_est * 1e3, slow_crossings=crossings)

    replicas = [tuple(range(g * model, (g + 1) * model))
                for g in range(pods * data)]
    _engine_demo(wcomm, wbytes, cfg, prompt_len, model, replicas, n_requests)

    # Continuous batching: requests join/leave the running batch per step;
    # KV lives in on-demand blocks; each step's decode gathers are priced
    # against the periodic weight broadcast by the priority engine.
    from repro.core.engine import Engine
    max_slots = min(n_requests, 8)
    n_blocks = 1 + max_slots * (s_max // block_size)
    ex = JaxExecutor(cfg, mesh, n_blocks=n_blocks, block_size=block_size,
                     max_slots=max_slots, max_blocks=s_max // block_size)
    act_itemsize = jnp.dtype(cfg.dtype).itemsize
    # one registry spans engine + scheduler + monitor when scraping: the
    # exposition file must read as ONE process, not three
    from repro.obs import MetricsRegistry
    registry = MetricsRegistry() if metrics_out or monitor else None
    eng = Engine(wcomm, policy="fifo" if policy == "fifo" else "priority",
                 age_rate=wbytes, metrics=registry)
    mon = None
    if monitor:
        from repro.obs import HealthMonitor
        mon = HealthMonitor(engine=eng, metrics=registry,
                            log_every=4)
    sch = Scheduler(
        ex, n_blocks=n_blocks, block_size=block_size, max_slots=max_slots,
        s_max=s_max, policy=policy, prefill_token_budget=4 * prompt_len,
        compute_model=default_compute_model(cfg.active_param_count(),
                                            model_size=model),
        engine=eng, replicas=replicas,
        weight_bytes=wbytes,
        gather_bytes=float(cfg.d_model * act_itemsize) / model,
        bcast_every=16, metrics=registry)

    if rate is None:
        arrivals = [0.0] * n_requests
    else:
        arrivals = poisson_arrivals(rate, n_requests / rate, seed=0)[:n_requests]
        arrivals += [n_requests / rate] * (n_requests - len(arrivals))
    reqs = make_requests(arrivals, vocab=cfg.vocab, prompt_len=prompt_len,
                         gen_len=gen_len, slo=SLO(), seed=0)

    t0 = time.monotonic()
    with compat.set_mesh(mesh):
        report = sch.run(reqs)
    dt = time.monotonic() - t0
    gen = np.stack([np.asarray(r.tokens, np.int32)
                    for r in sorted(reqs, key=lambda r: r.rid)])
    s = report.summary()
    log.info(f"{s['n_done']}/{s['n_requests']} done "
             f"({s['n_shed']} shed) in {report.steps} steps / "
             f"{report.now*1e3:.1f} ms simulated; TTFT p50 "
             f"{s['ttft_p50_s']*1e3:.2f} ms p99 {s['ttft_p99_s']*1e3:.2f} ms; "
             f"per-token p50 {s['tpot_p50_s']*1e3:.3f} ms; "
             f"max concurrent {report.max_concurrent}",
             event="report", n_done=s["n_done"], n_shed=s["n_shed"],
             steps=report.steps, simulated_ms=report.now * 1e3,
             ttft_p50_ms=s["ttft_p50_s"] * 1e3,
             ttft_p99_ms=s["ttft_p99_s"] * 1e3,
             tpot_p50_ms=s["tpot_p50_s"] * 1e3,
             max_concurrent=report.max_concurrent)
    if mon is not None:
        snap = mon.snapshot()
        log.info(f"health: {snap['refits']} refit(s), "
                 f"{len(snap['stragglers'])} straggler(s), worst drift "
                 f"{snap['worst_drift']:.3f} over {snap['checks']} checks",
                 event="health", **{k: snap[k] for k in
                                    ("refits", "worst_drift", "checks",
                                     "stragglers", "links")})
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(registry.to_prometheus())
        log.info(f"metrics: {len(registry.names())} series -> {metrics_out}",
                 event="metrics", path=metrics_out,
                 series=len(registry.names()))
    if tracer is not None:
        tracer.save(trace)
        log.info(f"trace: {tracer.n_events()} events -> {trace}",
                 event="trace", path=trace, events=tracer.n_events())
    out = {"generated": gen, "seconds": dt,
           "tokens_per_s": n_requests * gen_len / dt,
           "report": s}
    if mon is not None:
        out["health"] = mon.snapshot()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-100m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh", default="1x2x2")
    ap.add_argument("--policy", default="priority",
                    choices=("fifo", "priority", "slo"))
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop arrival rate (req/s); default: closed batch")
    ap.add_argument("--log-json", action="store_true",
                    help="emit one JSON object per log line instead of the "
                         "human format")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace of the serving run "
                         "(open in chrome://tracing or Perfetto)")
    ap.add_argument("--monitor", action="store_true",
                    help="attach a HealthMonitor to the engine: drift "
                         "detection, straggler scoring, auto-refit, "
                         "periodic health snapshots in the log")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the run's metrics as Prometheus text "
                         "exposition (no tracer needed)")
    args = ap.parse_args()
    set_json(args.log_json)
    out = serve(args.arch, args.requests, args.prompt_len, args.gen_len,
                args.mesh, policy=args.policy, rate=args.rate,
                trace=args.trace, monitor=args.monitor,
                metrics_out=args.metrics_out)
    log.info(f"generated {out['generated'].shape} tokens in "
             f"{out['seconds']:.2f}s ({out['tokens_per_s']:.1f} tok/s)",
             event="done", shape=list(out["generated"].shape),
             seconds=out["seconds"], tokens_per_s=out["tokens_per_s"])
    log.info(f"first request: {out['generated'][0][:16]}",
             event="sample", tokens=out["generated"][0][:16].tolist())


if __name__ == "__main__":
    main()
