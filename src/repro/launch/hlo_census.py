"""Collective census over lowered/compiled HLO text.

cost_analysis() has no collective-byte information, so we parse the HLO:
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes its wire bytes, classified by whether its
replica groups cross a pod boundary (DCN) or stay inside a pod (ICI) under
the row-major (pod, data, model) device flattening.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

__all__ = ["census", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*(?:,|$)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                             r"(?:T\(([\d,]+)\))?")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(txt: str) -> int:
    """Total bytes of the first shape (incl. tuple elements) in ``txt``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size_and_span(line: str, chips_per_pod: int) -> tuple[int, bool]:
    """(participants per group, crosses_pod)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format: [ngroups,group_size]<=[dims](T(perm))
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        # reconstruct the first group's device ids
        total = math.prod(dims)
        tdims = [dims[p] for p in perm]
        ids = []
        for flat in range(total):
            # unindex in transposed space, then map back to linear id
            rem, coord = flat, []
            for d in reversed(tdims):
                coord.append(rem % d)
                rem //= d
            coord = coord[::-1]
            orig = [0] * len(dims)
            for i, p in enumerate(perm):
                orig[p] = coord[i]
            lin = 0
            for i, d in enumerate(dims):
                lin = lin * d + orig[i]
            ids.append(lin)
            if len(ids) >= gsize:
                break
        crosses = len({i // chips_per_pod for i in ids}) > 1
        return gsize, crosses
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        ids = [int(x) for x in first.split(",") if x.strip()]
        crosses = len({i // chips_per_pod for i in ids}) > 1
        return max(len(ids), 1), crosses
    return 1, False


# computation headers sit at column 0: "%name (params...) -> type {"
# (params may contain nested tuple parens, so don't try to match them)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)')
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                      r"[={]*%?([\w.\-]+)")


def _loop_multipliers(hlo_text: str) -> dict[str, int]:
    """computation name -> total dynamic execution count multiplier.

    XLA HLO text prints each while body ONCE; a collective inside a
    scan-over-layers body runs trip_count times per step.  We walk
    computation headers, record which computations are while bodies (and
    their known_trip_count), and propagate multipliers through nesting.
    """
    parent: dict[str, tuple[str, int]] = {}  # comp -> (enclosing comp, trip)
    current = None
    for line in hlo_text.splitlines():
        ls = line.strip()
        mh = _COMP_RE.match(line)  # headers are unindented
        if mh and line[0] not in " \t":
            current = mh.group(1)
            continue
        if current is None:
            continue
        if " while(" in ls:
            trip = _TRIP_RE.search(ls)
            t = int(trip.group(1)) if trip else 1
            for rex in (_WHILE_BODY_RE, _WHILE_COND_RE):
                mb = rex.search(ls)
                if mb:
                    parent[mb.group(1)] = (current, t)
        else:
            for mc in _CALL_RE.finditer(ls):
                parent.setdefault(mc.group(1), (current, 1))

    mult: dict[str, int] = {}

    def total(comp: str, depth=0) -> int:
        if depth > 20 or comp not in parent:
            return 1
        if comp in mult:
            return mult[comp]
        up, t = parent[comp]
        mult[comp] = t * total(up, depth + 1)
        return mult[comp]

    return {c: total(c) for c in set(parent)}


def census(hlo_text: str, chips_per_pod: int) -> dict:
    """PER-CHIP wire bytes by (collective kind, level) + op counts.

    Wire-byte model per participating chip (ring algorithms):
      all-reduce:          2 * bytes * (n-1)/n
      all-gather:          out_bytes * (n-1)/n
      reduce-scatter:      shard_bytes * (n-1)
      all-to-all:          bytes * (n-1)/n
      collective-permute:  bytes

    Collectives inside while loops (scan-over-layers, chunked attention)
    are multiplied by their known trip counts.
    """
    mults = _loop_multipliers(hlo_text)
    out = {"ici_bytes": 0.0, "dcn_bytes": 0.0,
           "counts": defaultdict(int), "ops": []}
    current = None
    for line in hlo_text.splitlines():
        mh = _COMP_RE.match(line)
        if mh and line and line[0] not in " \t":
            current = mh.group(1)
            continue
        ls = line.strip()
        if ls.startswith("ROOT "):
            ls = ls[5:]
        # result may be a TUPLE shape with /*index=N*/ comments (XLA's
        # collective combiner merges many psums into one tuple all-reduce)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s*"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", ls)
        if not m:
            continue
        kind, phase = m.group(2), m.group(3)
        if phase == "-done":  # avoid double counting async pairs
            continue
        nbytes = _shape_bytes(m.group(1))
        n, crosses = _group_size_and_span(ls, chips_per_pod)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            wire = 2.0 * nbytes * frac
        elif kind == "collective-permute":
            wire = float(nbytes)
        elif kind == "reduce-scatter":
            # result shape is the 1/n shard; wire = shard * (n-1)
            wire = nbytes * (n - 1)
        else:
            wire = nbytes * frac
        k = mults.get(current, 1)
        wire *= k
        key = "dcn_bytes" if crosses else "ici_bytes"
        out[key] += wire
        out["counts"][f"{kind}{'/dcn' if crosses else '/ici'}"] += k
        out["ops"].append({"kind": kind, "bytes": nbytes, "group": n,
                           "dcn": crosses, "x": k})
    out["counts"] = dict(out["counts"])
    return out
