import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# Persistent compilation cache makes re-sweeps (perf iterations) cheap.
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves (a) the sharding config is coherent on the
production mesh (16x16 single-pod AND 2x16x16 multi-pod), (b) it fits
memory (memory_analysis), and (c) extracts the roofline terms
(cost_analysis + HLO collective census).

Results accumulate in benchmarks/results/dryrun.json (incremental; safe to
re-run cell by cell).

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--comm multilevel]
"""
import argparse
import json
import sys
import time
import traceback

import numpy as np
import jax

from repro import compat

from repro.configs import get_config, list_archs, SHAPES
from repro.configs.shapes import input_specs, cache_specs, applicable
from repro.core.costmodel import TPU_V5E, roofline_terms
from repro.launch import hlo_census
from repro.launch.mesh import make_production_mesh
from repro.launch import step as STEP
from repro.optim.adamw import OptConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.json")


def _load() -> dict:
    try:
        with open(RESULTS) as f:
            return json.load(f)
    except Exception:
        return {}


def _save(res: dict) -> None:
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    tmp = RESULTS + ".tmp"
    with open(tmp, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
    os.replace(tmp, RESULTS)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               comm_mode: str = "multilevel", zero1: bool = True,
               parallel_block: bool = False):
    """Lower+compile one cell; return the roofline record."""
    import dataclasses
    cfg = get_config(arch)
    if parallel_block:
        cfg = dataclasses.replace(cfg, parallel_block=True)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    chips_per_pod = chips // mesh.shape.get("pod", 1)
    t0 = time.time()

    from repro.optim import adamw
    from repro.models.sharding import param_shardings, batch_pspec
    from jax.sharding import NamedSharding, PartitionSpec as P

    if shape.kind == "train":
        opt_cfg = OptConfig(comm_mode=comm_mode, zero1=zero1)
        raw = STEP.make_train_fn(cfg, opt_cfg, mesh)
        p_sh, o_sh, b_sh = STEP.train_in_shardings(cfg, opt_cfg, mesh)
        aparams = STEP.abstract_params(cfg)
        aopt = jax.eval_shape(
            lambda p: adamw.init_opt_state(
                p, opt_cfg, n_slow=mesh.shape.get("pod", 1)), aparams)
        batch = input_specs(cfg, shape)
        fn = jax.jit(raw, donate_argnums=(0, 1),
                     in_shardings=(p_sh, o_sh,
                                   jax.tree.map(lambda _: b_sh, batch)))
        with compat.set_mesh(mesh):
            lowered = fn.lower(aparams, aopt, batch)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        raw = STEP.make_prefill_fn(cfg, mesh, s_max=shape.seq_len)
        aparams = STEP.abstract_params(cfg)
        p_sh = param_shardings(aparams, mesh)
        b_sh = NamedSharding(mesh, batch_pspec(mesh))
        batch = input_specs(cfg, shape)
        fn = jax.jit(raw,
                     in_shardings=(p_sh, jax.tree.map(lambda _: b_sh, batch)))
        with compat.set_mesh(mesh):
            lowered = fn.lower(aparams, batch)
            compiled = lowered.compile()
    else:  # decode
        raw = STEP.make_decode_fn(cfg, mesh)
        aparams = STEP.abstract_params(cfg)
        p_sh = param_shardings(aparams, mesh)
        acache = cache_specs(cfg, SHAPES[shape_name])
        c_sh = STEP.cache_shardings(cfg, mesh, acache)
        inp = input_specs(cfg, shape)
        tok_sh = NamedSharding(mesh, P("data" if shape.global_batch
                                       % mesh.shape["data"] == 0 else None))
        fn = jax.jit(raw, donate_argnums=(1,),
                     in_shardings=(p_sh, c_sh, tok_sh,
                                   NamedSharding(mesh, P())))
        with compat.set_mesh(mesh):
            lowered = fn.lower(aparams, acache, inp["tokens"],
                               inp["pos"])
            compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cens = hlo_census.census(compiled.as_text(), chips_per_pod)

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(
        hlo_flops=flops, hlo_bytes=bytes_acc,
        ici_bytes=cens["ici_bytes"],     # census bytes are per-chip already
        dcn_bytes=cens["dcn_bytes"],
        chips=chips, hw=TPU_V5E)
    # model flops: 6*N*D for train, 2*N*D for inference (per token)
    cfg_full = cfg
    n_active = cfg_full.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "comm_mode": comm_mode, "zero1": zero1,
        "compile_s": round(compile_s, 1),
        "bytes_per_device": int(mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                - mem.alias_size_in_bytes)
        if hasattr(mem, "temp_size_in_bytes") else str(mem),
        "hlo_gflops": flops / 1e9,
        "hlo_gbytes": bytes_acc / 1e9,
        "ici_mb_per_chip": cens["ici_bytes"] / 1e6,
        "dcn_mb_per_chip": cens["dcn_bytes"] / 1e6,
        "collective_counts": cens["counts"],
        "model_gflops": model_flops / 1e9,
        "useful_flops_frac": model_flops / flops if flops else None,
        **{k: v for k, v in terms.items()},
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--comm", default="multilevel",
                    choices=["flat", "multilevel", "multilevel_compress"])
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--parallel-block", action="store_true",
                    help="PaLM-style parallel residual (perf variant)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default=None, help="results key suffix")
    args = ap.parse_args()

    archs = list_archs()[:10] if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    res = _load()
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}|{args.comm}" \
                      + (f"|{args.tag}" if args.tag else "")
                if key in res and "error" not in res[key]:
                    print(f"SKIP (cached) {key}")
                    continue
                print(f"RUN {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, args.comm,
                                     zero1=not args.no_zero1,
                                     parallel_block=args.parallel_block)
                    rec["tag"] = args.tag
                    res[key] = rec
                    msg = rec.get("skipped") or (
                        f"ok compile={rec['compile_s']}s "
                        f"bound={rec.get('bound')} step={rec.get('step_s'):.4f}s")
                    print(f"  -> {msg}", flush=True)
                except Exception as e:
                    failures += 1
                    res[key] = {"error": f"{type(e).__name__}: {e}",
                                "trace": traceback.format_exc()[-2000:]}
                    print(f"  -> FAIL {type(e).__name__}: {e}", flush=True)
                _save(res)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
