"""Step builders: jitted train / prefill / decode steps for any (arch, mesh).

train_step: partial-manual shard_map — MANUAL over the data-parallel axes
(`pod`, `data`) so the paper's multilevel gradient collective is explicit in
the lowered HLO, AUTO (GSPMD) over `model` so tensor-parallel sharding is
propagated by XLA.

serve steps: pure GSPMD jit with sharding constraints (no dp gradient sync
to decompose); decode KV caches shard batch over `data` and the cache
sequence dim over `model` (flash-decode style).
"""
from __future__ import annotations

import functools
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.models import transformer as T
from repro.models import sharding as SH
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.configs.shapes import ShapeSpec, AUDIO_SRC_FRACTION

__all__ = ["model_dims_of", "make_train_step", "make_prefill_step",
           "make_decode_step", "make_paged_decode_step", "train_in_shardings",
           "cache_shardings", "paged_pool_shardings", "abstract_params",
           "layer_grad_bytes"]


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))


def layer_grad_bytes(cfg: ModelConfig, model_size: int = 1) -> list[float]:
    """Per-layer gradient wire bytes (f32 sync) in FORWARD order.

    Backward produces gradients for these entries last-to-first, which is
    exactly the issue order of the engine's bucketed gradient sync — feed
    this list to :func:`repro.core.engine.overlapped_step_times` (the
    train driver's overlap estimate and ``benchmarks/bench_engine.py`` do).
    Entry 0 aggregates the non-layer leaves (embedding/head/norms): their
    gradients arrive at the very end of backward.  ``model_size`` divides
    out the tensor-parallel shard — the sync moves 1/model_size of the
    bytes per model slice.
    """
    aparams = abstract_params(cfg)
    runs = aparams.get("runs", [])
    run_bytes = 0.0
    layers: list[float] = []
    for (kind, n), run in zip(cfg.runs(), runs):
        rb = 4.0 * sum(l.size for l in jax.tree.leaves(run))
        run_bytes += rb
        layers.extend([rb / n] * n)
    total = 4.0 * sum(l.size for l in jax.tree.leaves(aparams))
    return [(total - run_bytes) / model_size] + [b / model_size
                                                for b in layers]


def model_dims_of(params: Any, model_size: int) -> Any:
    """Tree of ints: which dim of each leaf is model-sharded (-1 if none)."""
    specs = SH.param_pspecs(params, model_size)

    def dim(spec):
        for i, s in enumerate(spec):
            if s == "model":
                return i
        return -1

    return jax.tree.map(dim, specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------- #
# Train
# ---------------------------------------------------------------------- #

def make_train_fn(cfg: ModelConfig, opt_cfg: adamw.OptConfig, mesh,
                  comm=None):
    """The raw (un-jitted) shard_map'd train step.

    Structure: OUTER shard_map manual over the dp axes (pod, data) with the
    model axis auto (GSPMD propagates tensor-parallel shardings through the
    fwd/bwd); an INNER shard_map makes `model` manual too for the gradient
    sync + optimizer, because a manual-axis collective on an auto-sharded
    operand makes the partitioner all-gather the auto axis first (measured:
    +52 GB/chip ICI on qwen3 train before this nesting).

    ``comm``: the mesh's :class:`repro.core.Communicator` (jax backend); the
    gradient sync decomposes over its (slow_axis, fast_axes).  Built from the
    mesh when omitted."""
    from repro.launch.mesh import mesh_communicator

    if comm is None:
        comm = mesh_communicator(mesh, backend="jax")
    dp = SH.dp_axes(mesh)                       # ("pod","data") or ("data",)
    slow = comm.slow_axis
    data_size = mesh.shape["data"]
    model_size = mesh.shape.get("model", 1)
    dp_degree = int(np.prod([mesh.shape[a] for a in dp]))

    aparams = abstract_params(cfg)
    mdims = model_dims_of(aparams, model_size)
    opt_specs = adamw.opt_manual_specs(aparams, opt_cfg, data_size, mdims,
                                       slow_axis=slow)
    pspecs = SH.param_pspecs(aparams, model_size)  # model-axis specs
    opt_inner = {"m": pspecs, "v": pspecs, "master": pspecs, "step": P()}
    if opt_cfg.error_feedback:
        # ef leaves carry a leading slow-axis dim ahead of the param dims
        opt_inner["ef"] = jax.tree.map(lambda s: P(None, *s), pspecs,
                                       is_leaf=lambda x: isinstance(x, P))
    model_axis = "model" if model_size > 1 else None

    def update(p_, g_, o_):
        return adamw.apply_updates(
            p_, g_, o_, opt_cfg, slow, data_size, dp_degree, mdims,
            model_axis=model_axis)

    if model_axis:
        # nested shard_map: mesh inferred from the enclosing manual context
        update = shard_map(update,
                           in_specs=(pspecs, pspecs, opt_inner),
                           out_specs=(pspecs, opt_inner),
                           axis_names={"model"}, check_vma=False)

    def step(params, opt, batch):
        loss_val, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch))(params)
        new_params, new_opt = update(params, grads, opt)
        return new_params, new_opt, lax.pmean(loss_val, dp)

    batch_spec = P(dp)
    return shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), opt_specs, batch_spec),
        out_specs=(P(), opt_specs, P()),
        axis_names=set(dp),
        check_vma=False,
    )


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig, mesh):
    return jax.jit(make_train_fn(cfg, opt_cfg, mesh), donate_argnums=(0, 1))


def train_in_shardings(cfg: ModelConfig, opt_cfg: adamw.OptConfig, mesh):
    """jit-level in_shardings for (params, opt, batch) — used by the dry-run
    to .lower() from ShapeDtypeStructs with pinned layouts."""
    aparams = abstract_params(cfg)
    model_size = mesh.shape.get("model", 1)
    pspecs = SH.param_pspecs(aparams, model_size)
    mdims = model_dims_of(aparams, model_size)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    axes = adamw.scatter_axes(aparams, mesh.shape["data"], mdims)

    def combined(spec, ax, leaf):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        if ax is not None and dims[ax] is None:
            dims[ax] = "data"
        return NamedSharding(mesh, P(*dims))

    scattered = jax.tree.map(combined, pspecs, axes, aparams,
                             is_leaf=lambda x: isinstance(x, P))
    ms = scattered if opt_cfg.sharded_state else param_sh
    opt_sh = {"m": ms, "v": ms, "master": ms,
              "step": NamedSharding(mesh, P())}
    if opt_cfg.error_feedback:
        # per-(pod, data)-shard residual even in dense mode: leading dim
        # over the slow axis, scatter dim over 'data'.  The shapes are the
        # leaf shapes regardless of opt_cfg.quant_kernel — the fused Pallas
        # quantiser pads its own input to QTILE internally, so the fused-EF
        # buffer needs no extra sharded storage here.
        slow = "pod" if "pod" in mesh.shape else None

        def ef_sharding(spec, ax, leaf):
            dims = list(spec) + [None] * (leaf.ndim - len(spec))
            if ax is not None and dims[ax] is None:
                dims[ax] = "data"
            return NamedSharding(mesh, P(slow, *dims))

        opt_sh["ef"] = jax.tree.map(ef_sharding, pspecs, axes, aparams,
                                    is_leaf=lambda x: isinstance(x, P))
    batch_sh = NamedSharding(mesh, SH.batch_pspec(mesh))
    return param_sh, opt_sh, batch_sh


# ---------------------------------------------------------------------- #
# Serve
# ---------------------------------------------------------------------- #

def _maybe(axis: str, size: int, div: int):
    return axis if size % div == 0 and div > 1 else None


def cache_shardings(cfg: ModelConfig, mesh, cache_abstract) -> Any:
    """Batch over `data`, cache sequence dim over `model` (flash-decode),
    recurrent channel dims over `model`."""
    dsz = mesh.shape.get("data", 1)
    msz = mesh.shape.get("model", 1)

    def spec_for(path, leaf):
        name = ""
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        shp = leaf.shape  # (run, B, ...)
        b_ax = _maybe("data", shp[1], dsz)
        if name in ("k", "v", "xk", "xv"):
            s_ax = _maybe("model", shp[2], msz)
            return NamedSharding(mesh, P(None, b_ax, s_ax, None, None))
        if name == "h":
            return NamedSharding(mesh, P(None, b_ax, _maybe("model", shp[2], msz)))
        if name == "conv":
            return NamedSharding(mesh, P(None, b_ax, None, _maybe("model", shp[3], msz)))
        if name == "S":
            return NamedSharding(mesh, P(None, b_ax, _maybe("model", shp[2], msz), None, None))
        if name in ("x_tm", "x_cm"):
            return NamedSharding(mesh, P(None, b_ax, _maybe("model", shp[2], msz)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec_for, cache_abstract)


def make_prefill_fn(cfg: ModelConfig, mesh, s_max: int):
    def run(params, inputs):
        return T.prefill(params, cfg, inputs, s_max)
    return run


def make_prefill_step(cfg: ModelConfig, mesh, s_max: int):
    return jax.jit(make_prefill_fn(cfg, mesh, s_max))


def make_decode_fn(cfg: ModelConfig, mesh):
    def run(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos)
    return run


def make_decode_step(cfg: ModelConfig, mesh):
    return jax.jit(make_decode_fn(cfg, mesh), donate_argnums=(1,))


def paged_pool_shardings(cfg: ModelConfig, mesh, pools_abstract) -> Any:
    """Paged pools have no batch dim — any request's blocks live anywhere in
    the shared pool — so the only safe static partition is over the KV-head
    dim (model axis), mirroring tensor-parallel attention."""
    msz = mesh.shape.get("model", 1)

    def spec_for(leaf):
        h_ax = _maybe("model", leaf.shape[3], msz)
        return NamedSharding(mesh, P(None, None, None, h_ax, None))

    return jax.tree.map(spec_for, pools_abstract)


def make_paged_decode_fn(cfg: ModelConfig, mesh):
    def run(params, pools, block_tables, tokens, pos):
        return T.decode_step_paged(params, cfg, pools, block_tables,
                                   tokens, pos)
    return run


def make_paged_decode_step(cfg: ModelConfig, mesh):
    """Jitted paged decode step; the pool buffers are donated so the
    fixed-size cache is updated in place across steps."""
    return jax.jit(make_paged_decode_fn(cfg, mesh), donate_argnums=(1,))
