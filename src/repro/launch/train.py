"""Training driver: checkpoint/restart, elastic recovery, straggler
mitigation — the control plane the dry-run's data plane plugs into.

Usage (CPU demo, also the e2e example driver):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch gpt-100m --steps 200 \\
      --mesh 1x2x2 --seq 128 --batch 8 --comm multilevel

On a real fleet the same driver runs under ``jax.distributed.initialize``
with the production mesh from launch/mesh.py; nothing in the loop is
CPU-specific.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataPipeline
from repro.launch import step as STEP
from repro.launch.mesh import (make_test_mesh, make_production_mesh,
                               mesh_communicator)
from repro.models import transformer as T
from repro.obs import Tracer, get_logger, set_json
from repro.optim.adamw import OptConfig, init_opt_state
from repro.runtime.fault_tolerance import (FailureInjector, StragglerMonitor,
                                           plan_recovery, pod_member_ranks)

log = get_logger("train")


def build_mesh(spec: str):
    if spec == "production":
        return make_production_mesh(multi_pod=True)
    pods, data, model = (int(x) for x in spec.split("x"))
    return make_test_mesh(pods, data, model)


def _fit_ef(opt_tree: dict, lost_pods, new_pods: int) -> dict:
    """Fit the EF residual's leading pod dim after an elastic mesh change:
    surviving pods keep their own rows (their residuals are still the
    rounding error of the shard they exchange); any other mismatch resets
    to zeros (EF re-warms in one step)."""
    if "ef" not in opt_tree:
        return opt_tree
    lost = set(lost_pods)

    def fit(e):
        if e.shape[0] == new_pods:
            return e
        keep = [p for p in range(e.shape[0]) if p not in lost]
        if len(keep) == new_pods:
            return np.asarray(e)[keep]
        return np.zeros((new_pods,) + e.shape[1:], e.dtype)

    return dict(opt_tree, ef=jax.tree.map(fit, opt_tree["ef"]))


def _fit_batch(arr: np.ndarray, dp: int) -> np.ndarray:
    """Fit a host batch to a (possibly shrunk) dp degree: drop the tail
    rows that no longer tile (the lost pod's share — the straggler-drop
    semantics, the mean renormalises), or wrap-pad tiny batches up."""
    b = arr.shape[0]
    n = (b // dp) * dp
    if n == b:
        return arr
    if n == 0:
        reps = -(-dp // b)
        return np.concatenate([arr] * reps, axis=0)[:dp]
    return arr[:n]


def train(arch: str, steps: int, mesh_spec: str, seq: int, batch: int,
          comm: str, zero1: bool, ckpt_dir: str, ckpt_every: int,
          fail_at: dict[int, list[int]] | None = None,
          smoke: bool = True, log_every: int = 10,
          bucket_mb: float = 0.0, trace: str | None = None) -> dict:
    """Returns summary metrics; restarts from the latest checkpoint if one
    exists (crash-consistent resume).

    ``bucket_mb`` > 0 switches the gradient sync to size-targeted buckets
    (reverse-layer order, one fused collective per bucket — overlappable
    with backward); forces the dense optimizer state since ZeRO-1 scatters
    per leaf.  ``trace`` writes a Chrome trace of the simulated planning
    plane (per-link occupancy, planner decisions) to that path."""
    cfg = get_config(arch, smoke=smoke)
    shape = ShapeSpec("custom", "train", seq, batch)
    mesh = build_mesh(mesh_spec)
    bucket_bytes = bucket_mb * 2 ** 20 if bucket_mb > 0 else None
    tracer = Tracer() if trace else None
    if bucket_bytes and zero1 and comm != "flat":
        log.info("bucketed sync: forcing zero1=False (ZeRO-1 "
                 "scatters per leaf)", event="config")
        zero1 = False
    opt_cfg = OptConfig(comm_mode=comm, zero1=zero1, lr=1e-3,
                        warmup_steps=20, total_steps=steps,
                        bucket_bytes=bucket_bytes)
    injector = FailureInjector(fail_at or {})
    straggler = StragglerMonitor()
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    pipe = DataPipeline(cfg, shape)
    losses: list[float] = []
    recoveries = 0
    repairs = 0

    # the planning/estimation plane outlives mesh rebuilds: on an in-place
    # recovery the SAME communicator is repaired (members shrink, cached
    # plans splice out the dead ranks) instead of being re-created
    from repro.core import Communicator
    from repro.launch.mesh import dp_topology
    sim = Communicator(dp_topology(mesh), policy="paper", backend="sim",
                       tracer=tracer)

    def setup(mesh):
        # the single topology-aware entry point: gradient sync decomposes
        # over the communicator's (slow, fast) mesh axes
        mcomm = mesh_communicator(mesh, backend="jax")
        # estimate over the dp ranks only, with each model slice's share of
        # the gradient (the sync moves 1/model_size of the bytes per slice)
        lbytes = STEP.layer_grad_bytes(cfg, mesh.shape.get("model", 1))
        slice_bytes = sum(lbytes)
        est_s = sim.allreduce(slice_bytes).time
        crossings = sim.slow_crossings('allreduce', nbytes=slice_bytes)
        log.info(f"{mcomm.describe()}; grad sync mode '{comm}': "
                 f"est {est_s*1e3:.1f} ms/step, "
                 f"{crossings} slow-link crossing(s)",
                 event="setup", mode=comm, est_ms=est_s * 1e3,
                 slow_crossings=crossings)
        if bucket_bytes:
            # overlapped-sync estimate through the async engine, at the
            # communication-bound threshold (backward compute ~ sync time,
            # spread over layers by gradient size)
            from repro.core.engine import overlapped_step_times
            t_comm = sim.allreduce(slice_bytes).time
            est = overlapped_step_times(
                sim, lbytes,
                [t_comm * b / slice_bytes for b in lbytes],
                bucket_bytes=bucket_bytes)
            log.info(f"bucketed sync ({bucket_mb:g} MiB x "
                     f"{est['n_buckets']} buckets): overlapped est "
                     f"{est['overlapped_s']*1e3:.1f} ms/step vs serial "
                     f"{est['serial_s']*1e3:.1f} ms "
                     f"({est['speedup']:.2f}x, balanced-compute model)",
                     event="bucketed_estimate",
                     n_buckets=est["n_buckets"],
                     overlapped_ms=est["overlapped_s"] * 1e3,
                     serial_ms=est["serial_s"] * 1e3,
                     speedup=est["speedup"])
        fn = jax.jit(STEP.make_train_fn(cfg, opt_cfg, mesh, comm=mcomm),
                     donate_argnums=(0, 1))
        p_sh, o_sh, b_sh = STEP.train_in_shardings(cfg, opt_cfg, mesh)
        return fn, p_sh, o_sh, b_sh

    fn, p_sh, o_sh, b_sh = setup(mesh)
    params_host = jax.tree.map(np.asarray,
                               T.init_model(jax.random.PRNGKey(0), cfg))
    opt_host = jax.tree.map(np.asarray, init_opt_state(
        params_host, opt_cfg, n_slow=mesh.shape.get("pod", 1)))

    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(latest, {"params": params_host, "opt": opt_host})
        params_host, opt_host = state["params"], state["opt"]
        start = latest + 1
        log.info(f"resumed from checkpoint step {latest}",
                 event="resume", step=latest)

    params = jax.device_put(params_host, p_sh)
    opt = jax.device_put(opt_host, o_sh)

    step_i = start
    accum = 1
    orig_dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    while step_i < steps:
        t0 = time.monotonic()
        # ---- failure injection / elastic recovery --------------------- #
        failed = injector.failed_pods_at(step_i)
        if failed:
            plan = plan_recovery(tuple(mesh.shape.values()),
                                 tuple(mesh.shape.keys()), failed)
            # current-mesh dp ranks of the lost pods, translated to the
            # ORIGINAL rank ids the planning communicator still speaks
            # (its members list is the order-preserved survivor list)
            dead = [sim.members[r] for r in
                    pod_member_ranks(plan.old_shape, plan.axis_names,
                                     list(plan.lost_pods))
                    if r < len(sim.members)]
            in_place = plan.changed and sim.has_quorum(dead)
            log.info(f"step {step_i}: pods {failed} failed -> "
                     f"mesh {plan.old_shape} -> {plan.new_shape}, "
                     f"accum x{plan.accum_factor} "
                     f"({'in-place repair' if in_place else 'restart'})",
                     event="failure", step=step_i, failed=list(failed),
                     accum=plan.accum_factor, in_place=in_place)
            if plan.changed and plan.new_shape[0] >= 1:
                mesh = build_mesh("x".join(map(str, plan.new_shape))
                                  if len(plan.new_shape) == 3 else mesh_spec)
                if in_place:
                    rep = sim.repair(failed=dead)
                    repairs += 1
                    log.info(f"repair: {rep.repaired} plan(s) spliced "
                             f"in place, {rep.evicted} evicted, {rep.kept} "
                             f"kept; {len(rep.members)} dp rank(s) remain",
                             event="repair", step=step_i,
                             repaired=rep.repaired, evicted=rep.evicted,
                             kept=rep.kept, survivors=len(rep.members))
                else:
                    # full restart: the old membership (and its rank
                    # translation) is void — re-plan on the new mesh
                    sim = Communicator(dp_topology(mesh), policy="paper",
                                       backend="sim")
                fn, p_sh, o_sh, b_sh = setup(mesh)
                accum = plan.accum_factor
            # quorum held: carry the LIVE state onto the shrunk mesh — no
            # checkpoint rewind, no step replay.  Below quorum: restore
            # from the last durable checkpoint (live-carry only as the
            # no-checkpoint-yet fallback).
            carry_live = in_place
            n_pods = mesh.shape.get("pod", 1)
            if not in_place:
                recoveries += 1
                latest = ckpt.latest_step()
                if latest is not None:
                    ckpt.wait()
                    state = ckpt.restore(
                        latest, {"params": params_host, "opt": opt_host})
                    params = jax.device_put(state["params"], p_sh)
                    opt = jax.device_put(
                        _fit_ef(state["opt"], plan.lost_pods, n_pods), o_sh)
                    step_i = latest + 1
                    continue
                carry_live = plan.changed
            if carry_live:
                params = jax.device_put(jax.tree.map(np.asarray, params), p_sh)
                opt = jax.device_put(
                    _fit_ef(jax.tree.map(np.asarray, opt),
                            plan.lost_pods, n_pods), o_sh)

        # ---- the actual step (with grad accumulation on shrunk mesh) -- #
        loss_acc = 0.0
        dp_deg = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
        for micro in range(accum):
            hb = pipe.host_batch(step_i * accum + micro)
            # batch fitting is ELASTIC-only: a shrunk dp degree may stop
            # tiling the configured batch; a healthy run keeps the loud
            # device_put error on a misconfigured batch
            fit = ((lambda v: _fit_batch(np.asarray(v), dp_deg))
                   if dp_deg != orig_dp else np.asarray)
            gb = {k: jax.device_put(fit(v), b_sh) for k, v in hb.items()}
            params, opt, loss = fn(params, opt, gb)
            loss_acc += float(loss)
        losses.append(loss_acc / accum)

        dt = time.monotonic() - t0
        if straggler.observe(step_i, dt):
            log.info(f"step {step_i}: straggler ({dt:.2f}s vs median "
                     f"{straggler.median:.2f}s) — bounded-staleness drop "
                     f"logged", event="straggler", step=step_i, dt_s=dt,
                     median_s=straggler.median)
        if ckpt_every and step_i % ckpt_every == 0 and step_i > start:
            params_host = jax.tree.map(np.asarray, params)
            opt_host = jax.tree.map(np.asarray, opt)
            ckpt.save(step_i, {"params": params_host, "opt": opt_host})
        if step_i % log_every == 0:
            log.info(f"step {step_i:5d} loss {losses[-1]:.4f} "
                     f"({dt*1e3:.0f} ms)", event="step", step=step_i,
                     loss=losses[-1], dt_ms=dt * 1e3)
        step_i += 1

    ckpt.wait()
    if tracer is not None:
        tracer.save(trace)
        log.info(f"trace: {tracer.n_events()} events -> {trace}",
                 event="trace", path=trace, events=tracer.n_events())
    return {"losses": losses, "recoveries": recoveries,
            "repairs": repairs,
            "stragglers": len(straggler.dropped_steps),
            "final_loss": losses[-1] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="1x2x2")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--comm", default="multilevel",
                    choices=["flat", "multilevel", "multilevel_compress"])
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-smoke) architecture config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--bucket-mb", type=float, default=0.0,
                    help="size-targeted gradient buckets (MiB); 0 = one "
                         "monolithic sync")
    ap.add_argument("--log-json", action="store_true",
                    help="emit one JSON object per log line instead of the "
                         "human format")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace of the planning plane "
                         "(open in chrome://tracing or Perfetto)")
    args = ap.parse_args()
    set_json(args.log_json)
    out = train(args.arch, args.steps, args.mesh, args.seq, args.batch,
                args.comm, not args.no_zero1, args.ckpt_dir, args.ckpt_every,
                smoke=not args.full_config, bucket_mb=args.bucket_mb,
                trace=args.trace)
    log.info(f"done: final_loss={out['final_loss']:.4f} "
             f"recoveries={out['recoveries']} repairs={out['repairs']} "
             f"stragglers={out['stragglers']}",
             event="done", final_loss=out["final_loss"],
             recoveries=out["recoveries"], repairs=out["repairs"],
             stragglers=out["stragglers"])


if __name__ == "__main__":
    main()
