"""Pallas TPU flash-attention kernel (forward).

TPU-native adaptation of the flash algorithm: BlockSpec-tiled VMEM staging,
MXU-aligned (multiple-of-128) q/k blocks, grid (batch*kv_heads, q_blocks,
kv_blocks) with the kv dimension marked "arbitrary" so the online-softmax
accumulator lives in VMEM scratch across kv steps.

GQA layout: q is (B*Hkv, G*bq, hd) blocks against k/v (B*Hkv, bk, hd) — the
query-group dim rides inside the q block so one k/v VMEM stage serves all G
query heads of its group (cuts k/v HBM traffic by G).

Validated on CPU via interpret=True against ``ref.mha_reference``; the
backward pass on TPU reuses the jnp custom-VJP from
``repro.models.layers`` (same blockwise-recompute algorithm).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_k: int, groups: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G_, bq, hd = q_ref.shape[1:]
    q = q_ref[0].astype(jnp.float32).reshape(G_ * bq, hd)   # (G*bq, hd)
    k = k_ref[0].astype(jnp.float32)                        # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # positions: row r of the q block is query (qi*bq + r % bq) of group r//bq
    r = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    q_pos = qi * block_q + jax.lax.rem(r, block_q)
    k_pos = ki * block_k + c
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = o.reshape(G_, bq, hd).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,          # (B, Sq, H, hd)
    k: jax.Array,          # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q != 0 or Sk % block_k != 0:
        raise ValueError(f"flash attention blocks must tile the "
                         f"sequence: Sq={Sq} Sk={Sk} "
                         f"block_q={block_q} block_k={block_k}")
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)

    # (B,S,H,hd) -> (B*Hkv, G*Sq', hd) with q grouped per kv head
    qg = (q.reshape(B, Sq, Hkv, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * Hkv, G, Sq, hd))
    kg = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)

    grid = (B * Hkv, n_q, n_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k, groups=G),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q,), jnp.float32),   # running max m
            pltpu.VMEM((G * block_q,), jnp.float32),   # running sum l
            pltpu.VMEM((G * block_q, hd), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(qg, kg, vg)
    # (B*Hkv, G, Sq, hd) -> (B, Sq, H, hd)
    out = out.reshape(B, Hkv, G, Sq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, hd)
