"""Pallas TPU flash-attention kernels (forward AND backward).

TPU-native adaptation of the flash algorithm: BlockSpec-tiled VMEM staging,
MXU-aligned (multiple-of-128) q/k blocks, grid (batch*kv_heads, q_blocks,
kv_blocks) with the innermost dimension "arbitrary" so accumulators live in
VMEM scratch across its steps.

GQA layout (shared by forward and backward): q is (B*Hkv, G*bq, hd) blocks
against k/v (B*Hkv, bk, hd) — the query-group dim rides inside the q block
so one k/v VMEM stage serves all G query heads of its group (cuts k/v HBM
traffic by G).

Backward = blockwise recompute (no S x S buffer):
  delta_i = rowsum(do_i * o_i)                       (precomputed, tiny)
  p_ij    = exp(s_ij - lse_i)     where s = qk^T * scale, masked
  dv_j   += p^T do ;  ds = p * (dp - delta) * scale  with dp = do v^T
  dq_i   += ds k   ;  dk_j += ds^T q
split over two kernels so each accumulator matches its grid order: dq
iterates kv innermost (grid b, i, j), dk/dv iterate q innermost (grid
b, j, i).  ``flash_attention`` wires both into a jax.custom_vjp, which
``models.layers.chunked_attention`` dispatches to on TPU — the jnp
custom-VJP there remains the CPU lowering and the numerical oracle.

Validated on CPU via interpret mode against ``ref.mha_reference`` and the
jnp VJP (see tests/test_kernels.py); ``interpret=None`` auto-detects the
backend (``repro.kernels.backend``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _block_mask(s_shape, qi, ki, *, causal, window, block_q, block_k,
                q_offset):
    """Boolean keep-mask for one (q block, k block) tile of scores.

    Row r of the flattened (G*bq, bk) tile is query ``qi*bq + r % bq`` of
    group ``r // bq``; ``q_offset`` shifts query positions (decode /
    continuation chunks)."""
    r = jax.lax.broadcasted_iota(jnp.int32, s_shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, s_shape, 1)
    q_pos = q_offset + qi * block_q + jax.lax.rem(r, block_q)
    k_pos = ki * block_k + c
    mask = jnp.ones(s_shape, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    return mask


# ---------------------------------------------------------------------- #
# Forward
# ---------------------------------------------------------------------- #

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_k: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G_, bq, hd = q_ref.shape[1:]
    q = q_ref[0].astype(jnp.float32).reshape(G_ * bq, hd)   # (G*bq, hd)
    k = k_ref[0].astype(jnp.float32)                        # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(s.shape, qi, ki, causal=causal, window=window,
                       block_q=block_q, block_k=block_k, q_offset=q_offset)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / l[:, None]
        o_ref[0] = o.reshape(G_, bq, hd).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l)).reshape(G_, bq)


def _fold_gqa(q, k, v):
    """(B,S,H,hd) tensors -> grouped (B*Hkv, G, Sq, hd) / (B*Hkv, Sk, hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = (q.reshape(B, Sq, Hkv, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * Hkv, G, Sq, hd))
    kg = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    return qg, kg, vg


def _check_blocks(Sq, Sk, block_q, block_k):
    block_q, block_k = min(block_q, Sq), min(block_k, Sk)
    if Sq % block_q != 0 or Sk % block_k != 0:
        raise ValueError(f"flash attention blocks must tile the "
                         f"sequence: Sq={Sq} Sk={Sk} "
                         f"block_q={block_q} block_k={block_k}")
    return block_q, block_k


def flash_attention_fwd(
    q: jax.Array,          # (B, Sq, H, hd)
    k: jax.Array,          # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    q_offset: int = 0,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (o: (B,Sq,H,hd), lse: (B,Hkv,G,Sq) f32) — the lse layout of
    ``models.layers._flash_fwd_impl``, consumed by the backward kernels."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_q, block_k = _check_blocks(Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)
    qg, kg, vg = _fold_gqa(q, k, v)

    grid = (B * Hkv, n_q, n_k)
    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, G, block_q), lambda b, i, j: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, G, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B * Hkv, G, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * block_q,), jnp.float32),   # running max m
            pltpu.VMEM((G * block_q,), jnp.float32),   # running sum l
            pltpu.VMEM((G * block_q, hd), jnp.float32),  # accumulator
        ],
        interpret=resolve_interpret(interpret),
    )(qg, kg, vg)
    # (B*Hkv, G, Sq, hd) -> (B, Sq, H, hd)
    out = out.reshape(B, Hkv, G, Sq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, hd), lse.reshape(B, Hkv, G, Sq)


# ---------------------------------------------------------------------- #
# Backward
# ---------------------------------------------------------------------- #

def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                         dq_ref, acc_ref, *, scale: float, causal: bool,
                         window: int | None, block_q: int, block_k: int,
                         n_k: int, q_offset: int):
    """dq: grid (B*Hkv, n_q, n_k) — kv innermost, dq accumulator in VMEM."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    G_, bq, hd = q_ref.shape[1:]
    q = q_ref[0].astype(jnp.float32).reshape(G_ * bq, hd)
    do = do_ref[0].astype(jnp.float32).reshape(G_ * bq, hd)
    lse = lse_ref[0].reshape(G_ * bq)
    delta = dl_ref[0].reshape(G_ * bq)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(s.shape, qi, ki, causal=causal, window=window,
                       block_q=block_q, block_k=block_k, q_offset=q_offset)
    # explicit mask (not NEG_INF arithmetic): a fully-masked row has
    # lse ~ NEG_INF and exp(s - lse) would blow up to 1, not 0
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    acc_ref[...] = acc_ref[...] + jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        dq_ref[0] = acc_ref[...].reshape(G_, bq, hd)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                          causal: bool, window: int | None, block_q: int,
                          block_k: int, n_q: int, q_offset: int):
    """dk/dv: grid (B*Hkv, n_k, n_q) — q innermost, dk/dv scratch in VMEM."""
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    G_, bq, hd = q_ref.shape[1:]
    q = q_ref[0].astype(jnp.float32).reshape(G_ * bq, hd)
    do = do_ref[0].astype(jnp.float32).reshape(G_ * bq, hd)
    lse = lse_ref[0].reshape(G_ * bq)
    delta = dl_ref[0].reshape(G_ * bq)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _block_mask(s.shape, qi, ki, causal=causal, window=window,
                       block_q=block_q, block_k=block_k, q_offset=q_offset)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    # dv += p^T do  — contract the G*bq query dim
    dv_acc[...] = dv_acc[...] + jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    dk_acc[...] = dk_acc[...] + jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


def flash_attention_bwd(
    q: jax.Array, k: jax.Array, v: jax.Array,
    o: jax.Array, lse: jax.Array, do: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    q_offset: int = 0,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blockwise-recompute backward.  ``lse``: (B,Hkv,G,Sq) f32 from
    :func:`flash_attention_fwd`.  Returns (dq, dk, dv) in the input dtypes."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    block_q, block_k = _check_blocks(Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)
    interpret = resolve_interpret(interpret)

    qg, kg, vg = _fold_gqa(q, k, v)
    dog, _, _ = _fold_gqa(do, k, v)
    og, _, _ = _fold_gqa(o, k, v)
    lseg = lse.reshape(B * Hkv, G, Sq)
    # delta_i = rowsum(do_i * o_i): O(S*hd), cheap enough to precompute
    delta = jnp.einsum("bgsd,bgsd->bgs", dog.astype(jnp.float32),
                       og.astype(jnp.float32))

    kw = dict(scale=scale, causal=causal, window=window, block_q=block_q,
              block_k=block_k, q_offset=q_offset)
    q_spec = pl.BlockSpec((1, G, block_q, hd), lambda b, i, j: (b, 0, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, G, block_q), lambda b, i, j: (b, 0, i))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, n_k=n_k, **kw),
        grid=(B * Hkv, n_q, n_k),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, G, block_q, hd),
                               lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, Sq, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((G * block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qg, kg, vg, dog, lseg, delta)

    # dkv grid swaps the loop order: index maps see (b, j, i)
    q_spec_t = pl.BlockSpec((1, G, block_q, hd), lambda b, j, i: (b, 0, i, 0))
    kv_spec_t = pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0))
    row_spec_t = pl.BlockSpec((1, G, block_q), lambda b, j, i: (b, 0, i))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, n_q=n_q, **kw),
        grid=(B * Hkv, n_k, n_q),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
                   pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * Hkv, Sk, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B * Hkv, Sk, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=interpret,
    )(qg, kg, vg, dog, lseg, delta)

    dq = (dq.reshape(B, Hkv, G, Sq, hd).transpose(0, 3, 1, 2, 4)
          .reshape(B, Sq, H, hd).astype(q.dtype))
    dk = (dk.reshape(B, Hkv, Sk, hd).transpose(0, 2, 1, 3)
          .astype(k.dtype))
    dv = (dv.reshape(B, Hkv, Sk, hd).transpose(0, 2, 1, 3)
          .astype(v.dtype))
    return dq, dk, dv


# ---------------------------------------------------------------------- #
# Differentiable entry point
# ---------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal, window, block_q, block_k, q_offset,
                    interpret):
    """Differentiable flash attention: Pallas forward AND backward.
    Positional statics (custom_vjp nondiff args); use the keyword wrapper
    ``repro.kernels.ops.flash_attention`` from user code."""
    o, _ = flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               q_offset=q_offset, interpret=interpret)
    return o


def _fa_vjp_fwd(q, k, v, causal, window, block_q, block_k, q_offset,
                interpret):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 q_offset=q_offset, interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_vjp_bwd(causal, window, block_q, block_k, q_offset, interpret,
                res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, q_offset=q_offset,
                               interpret=interpret)


flash_attention.defvjp(_fa_vjp_fwd, _fa_vjp_bwd)
