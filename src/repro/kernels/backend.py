"""Backend detection shared by every Pallas kernel entry point.

All kernels in this package take ``interpret: bool | None = None``:
``None`` resolves at call time to "interpret off-TPU" — CPU/GPU (this
container, CI) execute the kernels through the Pallas interpreter, a real
TPU compiles them — while an explicit bool always wins, so tests can force
either path and a TPU run can still drop to interpret mode for debugging.
"""
from __future__ import annotations

import jax

__all__ = ["on_tpu", "resolve_interpret"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """None -> auto (interpret unless running on TPU); bools pass through."""
    return (not on_tpu()) if interpret is None else bool(interpret)
