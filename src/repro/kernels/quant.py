"""Pallas TPU kernels: blockwise symmetric int8 quantise / dequantise, and
the FUSED quantise + error-feedback residual update.

Used by the slow-link (DCN) gradient compressor — the perf-critical inner
loop of the paper-inspired topology-aware compression: gradients cross the
pod boundary as int8 + per-block f32 scales (~0.26x of f32 wire bytes).

``quantize_ef_int8`` computes ``q``, ``scales`` AND the new EF residual
``(x+ef) - dequant(q)`` in one VMEM pass: the two-pass formulation (add,
quantise, dequantise, subtract as separate HBM-resident ops) moves ~34
bytes/element where the fused kernel moves ~13 (see BENCH_kernels.json).

VMEM tiling: TILE quant blocks of QBLOCK elements each per grid step; both
are multiples of the 128-lane VPU width.  The constants live in
``repro.core.compression`` (single source of truth shared with the jnp
reference path); callers pad with ``compression.pad_to_block(x, QTILE)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compression import BLOCK as QBLOCK, TILE, QTILE
from repro.kernels.backend import resolve_interpret


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (TILE, QBLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...][:, None]


def _quant_ef_kernel(x_ref, e_ref, q_ref, s_ref, r_ref):
    # one pass: corrected buffer, quantise, and the fresh rounding residual
    x = x_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    # q is already the exact f32 value of the int8 payload, so this residual
    # is bit-identical to the two-pass dequantise-and-subtract — PROVIDED the
    # product is rounded before the subtract.  Compilers contract x - q*scale
    # into an FMA (one rounding, ulp-off from the two-pass reference;
    # optimization_barrier does NOT stop the CPU emitter); the minimum with
    # F32_MAX is a value-identity the contraction cannot look through.
    deq = jnp.minimum(q * scale[:, None], jnp.float32(3.4028235e38))
    r_ref[...] = x - deq


def _check_1d(x: jax.Array, name: str) -> None:
    if x.ndim != 1 or x.size % QTILE != 0:
        raise ValueError(f"{name} needs a 1-D buffer divisible by "
                         f"QTILE={QTILE} (see compression.pad_to_block), "
                         f"got shape {x.shape}")


def quantize_int8(x: jax.Array, *, interpret: bool | None = None):
    """x: 1-D f32, length divisible by QTILE (callers pad).
    Returns (q int8 [N], scales f32 [N/QBLOCK])."""
    _check_1d(x, "quantize_int8")
    nblk = x.size // QBLOCK
    xb = x.reshape(nblk, QBLOCK)
    grid = (nblk // TILE,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((TILE,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nblk,), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(xb)
    return q.reshape(-1), s


def dequantize_int8(q: jax.Array, scales: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    _check_1d(q, "dequantize_int8")
    nblk = q.size // QBLOCK
    qb = q.reshape(nblk, QBLOCK)
    grid = (nblk // TILE,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(qb, scales)
    return x.reshape(-1)


def quantize_ef_int8(x: jax.Array, ef: jax.Array, *,
                     interpret: bool | None = None):
    """Fused EF quantiser: quantise ``x + ef`` and emit the new residual in
    the same VMEM pass.

    x, ef: 1-D f32 of equal length divisible by QTILE (callers pad).
    Returns (q int8 [N], scales f32 [N/QBLOCK], new_ef f32 [N]) with
    ``new_ef = (x+ef) - q*scale`` — bit-identical to the two-pass
    quantise/dequantise/subtract, minus two HBM round-trips.
    """
    _check_1d(x, "quantize_ef_int8")
    if ef.shape != x.shape:
        raise ValueError(f"quantize_ef_int8 needs matching shapes, got "
                         f"x={x.shape} ef={ef.shape}")
    nblk = x.size // QBLOCK
    grid = (nblk // TILE,)
    q, s, r = pl.pallas_call(
        _quant_ef_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((TILE,), lambda i: (i,)),
                   pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nblk,), jnp.float32),
                   jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x.reshape(nblk, QBLOCK), ef.reshape(nblk, QBLOCK))
    return q.reshape(-1), s, r.reshape(-1)
