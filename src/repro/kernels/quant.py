"""Pallas TPU kernel: blockwise symmetric int8 quantise / dequantise.

Used by the slow-link (DCN) gradient compressor — the perf-critical inner
loop of the paper-inspired topology-aware compression: gradients cross the
pod boundary as int8 + per-block f32 scales (~0.26x of f32 wire bytes).

VMEM tiling: TILE quant blocks of QBLOCK elements each per grid step; both
are multiples of the 128-lane VPU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256       # elements sharing one scale (matches core.compression)
TILE = 32          # quant blocks per grid step -> 8192 elements per stage


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (TILE, QBLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...][:, None]


def quantize_int8(x: jax.Array, *, interpret: bool = True):
    """x: 1-D f32, length divisible by QBLOCK*TILE (callers pad).
    Returns (q int8 [N], scales f32 [N/QBLOCK])."""
    if x.ndim != 1 or x.size % (QBLOCK * TILE) != 0:
        raise ValueError(f"quantize_int8 needs a 1-D buffer divisible "
                         f"by {QBLOCK * TILE}, got shape {x.shape}")
    nblk = x.size // QBLOCK
    xb = x.reshape(nblk, QBLOCK)
    grid = (nblk // TILE,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((TILE,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nblk,), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q.reshape(-1), s


def dequantize_int8(q: jax.Array, scales: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    if q.ndim != 1 or q.size % (QBLOCK * TILE) != 0:
        raise ValueError(f"dequantize_int8 needs a 1-D buffer "
                         f"divisible by {QBLOCK * TILE}, got shape "
                         f"{q.shape}")
    nblk = q.size // QBLOCK
    qb = q.reshape(nblk, QBLOCK)
    grid = (nblk // TILE,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((TILE, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, QBLOCK), jnp.float32),
        interpret=interpret,
    )(qb, scales)
    return x.reshape(-1)
