"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.compression import BLOCK as QBLOCK  # single source of truth


def mha_reference(q, k, v, *, causal: bool = True,
                  window: int | None = None) -> jax.Array:
    """Naive O(S^2) GQA attention.  q:(B,Sq,H,hd) k/v:(B,Sk,Hkv,hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def quantize_int8_reference(x: jax.Array, block: int = QBLOCK):
    xb = x.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_int8_reference(q: jax.Array, scales: jax.Array,
                              block: int = QBLOCK) -> jax.Array:
    return (q.reshape(-1, block).astype(jnp.float32)
            * scales[:, None]).reshape(-1)
