"""Pallas TPU kernel: RWKV-6 chunked WKV recurrence, fused over the sequence.

The linear-attention state update S_t = diag(w_t) S_{t-1} + k_t v_t^T with
per-step output o_t = r_t S_{t-1} + (r_t . (u*k_t)) v_t is the compute
hot-spot of the rwkv6-1.6b architecture.  The chunked form (intra-chunk
factored decays + inter-chunk state) is exactly `models.layers._wkv_chunk_
scan`.

ONE kernel invocation per (batch*head): the full (S, hd) sequence is staged
per grid step and a ``lax.fori_loop`` INSIDE the kernel walks the chunks
with the (hd, hd) state carried as the loop value — no per-chunk grid
relaunch, no state round-trip through HBM between chunks (the pre-fusion
version ran one grid step per chunk with the state parked in VMEM scratch
across steps; this version also removes the per-chunk block re-staging).

Validated against ``models.layers._wkv_chunk_scan`` in
tests/test_kernels.py; ``interpret=None`` auto-detects the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

CHUNK = 16


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, *,
                chunk: int, n_chunks: int):
    hd = r_ref.shape[-1]
    u = u_ref[0].astype(jnp.float32)                # (1, hd) bonus

    def chunk_step(ci, S):
        sl = pl.ds(ci * chunk, chunk)
        r = r_ref[0, sl, :].astype(jnp.float32)     # (C, hd)
        k = k_ref[0, sl, :].astype(jnp.float32)
        v = v_ref[0, sl, :].astype(jnp.float32)
        w = w_ref[0, sl, :].astype(jnp.float32)     # decays in (0,1)

        logw = jnp.log(jnp.maximum(w, 1e-8))
        e = jnp.exp(jnp.cumsum(logw, axis=0))       # e_t = prod_{j<=t} w_j
        e_excl = e / jnp.maximum(w, 1e-8)           # prod_{j<t}
        # inter-chunk: o_t += (r_t * e_excl_t) @ S_prev
        o = jax.lax.dot_general(r * e_excl, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # intra-chunk: scores_{t,j} = (r_t*e_excl_t) . (k_j/e_j), j < t
        kk = k / jnp.maximum(e, 1e-30)
        sc = jax.lax.dot_general(r * e_excl, kk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        sc = jnp.where(row > col, sc, 0.0)
        o = o + jax.lax.dot_general(sc, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        # diagonal bonus
        bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)
        o = o + bonus * v
        o_ref[0, sl, :] = o.astype(o_ref.dtype)
        # state to next chunk: S = diag(e_C) S + sum_j diag(e_C/e_j) k_j v_j^T
        eC = e[-1:]                                 # (1, hd)
        return eC.T * S + jax.lax.dot_general(
            kk * eC, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    jax.lax.fori_loop(0, n_chunks, chunk_step,
                      jnp.zeros((hd, hd), jnp.float32))


def wkv_chunked(r, k, v, w, u, *, chunk: int = CHUNK,
                interpret: bool | None = None):
    """r,k,v,w: (B,S,H,hd); u: (H,hd).  Returns o: (B,S,H,hd) f32.

    S must divide by ``chunk`` (callers pad, as models.layers does)."""
    B, S, H, hd = r.shape
    if S % chunk != 0:
        raise ValueError(f"wkv_chunked needs S % chunk == 0, got "
                         f"S={S} chunk={chunk}")
    n = S // chunk

    def fold(x):  # (B,S,H,hd) -> (B*H, S, hd)
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    seq_spec = pl.BlockSpec((1, S, hd), lambda b: (b, 0, 0))
    out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n),
        grid=(B * H,),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, 1, hd), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, S, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
