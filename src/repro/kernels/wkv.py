"""Pallas TPU kernel: RWKV-6 chunked WKV recurrence (one head-block step).

The linear-attention state update S_t = diag(w_t) S_{t-1} + k_t v_t^T with
per-step output o_t = r_t S_{t-1} + (r_t . (u*k_t)) v_t is the compute
hot-spot of the rwkv6-1.6b architecture.  The chunked form (intra-chunk
factored decays + inter-chunk state) is exactly `models.layers._wkv_chunk_
scan`; this kernel executes ONE (batch*head, chunk) tile with the state
carried in VMEM scratch across the chunk-grid dimension.

Grid: (B*H, n_chunks) with n_chunks "arbitrary" so the state scratch
persists across chunk steps.  All matmul dims are the head dim (64/128),
padded to MXU lanes by the caller if needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)        # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)        # decays in (0,1)
    u = u_ref[0].astype(jnp.float32)        # (1, hd) bonus
    S = s_ref[...]                          # (hd, hd) carried state

    logw = jnp.log(jnp.maximum(w, 1e-8))
    e = jnp.exp(jnp.cumsum(logw, axis=0))           # e_t = prod_{j<=t} w_j
    e_excl = e / jnp.maximum(w, 1e-8)               # prod_{j<t}
    # inter-chunk: o_t += (r_t * e_excl_t) @ S_prev
    o = jax.lax.dot_general(r * e_excl, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: scores_{t,j} = (r_t*e_excl_t) . (k_j/e_j), j < t
    kk = k / jnp.maximum(e, 1e-30)
    sc = jax.lax.dot_general(r * e_excl, kk, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    C = sc.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    sc = jnp.where(row > col, sc, 0.0)
    o = o + jax.lax.dot_general(sc, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # diagonal bonus
    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)
    o = o + bonus * v
    o_ref[0] = o.astype(o_ref.dtype)
    # state to next chunk: S = diag(e_C) S + sum_j diag(e_C/e_j) k_j v_j^T
    eC = e[-1:]                                     # (1, hd)
    s_ref[...] = eC.T * S + jax.lax.dot_general(
        (kk * eC).astype(jnp.float32), v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def wkv_chunked(r, k, v, w, u, *, chunk: int = CHUNK,
                interpret: bool = True):
    """r,k,v,w: (B,S,H,hd); u: (H,hd).  Returns o: (B,S,H,hd) f32.

    S must divide by ``chunk`` (callers pad, as models.layers does)."""
    B, S, H, hd = r.shape
    if S % chunk != 0:
        raise ValueError(f"wkv_chunked needs S % chunk == 0, got "
                         f"S={S} chunk={chunk}")
    n = S // chunk

    def fold(x):  # (B,S,H,hd) -> (B*H, S, hd)
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, n_chunks=n),
        grid=(B * H, n),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
