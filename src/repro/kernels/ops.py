"""jit'd public wrappers around the Pallas kernels.

``interpret=None`` auto-detects the backend (interpret off-TPU, compiled on
TPU — see ``repro.kernels.backend``); an explicit bool always wins.  The
flag is threaded, never hard-coded, so the same call sites run in both
environments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compression import QTILE
from . import flash_attention as _fa
from . import quant as _q
from .backend import on_tpu, resolve_interpret  # noqa: F401  (re-exported)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "q_offset",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=_fa.DEFAULT_BLOCK_Q, block_k=_fa.DEFAULT_BLOCK_K,
                    q_offset=0, interpret=None):
    """Differentiable flash attention: forward AND backward are Pallas
    kernels (``jax.custom_vjp`` wired in ``repro.kernels.flash_attention``)."""
    return _fa.flash_attention(q, k, v, causal, window, block_q, block_k,
                               q_offset, interpret)


@functools.partial(jax.jit, static_argnames=("pad", "interpret"))
def _quantize_padded(x, pad, interpret):
    xp = jnp.pad(x, (0, pad)) if pad else x
    return _q.quantize_int8(xp, interpret=interpret)


def quantize_int8(x, *, interpret=None):
    """Returns (q, scales, pad) — pad is a python int for the dequant call."""
    pad = int((-x.size) % QTILE)
    q, s = _quantize_padded(x, pad, interpret)
    return q, s, pad


@functools.partial(jax.jit, static_argnames=("pad", "interpret"))
def dequantize_int8(q, scales, pad=0, *, interpret=None):
    x = _q.dequantize_int8(q, scales, interpret=interpret)
    return x[: x.size - pad] if pad else x


@functools.partial(jax.jit, static_argnames=("pad", "interpret"))
def _quantize_ef_padded(x, ef, pad, interpret):
    if pad:
        x, ef = jnp.pad(x, (0, pad)), jnp.pad(ef, (0, pad))
    q, s, r = _q.quantize_ef_int8(x, ef, interpret=interpret)
    return q, s, r[: r.size - pad] if pad else r


def quantize_ef_int8(x, ef, *, interpret=None):
    """Fused quantise + error-feedback update (one VMEM pass).

    Returns (q, scales, new_ef, pad): ``q``/``scales`` cover the padded
    buffer (pad is a python int for the dequant call); ``new_ef`` is sliced
    back to ``x.size`` and carries ``(x+ef) - dequant(q)``."""
    if x.shape != ef.shape:
        raise ValueError(f"quantize_ef_int8 needs matching shapes, got "
                         f"x={x.shape} ef={ef.shape}")
    pad = int((-x.size) % QTILE)
    q, s, r = _quantize_ef_padded(x, ef, pad, interpret)
    return q, s, r, pad
