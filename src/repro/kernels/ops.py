"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and should be False
on real TPU; the flag is threaded, never hard-coded, so the same call sites
run in both environments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import quant as _q

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=_fa.DEFAULT_BLOCK_Q, block_k=_fa.DEFAULT_BLOCK_K,
                    interpret=not _ON_TPU):
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("pad", "interpret"))
def _quantize_padded(x, pad, interpret):
    xp = jnp.pad(x, (0, pad)) if pad else x
    return _q.quantize_int8(xp, interpret=interpret)


def quantize_int8(x, *, interpret=not _ON_TPU):
    """Returns (q, scales, pad) — pad is a python int for the dequant call."""
    pad = int((-x.size) % (_q.QBLOCK * _q.TILE))
    q, s = _quantize_padded(x, pad, interpret)
    return q, s, pad


@functools.partial(jax.jit, static_argnames=("pad", "interpret"))
def dequantize_int8(q, scales, pad=0, *, interpret=not _ON_TPU):
    x = _q.dequantize_int8(q, scales, interpret=interpret)
    return x[: x.size - pad] if pad else x
