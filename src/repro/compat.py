"""Version-compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern mesh-context API (``jax.shard_map``
with ``axis_names=``/``check_vma=``, ``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``).  Older toolchains (jax 0.4.x) expose the
same machinery as ``jax.experimental.shard_map.shard_map`` with
``auto=``/``check_rep=`` and no abstract-mesh context.  Importing from here
instead of from ``jax`` keeps every explicitly-meshed path working on both;
mesh-less (abstract-mesh-inferred) shard_maps degrade to a clear
``NotImplementedError`` on old jax, and the model code guards those paths via
:func:`get_abstract_mesh` returning ``None``.
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["shard_map", "set_mesh", "get_abstract_mesh"]

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
              check_vma: bool | None = None):
    """Portable shard_map.

    ``axis_names`` follows the NEW convention: the set of mesh axes that are
    MANUAL inside ``f`` (all axes when None).  ``check_vma`` maps onto legacy
    ``check_rep``; left unset it keeps the upstream default on modern jax and
    disables the legacy replication checker (which false-positives on the
    partial-permute programs this repo traces).
    """
    if _HAS_NEW_SHARD_MAP:
        kw = dict(in_specs=in_specs, out_specs=out_specs)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)

    from jax.experimental.shard_map import shard_map as _legacy

    if mesh is None:
        def _unsupported(*_a, **_k):
            raise NotImplementedError(
                "mesh-less (abstract-mesh-inferred) shard_map requires a "
                f"newer jax than {jax.__version__}; pass an explicit mesh "
                "or upgrade")
        return _unsupported
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _legacy(f, mesh, in_specs, out_specs,
                   check_rep=bool(check_vma) if check_vma is not None
                   else False, auto=auto)


def set_mesh(mesh):
    """Context manager establishing ``mesh`` as the ambient device mesh.

    Falls back to a null context on toolchains without a mesh-context API —
    callers there must rely on explicit NamedShardings (the model code's
    abstract-mesh fast paths are guarded off via :func:`get_abstract_mesh`).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext(mesh)


def get_abstract_mesh():
    """The ambient abstract mesh, or None when unsupported/absent."""
    try:
        return jax.sharding.get_abstract_mesh()
    except AttributeError:
        return None
