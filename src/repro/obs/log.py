"""Structured logging for the launchers.

``launch/train.py`` and ``launch/serve.py`` used bare ``print(f"[train]
...")`` calls — fine for a human at a terminal, useless for anything that
wants to scrape step records.  This module gives each launcher a named
logger with two renderings of the SAME call:

* human (default): ``[train] step 3 | loss 1.234`` — byte-identical to the
  old prints, so default output does not change;
* JSON (``--log-json``): one ``json.dumps`` object per line with
  ``logger``/``msg`` plus any structured fields, machine-parseable.

The mode is a process-wide switch (:func:`set_json`) because it models one
thing — what kind of consumer is attached to stdout — not a per-logger
preference.
"""
from __future__ import annotations

import json
import sys

__all__ = ["Logger", "get_logger", "set_json", "json_enabled"]

_JSON = False
_LOGGERS: dict[str, "Logger"] = {}


def set_json(on: bool) -> None:
    """Switch ALL loggers to JSON-lines (or back).  Wired to ``--log-json``
    in the launchers."""
    global _JSON
    _JSON = bool(on)


def json_enabled() -> bool:
    return _JSON


class Logger:
    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def info(self, msg: str, **fields) -> None:
        if _JSON:
            rec = {"logger": self.name, "msg": msg}
            rec.update(fields)
            sys.stdout.write(json.dumps(rec, sort_keys=True) + "\n")
        else:
            # Human format matches the historical `print(f"[name] ...")`
            # exactly; structured fields are assumed to already be baked
            # into msg by the caller when they matter to a human.
            sys.stdout.write(f"[{self.name}] {msg}\n")


def get_logger(name: str) -> Logger:
    lg = _LOGGERS.get(name)
    if lg is None:
        lg = Logger(name)
        _LOGGERS[name] = lg
    return lg
