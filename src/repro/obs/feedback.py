"""Closed loop: measured collective durations feed the plan selector.

The Fast-Tuning idea (cs/0408034) applied to this stack: the communicator
selects trees by an a-priori postal model, but every *traced* execution
yields per-link measured durations.  :class:`FeedbackLoop` aggregates those
into per-link-class residuals (measured vs modeled transfer time) and,
when a class has drifted past a threshold, refits the communicator's
:class:`~repro.core.topology.Level` parameters — through the SAME
:func:`repro.core.discovery.refit_levels` path that targeted re-probing
uses (via :func:`~repro.core.discovery.synthetic_probes`), so there is one
writer of level parameters no matter where the evidence came from.  After
a refit the plan cache is invalidated and the next ``plan()`` re-runs its
argmin under costs that track observed reality: the regret of the selected
plan against the best plan *on the true network* drops (test-asserted in
``tests/test_obs.py``).

Two feeding modes:

* :meth:`run` — execute one collective of the communicator's choosing on a
  ``truth`` topology (the simulation stand-in for the real network) and
  harvest its trace.  This is what the regression test and
  ``benchmarks/bench_obs.py`` drive.
* :meth:`observe_trace` / :meth:`observe` — ingest link intervals from any
  tracer (e.g. one threaded through an engine or scheduler run), or a
  single wall-clock measurement, for callers that already have traffic.

The ``truth`` topology must share coordinates with the model (parameters
may differ arbitrarily) — the same restriction :meth:`Communicator.refresh`
carries: feedback corrects link *costs*, not cluster membership.
"""
from __future__ import annotations

import dataclasses

from ..core import discovery as D
from ..core.costmodel import link_affine_fit
from ..core.simulator import simulate_rounds
from . import contention
from .trace import Tracer

__all__ = ["FeedbackLoop", "FeedbackReport"]


@dataclasses.dataclass(frozen=True)
class FeedbackReport:
    """Outcome of one :meth:`FeedbackLoop.maybe_refit` call.

    ``drift`` maps link-class index -> mean measured/modeled transfer-time
    ratio (1.0 = the model matches); ``worst`` is the largest |ratio - 1|;
    ``fits`` holds the (latency, bandwidth, overhead) applied per refitted
    class (empty when ``refit`` is False); ``n_samples`` the evidence
    count per class.
    """

    refit: bool
    drift: dict[int, float]
    worst: float
    fits: dict[int, tuple[float, float, float]]
    n_samples: dict[int, int]


class FeedbackLoop:
    """Aggregate measured link durations against a communicator's model
    and refit drifted link classes.  See module docstring."""

    def __init__(self, comm, *, threshold: float = 0.15,
                 min_samples: int = 4):
        if comm.view is not None:
            # same reasoning as Communicator.refresh: a view's levels came
            # from an unknown transform; refitting the true topology alone
            # would leave tree construction on stale costs
            raise ValueError("feedback is not supported on a view-based "
                             "communicator")
        if threshold <= 0:
            raise ValueError("threshold must be positive")
        self.comm = comm
        self.threshold = threshold
        self.min_samples = min_samples
        # link class -> [(nbytes, measured_s, first), ...]
        self._samples: dict[int, list[tuple[float, float, bool]]] = {}
        self.refits = 0

    # -- feeding ------------------------------------------------------- #
    def observe(self, level: int, nbytes: float, seconds: float,
                first: bool = True) -> None:
        """One measured transfer on link class ``level``: ``seconds`` is
        the delivery time of ``nbytes`` (latency included when ``first``,
        pure streaming otherwise)."""
        self._samples.setdefault(level, []).append(
            (float(nbytes), float(seconds), bool(first)))

    def observe_trace(self, tracer: Tracer, *,
                      deconvolve: bool = True) -> int:
        """Ingest every link interval a tracer recorded; returns the
        number of samples taken.

        ``deconvolve`` (default) scales each interval back to its
        isolated-equivalent duration via
        :func:`repro.obs.contention.deconvolve`, so traces from the
        concurrent engine yield unbiased residuals.  It is a no-op on
        uncontended traces (lone collectives price identically either
        way); pass ``False`` only to study the contention bias itself.
        """
        rows = (contention.deconvolve(tracer) if deconvolve
                else tracer.link_samples())
        n = 0
        for _src, _dst, level, dt, nbytes, first in rows:
            self.observe(level, nbytes, dt, first)
            n += 1
        return n

    def run(self, op: str, nbytes: float, *, root: int | None = None,
            truth=None) -> tuple[float, float]:
        """Plan ``op`` with the communicator's model, execute it on the
        ``truth`` topology (default: the model itself — a no-drift
        control), harvest the traced link samples, and return
        ``(predicted_s, measured_s)``."""
        truth = self.comm.topo if truth is None else truth
        if truth.nprocs != self.comm.topo.nprocs:
            raise ValueError("truth topology has a different rank count")
        root = self.comm.members[0] if root is None else root
        plan = self.comm.plan(op, root=root, nbytes=nbytes)
        low = plan.lower(nbytes)
        predicted = max(simulate_rounds(low, self.comm.topo).values())
        tr = Tracer()
        measured = max(simulate_rounds(low, truth, tracer=tr,
                                       label=f"feedback:{op}").values())
        self.observe_trace(tr)
        return predicted, measured

    # -- reading -------------------------------------------------------- #
    def _model_time(self, level: int, nbytes: float, first: bool) -> float:
        lvl = self.comm.topo.levels[level]
        return (lvl.latency if first else 0.0) + nbytes / lvl.bandwidth

    def drift(self) -> dict[int, float]:
        """Per link class: mean measured / modeled transfer-time ratio
        over every recorded sample (total-time ratio, so the large
        bandwidth-bound transfers dominate exactly as they dominate the
        makespan the planner mispredicts)."""
        out: dict[int, float] = {}
        for level, rows in sorted(self._samples.items()):
            model = sum(self._model_time(level, n, f) for n, _, f in rows)
            meas = sum(t for _, t, _ in rows)
            if model > 0:
                out[level] = meas / model
        return out

    def n_samples(self) -> dict[int, int]:
        return {lvl: len(rows) for lvl, rows in sorted(self._samples.items())}

    def residual_table(self) -> list[dict]:
        """One row per observed link class — what EXPERIMENTS.md tabulates
        before/after a refit."""
        drift = self.drift()
        return [{"level": lvl,
                 "name": self.comm.topo.levels[lvl].name,
                 "n_samples": len(rows),
                 "measured_over_model": drift.get(lvl, float("nan"))}
                for lvl, rows in sorted(self._samples.items())]

    # -- acting --------------------------------------------------------- #
    def maybe_refit(self) -> FeedbackReport:
        """Refit every sufficiently-evidenced link class when the worst
        per-class drift exceeds the threshold.

        On refit: per-class (latency, bandwidth) come from
        :func:`~repro.core.costmodel.link_affine_fit` over that class's
        samples (overhead is kept — delivery intervals cannot observe
        sender CPU cost), rendered into synthetic probes and applied via
        :func:`~repro.core.discovery.refit_levels`; the communicator's
        plan cache is invalidated (counters stay) and the sample buffer
        resets so post-refit evidence is judged against the NEW model.
        """
        drift = self.drift()
        eligible = {lvl: rows for lvl, rows in self._samples.items()
                    if len(rows) >= self.min_samples and lvl in drift}
        worst = max((abs(drift[lvl] - 1.0) for lvl in eligible),
                    default=0.0)
        counts = self.n_samples()
        if worst <= self.threshold:
            return FeedbackReport(False, drift, worst, {}, counts)
        fits: dict[int, tuple[float, float, float]] = {}
        for lvl, rows in sorted(eligible.items()):
            old = self.comm.topo.levels[lvl]
            lat, bw = link_affine_fit(rows, fallback_latency=old.latency)
            fits[lvl] = (lat, bw, old.overhead)
        probes = D.synthetic_probes(self.comm.topo, fits)
        self.comm.topo = D.refit_levels(self.comm.topo, probes)
        self.comm._cache.invalidate()  # stale costs; stats/counters stay
        self._samples.clear()
        self.refits += 1
        return FeedbackReport(True, drift, worst, fits, counts)
