"""Observability: tracing, metrics, structured logs, measured-cost feedback.

``repro.core`` imports :mod:`repro.obs.metrics` (the registry backs
``Communicator.stats()``), and :mod:`repro.obs.feedback` imports
``repro.core`` (it drives ``discovery.refit_levels``).  To keep that pair
acyclic this package eagerly exposes only the leaf modules — ``feedback``
and ``monitor`` (which also imports ``repro.core``) are loaded on first
attribute access.
"""
from __future__ import annotations

from .contention import deconvolve, occupancy
from .log import get_logger, set_json
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, percentile
from .trace import (PID_LINKS, PID_PLANNER, PID_PROGRAMS, PID_REQUESTS,
                    Tracer)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "Tracer",
    "PID_LINKS",
    "PID_PROGRAMS",
    "PID_REQUESTS",
    "PID_PLANNER",
    "get_logger",
    "set_json",
    "deconvolve",
    "occupancy",
    "FeedbackLoop",
    "FeedbackReport",
    "HealthMonitor",
    "HealthEvent",
]

_LAZY = {"FeedbackLoop": "feedback", "FeedbackReport": "feedback",
         "feedback": "feedback",
         "HealthMonitor": "monitor", "HealthEvent": "monitor",
         "monitor": "monitor"}


def __getattr__(name):
    modname = _LAZY.get(name)
    if modname is not None:
        # importlib, not `from . import`: the latter re-enters this hook
        # through importlib's hasattr check and recurses
        import importlib

        mod = importlib.import_module(f".{modname}", __name__)
        if name == modname:
            return mod
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
