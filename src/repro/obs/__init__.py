"""Observability: tracing, metrics, structured logs, measured-cost feedback.

``repro.core`` imports :mod:`repro.obs.metrics` (the registry backs
``Communicator.stats()``), and :mod:`repro.obs.feedback` imports
``repro.core`` (it drives ``discovery.refit_levels``).  To keep that pair
acyclic this package eagerly exposes only the leaf modules — ``feedback``
is loaded on first attribute access.
"""
from __future__ import annotations

from .log import get_logger, set_json
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, percentile
from .trace import (PID_LINKS, PID_PLANNER, PID_PROGRAMS, PID_REQUESTS,
                    Tracer)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "Tracer",
    "PID_LINKS",
    "PID_PROGRAMS",
    "PID_REQUESTS",
    "PID_PLANNER",
    "get_logger",
    "set_json",
    "FeedbackLoop",
    "FeedbackReport",
]


def __getattr__(name):
    if name in ("FeedbackLoop", "FeedbackReport", "feedback"):
        # importlib, not `from . import`: the latter re-enters this hook
        # through importlib's hasattr check and recurses
        import importlib

        feedback = importlib.import_module(".feedback", __name__)
        if name == "feedback":
            return feedback
        return getattr(feedback, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
