"""Online health monitoring: the closed control loop under production load.

PR 8's :class:`~repro.obs.feedback.FeedbackLoop` is passive — something
must notice drift and decide to act.  :class:`HealthMonitor` is that
something, wired into a live serving run:

* **Rolling-window SLO tracking** — TTFT/TPOT p50/p95/p99, shed/evict
  rates over the last ``window`` finished requests (fed by the
  :class:`~repro.serving.scheduler.Scheduler`).
* **Per-rank straggler scoring** — every resolved engine batch reports
  each handle's ``measured_s`` against its isolated (contention-free)
  ``predicted_s``; the inflation is EWMA-attributed to the handle's
  member ranks, so a rank that keeps appearing in slow collectives while
  its peers do not floats to the top.
* **Drift detection and auto-refit** — traced link intervals are drained
  each check, deconvolved (:mod:`repro.obs.contention`) to
  isolated-equivalent durations, and aggregated into per-link-class
  residual ratios smoothed by an EWMA.  A class past ``threshold``
  triggers either a *targeted re-probe* — ``probe(pairs)`` over
  :func:`~repro.core.discovery.representative_pairs` scoped to the
  implicated class, applied via :meth:`Communicator.refresh` — or, with
  no probe path, a passive refit feeding the windowed residuals through
  :meth:`FeedbackLoop.maybe_refit`.  Either way ``refit_levels`` stays
  the only writer of level parameters, every plan cache (main communicator
  AND the engine's per-subset communicators) is invalidated mid-run via
  :meth:`Engine.refresh_plans`, and the residual windows reset so
  post-refit evidence is judged against the new model.

The monitor owns no thread: the scheduler calls :meth:`on_step` once per
step and every ``check_every`` steps the detectors run inline — all on
the run's virtual clock, so behaviour is deterministic and testable.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from ..core import discovery as D
from ..core.simulator import simulate_rounds
from . import contention
from .feedback import FeedbackLoop, FeedbackReport
from .log import get_logger
from .metrics import MetricsRegistry, percentile
from .trace import Tracer

__all__ = ["HealthMonitor", "HealthEvent"]


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One detector firing: ``kind`` is ``"drift"`` (a link class left its
    model), ``"refit"`` (level parameters were rewritten and plan caches
    invalidated), or ``"straggler"`` (a rank's inflation score crossed the
    flagging rule).  ``step``/``now`` locate it on the run's clock."""

    kind: str
    step: int
    now: float
    detail: dict

    def to_dict(self) -> dict:
        return {"kind": self.kind, "step": self.step, "now": self.now,
                **self.detail}


class HealthMonitor:
    """See module docstring.

    ``engine=`` attaches to a live :class:`~repro.core.engine.Engine`
    (installing a private :class:`Tracer` if it has none — the monitor
    then drains and discards trace records to stay memory-bounded; a
    caller-owned tracer is only read, via a cursor).  ``probe`` is an
    optional callable ``pairs -> TargetedProbes`` (e.g. wrapping
    :func:`~repro.core.discovery.targeted_probes` against the real
    network); without it, drift is corrected passively from the windowed
    residuals.  ``refit=False`` makes the monitor observe-only.
    """

    def __init__(self, comm=None, *, engine=None, window: int = 512,
                 threshold: float = 0.25, ewma_alpha: float = 0.5,
                 min_samples: int = 8, check_every: int = 8,
                 straggler_factor: float = 2.0, probe=None,
                 refit: bool = True, tracer=None,
                 metrics: MetricsRegistry | None = None,
                 log_every: int = 0):
        if threshold <= 0:
            raise ValueError("threshold must be positive")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if window <= 0 or check_every <= 0:
            raise ValueError("window and check_every must be positive")
        self.engine = engine
        self._own_tracer = False
        if engine is not None:
            if comm is None:
                comm = engine.comm
            elif comm is not engine.comm:
                raise ValueError("comm and engine.comm disagree; pass one")
            engine.monitor = self
            if engine.tracer is None:
                engine.tracer = Tracer()
                self._own_tracer = True
            tracer = engine.tracer
        if comm is None:
            raise ValueError("HealthMonitor needs a communicator or engine")
        if (refit or probe is not None) and comm.view is not None:
            raise ValueError("auto-refit is not supported on a view-based "
                             "communicator (same rule as FeedbackLoop)")
        self.comm = comm
        self.tracer = tracer
        self.window = window
        self.threshold = threshold
        self.ewma_alpha = ewma_alpha
        self.min_samples = min_samples
        self.check_every = check_every
        self.straggler_factor = straggler_factor
        self.probe = probe
        self.refit = refit
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.log = get_logger("monitor")
        self.log_every = log_every
        self.events: deque[HealthEvent] = deque(maxlen=256)

        # rolling request window
        self._ttft: deque[float] = deque(maxlen=window)
        self._tpot: deque[float] = deque(maxlen=window)
        self._outcomes: deque[int] = deque(maxlen=window)  # 1 = shed
        self._done = 0
        self._shed = 0
        self._evicted = 0

        # per-link-class residual window + EWMA
        self._res: dict[int, deque] = {}
        self._ewma: dict[int, float] = {}
        self._alarmed: set[int] = set()
        self._util: dict[int, dict] = {}
        self._cursor = 0
        self._last_drain_now: float | None = None

        # per-rank straggler EWMA + predicted-makespan memo
        self._rank_score: dict[int, float] = {}
        self._flagged: set[int] = set()
        self._pred: dict[tuple, float] = {}
        self._topo_ref = comm.topo

        self._step = 0
        self._now = 0.0
        self._steps_seen = 0
        self._m_checks = self.metrics.counter("monitor.checks")
        self._m_refits = self.metrics.counter("monitor.refits")
        self._m_events = self.metrics.counter("monitor.events")
        self._m_worst = self.metrics.gauge("monitor.worst_drift")
        self._m_stragglers = self.metrics.gauge("monitor.stragglers")
        self.refits = 0

    # -- feeding ------------------------------------------------------- #
    def observe_request(self, req, *, evicted: bool = False) -> None:
        """One finished (DONE or SHED) request enters the rolling window.
        Duck-typed on the :class:`~repro.serving.loadgen.Request` surface
        (``state``/``ttft``/``tpot``) so obs stays below serving."""
        state = getattr(getattr(req, "state", None), "name", "")
        if state == "SHED":
            self._outcomes.append(1)
            self._shed += 1
            if evicted:
                self._evicted += 1
            return
        self._outcomes.append(0)
        self._done += 1
        ttft = getattr(req, "ttft", None)
        tpot = getattr(req, "tpot", None)
        if ttft is not None:
            self._ttft.append(float(ttft))
        if tpot is not None:
            self._tpot.append(float(tpot))

    def observe_handles(self, handles) -> None:
        """One resolved engine batch: attribute each handle's
        measured-over-predicted inflation to its member ranks (EWMA)."""
        a = self.ewma_alpha
        for h in handles:
            if h.started is None or h.finished is None:
                continue
            pred = self._predicted(h)
            if pred <= 0.0:
                continue
            infl = (h.finished - h.started) / pred
            for r in h.members:
                cur = self._rank_score.get(r)
                self._rank_score[r] = infl if cur is None \
                    else a * infl + (1.0 - a) * cur

    def _predicted(self, h) -> float:
        """Isolated (contention-free) makespan of a handle's program on
        the current model — memoized per (op, root, nbytes, members) and
        flushed whenever the topology object changes (refit/repair)."""
        topo = self.comm.topo
        if self._topo_ref is not topo:
            self._pred.clear()
            self._topo_ref = topo
        key = (h.op, h.root, float(h.nbytes), tuple(h.members))
        pred = self._pred.get(key)
        if pred is None:
            comm = (self.engine._comm_for(tuple(h.members))
                    if self.engine is not None else self.comm)
            prog = comm.plan(h.op, root=h.root, nbytes=h.nbytes) \
                .lower(h.nbytes)
            pred = max(simulate_rounds(prog, topo).values())
            self._pred[key] = pred
        return pred

    # -- stepping ------------------------------------------------------ #
    def on_step(self, now: float, step: int) -> None:
        """Scheduler hook: called once per serving step; runs the
        detectors every ``check_every`` steps."""
        self._now = float(now)
        self._step = int(step)
        self._steps_seen += 1
        if self._steps_seen % self.check_every == 0:
            self.check()

    def check(self) -> list[HealthEvent]:
        """Drain the trace, update residuals/utilization, run the drift
        and straggler detectors, and act (targeted re-probe or passive
        refit + plan-cache invalidation).  Returns the events raised."""
        self._m_checks.inc()
        self._ingest(self._drain())
        events = self._detect_drift()
        events += self._detect_stragglers()
        for ev in events:
            self.events.append(ev)
            self._m_events.inc()
        if self.log_every and self._m_checks.value % self.log_every == 0:
            self._log_snapshot()
        return events

    def _drain(self) -> list[tuple]:
        if self.tracer is None:
            return []
        recs = self.tracer.link_records()
        new = recs[self._cursor:]
        self._cursor = len(recs)
        if self._own_tracer:
            # private tracer: nobody exports it, so drop drained records
            # (and the engine spans nobody will read) to bound memory
            self.tracer.links.clear()
            self.tracer.spans.clear()
            self.tracer.instants.clear()
            self._cursor = 0
        return new

    def _ingest(self, records: list[tuple]) -> None:
        if not records:
            return
        for src, dst, lvl, iso, nb, first in contention.deconvolve(records):
            dq = self._res.get(lvl)
            if dq is None:
                dq = self._res[lvl] = deque(maxlen=self.window)
            dq.append((nb, iso, first))
        now = self._now
        prev = self._last_drain_now
        occ = contention.occupancy(records)
        for lvl, row in occ.items():
            util = self._util.setdefault(
                lvl, {"utilization": 0.0, "mean_overlap": 1.0})
            if prev is not None and now > prev:
                util["utilization"] = row["busy_s"] / (now - prev)
            util["mean_overlap"] = row["mean_overlap"]
        self._last_drain_now = now

    # -- detectors ----------------------------------------------------- #
    def _model_time(self, lvl: int, nbytes: float, first: bool) -> float:
        l = self.comm.topo.levels[lvl]
        return (l.latency if first else 0.0) + nbytes / l.bandwidth

    def drift(self) -> dict[int, float]:
        """Per link class: the EWMA-smoothed windowed residual ratio
        (measured-isolated-equivalent over modeled total time; 1.0 = the
        model matches)."""
        return dict(sorted(self._ewma.items()))

    def _detect_drift(self) -> list[HealthEvent]:
        worst = 0.0
        drifted: set[int] = set()
        events: list[HealthEvent] = []
        levels = self.comm.topo.levels
        for lvl, dq in sorted(self._res.items()):
            if not dq:
                continue
            model = sum(self._model_time(lvl, nb, first)
                        for nb, _, first in dq)
            if model <= 0.0:
                continue
            ratio = sum(iso for _, iso, _ in dq) / model
            prev = self._ewma.get(lvl)
            ew = ratio if prev is None \
                else self.ewma_alpha * ratio \
                + (1.0 - self.ewma_alpha) * prev
            self._ewma[lvl] = ew
            self.metrics.gauge(f"monitor.drift.{levels[lvl].name}").set(ew)
            dev = abs(ew - 1.0)
            if len(dq) < self.min_samples:
                continue
            worst = max(worst, dev)
            if dev > self.threshold:
                drifted.add(lvl)
                if lvl not in self._alarmed:
                    self._alarmed.add(lvl)
                    events.append(HealthEvent(
                        "drift", self._step, self._now,
                        {"level": lvl, "name": levels[lvl].name,
                         "ratio": ew, "n_samples": len(dq)}))
            else:
                self._alarmed.discard(lvl)
        self._m_worst.set(worst)
        if drifted:
            ev = self._act_on_drift(drifted)
            if ev is not None:
                events.append(ev)
        return events

    def _act_on_drift(self, drifted: set[int]) -> HealthEvent | None:
        if self.probe is None and not self.refit:
            return None
        before = self.comm.topo
        detail: dict = {"levels": sorted(drifted)}
        if self.probe is not None:
            # targeted re-probe, scoped to the implicated link classes
            pairs = [p for p in D.representative_pairs(
                self.comm.topo, self.comm.members) if p[2] in drifted]
            probes = self.probe(pairs) if pairs else None
            if probes is not None:
                # the detector already decided; refresh at half threshold
                # so a genuine probe confirmation is never shrugged off
                self.comm.refresh(probes, threshold=self.threshold / 2.0)
                detail["via"] = "probe"
                detail["n_pairs"] = len(pairs)
        else:
            report = self._refit_from_window()
            detail["via"] = "feedback"
            detail["worst"] = report.worst
            detail["fits"] = {lvl: list(fit)
                              for lvl, fit in sorted(report.fits.items())}
        if self.comm.topo is before:
            return None  # probe/refit declined: evidence did not confirm
        if self.engine is not None:
            self.engine.refresh_plans()
        self._pred.clear()
        self._topo_ref = self.comm.topo
        # post-refit evidence is judged against the NEW model
        self._res.clear()
        self._ewma.clear()
        self._alarmed.clear()
        self.refits += 1
        self._m_refits.inc()
        return HealthEvent("refit", self._step, self._now, detail)

    def _refit_from_window(self) -> FeedbackReport:
        fb = FeedbackLoop(self.comm, threshold=self.threshold,
                          min_samples=self.min_samples)
        for lvl, dq in sorted(self._res.items()):
            for nb, iso, first in dq:
                fb.observe(lvl, nb, iso, first)
        return fb.maybe_refit()

    def stragglers(self) -> dict[int, float]:
        """Per-rank inflation scores (EWMA of measured/predicted over the
        handles the rank participated in), highest first."""
        return dict(sorted(self._rank_score.items(),
                           key=lambda kv: -kv[1]))

    def _detect_stragglers(self) -> list[HealthEvent]:
        scores = self._rank_score
        events: list[HealthEvent] = []
        if len(scores) >= 2:
            med = percentile(scores.values(), 50)
            for r, s in sorted(scores.items()):
                is_straggler = s > self.straggler_factor * med and s > 1.25
                if is_straggler and r not in self._flagged:
                    self._flagged.add(r)
                    events.append(HealthEvent(
                        "straggler", self._step, self._now,
                        {"rank": r, "score": s, "median": med}))
                elif not is_straggler:
                    self._flagged.discard(r)
        self._m_stragglers.set(len(self._flagged))
        return events

    # -- reading ------------------------------------------------------- #
    def snapshot(self) -> dict:
        """JSON-able state of every detector — what ``serve.py --monitor``
        logs periodically and the bench persists."""
        levels = self.comm.topo.levels
        links = {}
        for lvl in sorted(set(self._res) | set(self._util)):
            dq = self._res.get(lvl, ())
            links[levels[lvl].name] = {
                "ewma_ratio": self._ewma.get(lvl, float("nan")),
                "n_samples": len(dq),
                **self._util.get(lvl, {"utilization": 0.0,
                                       "mean_overlap": 1.0}),
            }
        outcomes = self._outcomes
        flagged = {r: self._rank_score[r] for r in sorted(self._flagged)}
        return {
            "step": self._step,
            "now": self._now,
            "requests": {
                "n_done": self._done,
                "n_shed": self._shed,
                "n_evicted": self._evicted,
                "shed_rate": (sum(outcomes) / len(outcomes)
                              if outcomes else 0.0),
                "ttft": {q: percentile(self._ttft, qv)
                         for q, qv in (("p50", 50), ("p95", 95),
                                       ("p99", 99))},
                "tpot": {q: percentile(self._tpot, qv)
                         for q, qv in (("p50", 50), ("p95", 95),
                                       ("p99", 99))},
            },
            "links": links,
            "stragglers": flagged,
            "worst_drift": self._m_worst.value,
            "refits": self.refits,
            "checks": self._m_checks.value,
            "events": [ev.to_dict() for ev in list(self.events)[-8:]],
        }

    def _log_snapshot(self) -> None:
        s = self.snapshot()
        req = s["requests"]
        self.log.info(
            f"step {s['step']}: ttft p99 {req['ttft']['p99']*1e3:.2f} ms, "
            f"shed rate {req['shed_rate']:.3f}, worst drift "
            f"{s['worst_drift']:.3f}, refits {s['refits']}, "
            f"stragglers {sorted(s['stragglers'])}",
            event="monitor", **{
                "step": s["step"], "now": s["now"],
                "ttft_p99_s": req["ttft"]["p99"],
                "shed_rate": req["shed_rate"],
                "worst_drift": s["worst_drift"],
                "refits": s["refits"],
                "stragglers": sorted(s["stragglers"]),
            })

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"HealthMonitor(window={self.window}, "
                f"threshold={self.threshold}, refits={self.refits}, "
                f"events={len(self.events)})")
