"""Low-overhead span/event recorder with Chrome trace-event export.

One :class:`Tracer` instance collects everything a run observes — per-link
busy intervals from the simulators, per-handle lifecycle spans from the
async engine, per-request lifecycle spans from the serving scheduler, and
wall-clock planner instants from :meth:`Communicator.plan` — and exports a
single Chrome/Perfetto trace-event JSON (open in ``chrome://tracing`` or
https://ui.perfetto.dev).

Hot-path discipline, two tiers: (1) the simulators pay NOTHING per event
on a live run — they queue a deterministic replay closure
(:meth:`Tracer.defer_record`) and the actual events are produced by
re-executing the program once, when the trace is first read; (2) inline
recording (the replay path, and ``Tracer(defer=False)``) is a bare tuple
append — no dicts, no string formatting, no timestamp conversion.  All
shaping (track assignment, microsecond conversion, metadata events,
deterministic sort) happens once, at export.  This is what keeps traced
simulation within the <5% overhead budget asserted by
``benchmarks/bench_obs.py``.

Track layout (Chrome ``pid``/``tid``):

* pid ``PID_LINKS``    — one tid per directed edge ``src->dst``; "X" spans
  are link-busy intervals (args: bytes, level, kind, first).
* pid ``PID_PROGRAMS`` — one tid per collective program / engine handle;
  "X" spans queue→dispatch→complete, "i" instants for policy decisions and
  critical paths.
* pid ``PID_REQUESTS`` — one tid per serving request; "X" spans for
  WAITING/PREFILL/DECODE, "i" instants for shed/evict.
* pid ``PID_PLANNER``  — wall-clock planner track (cache hit/miss instants
  with the selected algorithm × segment and predicted cost).

All simulated tracks share the virtual clock (seconds, converted to µs at
export); the planner track uses wall-clock µs since tracer creation.  The
two never share a pid, so mixed units cannot mislead within one track.
"""
from __future__ import annotations

import json
import time

__all__ = [
    "Tracer",
    "PID_LINKS",
    "PID_PROGRAMS",
    "PID_REQUESTS",
    "PID_PLANNER",
]

PID_LINKS = 1
PID_PROGRAMS = 2
PID_REQUESTS = 3
PID_PLANNER = 4

_PROCESS_NAMES = {
    PID_LINKS: "network links (virtual time)",
    PID_PROGRAMS: "collectives / engine handles (virtual time)",
    PID_REQUESTS: "serving requests (virtual time)",
    PID_PLANNER: "planner (wall clock)",
}


class Tracer:
    """Append-only event sink.  One instance per run; pass it as the
    ``tracer=`` keyword down through Communicator → Engine → simulator →
    Scheduler and call :meth:`to_chrome` / :meth:`save` at the end."""

    def __init__(self, defer: bool = True):
        # (src, dst, level, t0, t1, nbytes, kind, first, label,
        #  flow_end, gid) — t1 is the delivery time (latency tail included
        # for `first` sends); flow_end is when the payload stopped flowing
        # on the link (t1 minus the latency tail); gid names the simulator
        # invocation the interval shared bandwidth within, so contention
        # analysis never couples transfers that were priced independently
        self.links: list[tuple] = []
        # (pid, key, name, t0, t1, args_or_None)
        self.spans: list[tuple] = []
        # (pid, key, name, t, args_or_None)
        self.instants: list[tuple] = []
        # (name, value) monotonic tallies surfaced as trace metadata
        self.counters: dict[str, float] = {}
        # With ``defer`` (the default) the simulators record NOTHING on
        # their hot paths: they queue a zero-arg replay closure via
        # :meth:`defer_record` and the deterministic re-execution happens
        # once, here, when the trace is first read.  ``defer=False``
        # forces inline recording (what the replay closures themselves
        # use, and what the overhead benchmark compares against).
        self.defer = defer
        self._pending: list = []
        self._group = 0
        self._wall0 = time.perf_counter()

    # -------------------------------------------------------------- #
    # recording (hot path)
    # -------------------------------------------------------------- #

    def link(self, src: int, dst: int, level: int, t0: float, t1: float,
             nbytes: float, kind: str, first: bool, label=None,
             flow_end: float | None = None, gid: int | None = None) -> None:
        """One busy interval on the directed edge src->dst (virtual time).

        ``flow_end`` is when the payload stopped occupying the link
        (default: ``t1``, i.e. no latency tail); ``gid`` is the sharing
        group (default: a fresh group, i.e. the interval contended with
        nothing — the simulators pass :meth:`group` so every transfer of
        one invocation lands in the same group)."""
        self.links.append((src, dst, level, t0, t1, nbytes, kind, first,
                           label, t1 if flow_end is None else flow_end,
                           self.group() if gid is None else gid))

    def group(self) -> int:
        """A fresh bandwidth-sharing group id.  Each simulator invocation
        grabs one and stamps it on every link interval it records; only
        intervals in the same group ever shared a link's bandwidth."""
        self._group += 1
        return self._group

    def span(self, pid: int, key, name: str, t0: float, t1: float,
             args=None) -> None:
        """A complete [t0, t1] span on track ``key`` of process ``pid``."""
        self.spans.append((pid, key, name, t0, t1, args))

    def instant(self, pid: int, key, name: str, t: float, args=None) -> None:
        self.instants.append((pid, key, name, t, args))

    def wall(self) -> float:
        """Seconds since tracer creation — timestamps for PID_PLANNER."""
        return time.perf_counter() - self._wall0

    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def defer_record(self, fn) -> None:
        """Queue a zero-arg closure that records into this tracer when the
        trace is first read (any export / analysis accessor).  The
        simulators are deterministic, so replaying a program later yields
        the exact events inline recording would have — at zero cost to the
        live run."""
        self._pending.append(fn)

    def _materialize(self) -> None:
        if not self._pending:
            return
        was, self.defer = self.defer, False  # replays record inline
        try:
            while self._pending:
                fns, self._pending = self._pending, []
                for fn in fns:
                    fn()
        finally:
            self.defer = was

    def n_events(self) -> int:
        """Total recorded events (links + spans + instants)."""
        self._materialize()
        return len(self.links) + len(self.spans) + len(self.instants)

    # -------------------------------------------------------------- #
    # export
    # -------------------------------------------------------------- #

    def to_chrome(self) -> dict:
        """Shape the raw tuples into a Chrome trace-event document.

        Deterministic: tids are assigned in sorted track-name order per
        pid, and events are emitted sorted by (pid, tid, ts, name), so the
        same schedule always serialises to the same JSON — what the trace
        tests round-trip and diff against.
        """
        self._materialize()
        events: list[dict] = []
        tids: dict[tuple, int] = {}
        names: dict[tuple, str] = {}

        def tid_of(pid: int, track_name: str) -> int:
            k = (pid, track_name)
            t = tids.get(k)
            if t is None:
                t = len([1 for (p, _) in tids if p == pid]) + 1
                tids[k] = t
                names[k] = track_name
            return t

        # Pre-register link tracks in sorted edge order so tids are stable
        # regardless of schedule interleaving.
        for e in sorted({(s, d) for (s, d, *_ ) in self.links}):
            tid_of(PID_LINKS, f"{e[0]}->{e[1]}")
        for pid, key, *_ in sorted(self.spans, key=lambda r: (r[0], str(r[1]))):
            tid_of(pid, str(key))
        for pid, key, *_ in sorted(self.instants,
                                   key=lambda r: (r[0], str(r[1]))):
            tid_of(pid, str(key))

        for (src, dst, level, t0, t1, nbytes, kind, first, label,
             _fe, _gid) in self.links:
            args = {"bytes": nbytes, "level": level, "kind": kind,
                    "first": bool(first)}
            if label is not None:
                args["program"] = label
            events.append({
                "name": f"{kind} {int(nbytes)}B",
                "ph": "X", "pid": PID_LINKS,
                "tid": tids[(PID_LINKS, f"{src}->{dst}")],
                "ts": t0 * 1e6, "dur": max(t1 - t0, 0.0) * 1e6,
                "cat": "link", "args": args,
            })
        for (pid, key, name, t0, t1, args) in self.spans:
            ev = {"name": name, "ph": "X", "pid": pid,
                  "tid": tids[(pid, str(key))],
                  "ts": t0 * 1e6, "dur": max(t1 - t0, 0.0) * 1e6,
                  "cat": "span"}
            if args:
                ev["args"] = args
            events.append(ev)
        for (pid, key, name, t, args) in self.instants:
            ev = {"name": name, "ph": "i", "pid": pid,
                  "tid": tids[(pid, str(key))],
                  "ts": t * 1e6, "s": "t", "cat": "instant"}
            if args:
                ev["args"] = args
            events.append(ev)

        events.sort(key=lambda e: (e["pid"], e["tid"], e["ts"], e["name"]))

        meta: list[dict] = []
        for pid in sorted({p for (p, _) in tids}):
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0,
                         "args": {"name": _PROCESS_NAMES.get(pid,
                                                             f"pid {pid}")}})
        for (pid, track_name), t in sorted(tids.items(),
                                           key=lambda kv: (kv[0][0], kv[1])):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": t, "args": {"name": track_name}})

        doc = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        if self.counters:
            doc["otherData"] = {"counters": dict(sorted(self.counters.items()))}
        return doc

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)

    # -------------------------------------------------------------- #
    # analysis helpers (used by feedback + benchmarks)
    # -------------------------------------------------------------- #

    def link_samples(self) -> list[tuple]:
        """(src, dst, level, duration_s, nbytes, first) per interval — the
        raw material ``obs.feedback`` turns into per-link-class
        residuals.  Durations are as traced: stretched by contention when
        the run was concurrent (``obs.contention.deconvolve`` undoes
        that)."""
        self._materialize()
        return [(src, dst, level, t1 - t0, nbytes, first)
                for (src, dst, level, t0, t1, nbytes, _k, first, _lb,
                     _fe, _gid)
                in self.links]

    def link_records(self) -> list[tuple]:
        """The raw link tuples (see ``__init__`` for the layout), with any
        deferred replays materialized — what ``obs.contention`` consumes."""
        self._materialize()
        return self.links

    def busy_by_level(self) -> dict[int, float]:
        """Total busy seconds per link class — the quick 'which stratum was
        the bottleneck' readout."""
        self._materialize()
        out: dict[int, float] = {}
        for (_s, _d, level, t0, t1, *_rest) in self.links:
            out[level] = out.get(level, 0.0) + (t1 - t0)
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Tracer(links={len(self.links)}, spans={len(self.spans)}, "
                f"instants={len(self.instants)})")
