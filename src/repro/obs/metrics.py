"""Process-local metrics registry: counters, gauges, histograms.

Before this module every layer kept its own ad-hoc tallies — plain ints on
``PlanCache``, ``Engine._issued``-style privates, ``np.percentile`` calls
inlined in the serving summary.  The registry is the single sink those
layers now publish through: :class:`repro.core.Communicator` backs its
plan-cache/tree-build/repair counters here, the async
:class:`~repro.core.engine.Engine` its issue/complete/batch counters and
wait-latency histogram, and :class:`repro.serving.scheduler.Scheduler` its
request-lifecycle counters and TTFT/TPOT digests — while the frozen
``CommStats`` / ``EngineStats`` / summary-dict surfaces those layers expose
stay exactly as they were (they are *views* over the registry now).

Design constraints, in priority order:

1. **Cheap on the hot path.**  ``Counter.inc`` is one attribute add;
   ``Histogram.observe`` one list append.  Digests (p50/p95/p99) are
   computed at read time, never at write time.
2. **Monotonic counters.**  ``inc`` rejects negative deltas, so a counter
   can only move forward — what lets tests *assert* accounting identities
   (hits + misses = lookups, tree_builds only grows) instead of spot
   checking.  ``reset`` exists for explicit cache-clear semantics and is
   the only way down.
3. **No global state.**  Each registry is an object; layers create their
   own by default and accept a shared one for cross-layer dashboards.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Iterable

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "percentile"]


def percentile(xs: Iterable[float], q: float) -> float:
    """The ONE percentile rule every digest in the repo uses (linear
    interpolation, numpy semantics); empty input reads as NaN so summary
    tables stay total without special-casing."""
    xs = list(xs)
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=float), q))


def _prom_num(v: float) -> str:
    """Prometheus number rendering: integers stay integral, NaN is the
    literal ``NaN`` the exposition format defines (empty histograms)."""
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc by {n})")
        self._value += n

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        """Explicit zeroing (cache clear / test isolation) — the only
        non-monotonic move, and it is deliberate, never incidental."""
        self._value = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter({self.name}={self._value})"


class Gauge:
    """Last-write-wins instantaneous value (queue depth, clock, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Windowed sample store with read-time percentile digests.

    ``window`` bounds memory for long serving runs: samples live in a
    ``deque(maxlen=window)``, so once ``count`` exceeds the window the
    oldest samples roll off and the digests become *rolling-window*
    percentiles (what a live monitor wants anyway).  Below the bound the
    behavior is exactly the old unbounded list's — same samples, same
    digests.  ``window=None`` keeps every sample (the pre-bound
    behavior), for short analytical runs that digest the full population.
    """

    __slots__ = ("name", "samples", "window")

    #: default rolling window — generous enough that every bounded
    #: benchmark/test population fits (identical digests), small enough
    #: that an open-ended serving run cannot grow without limit
    DEFAULT_WINDOW = 8192

    def __init__(self, name: str, window: int | None = DEFAULT_WINDOW):
        if window is not None and window <= 0:
            raise ValueError(f"histogram window must be positive or None, "
                             f"got {window}")
        self.name = name
        self.window = window
        self.samples: deque[float] = deque(maxlen=window)

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def summary(self) -> dict:
        s = self.samples
        return {
            "count": len(s),
            "mean": float(np.mean(s)) if s else float("nan"),
            "p50": percentile(s, 50),
            "p95": percentile(s, 95),
            "p99": percentile(s, 99),
            "max": max(s) if s else float("nan"),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Histogram({self.name}, n={len(self.samples)})"


class MetricsRegistry:
    """Get-or-create namespace of metrics.  Asking for an existing name
    with a different kind is an error — two layers can share a registry
    without silently aliasing each other's instruments."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name)
            self._metrics[name] = m
        elif type(m) is not cls:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, *,
                  window: int | None = None) -> Histogram:
        """Get-or-create a histogram.  ``window`` applies at creation
        (``None`` = the class default); asking for an existing histogram
        with a *different* explicit window is an error — the window is
        part of the metric's meaning, two layers must not silently
        disagree on it."""
        m = self._metrics.get(name)
        if m is None:
            m = Histogram(name) if window is None \
                else Histogram(name, window=window)
            self._metrics[name] = m
        elif type(m) is not Histogram:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}, not Histogram")
        elif window is not None and m.window != window:
            raise ValueError(f"histogram {name!r} already registered with "
                             f"window={m.window}, not {window}")
        return m

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Flat JSON-able view: counters/gauges as numbers, histograms as
        digest dicts — what a dashboard or benchmark persists."""
        out: dict = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
            else:
                out[name] = m.summary()  # type: ignore[union-attr]
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4) of every metric.

        Counters and gauges expose their value; histograms expose the
        summary type (quantiles over the current window plus ``_sum`` /
        ``_count``).  Dots in metric names become underscores — the only
        transform needed to satisfy the exposition grammar, and it is
        reversible for every name the repo registers (none contain
        underscore/dot collisions)."""
        lines: list[str] = []
        for name in self.names():
            m = self._metrics[name]
            pname = name.replace(".", "_")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_prom_num(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_num(m.value)}")
            else:
                lines.append(f"# TYPE {pname} summary")
                for q in (0.5, 0.95, 0.99):
                    lines.append(f'{pname}{{quantile="{q}"}} '
                                 f"{_prom_num(m.percentile(q * 100.0))}")
                lines.append(f"{pname}_sum {_prom_num(sum(m.samples))}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"MetricsRegistry({len(self._metrics)} metrics)"
