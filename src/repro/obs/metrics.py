"""Process-local metrics registry: counters, gauges, histograms.

Before this module every layer kept its own ad-hoc tallies — plain ints on
``PlanCache``, ``Engine._issued``-style privates, ``np.percentile`` calls
inlined in the serving summary.  The registry is the single sink those
layers now publish through: :class:`repro.core.Communicator` backs its
plan-cache/tree-build/repair counters here, the async
:class:`~repro.core.engine.Engine` its issue/complete/batch counters and
wait-latency histogram, and :class:`repro.serving.scheduler.Scheduler` its
request-lifecycle counters and TTFT/TPOT digests — while the frozen
``CommStats`` / ``EngineStats`` / summary-dict surfaces those layers expose
stay exactly as they were (they are *views* over the registry now).

Design constraints, in priority order:

1. **Cheap on the hot path.**  ``Counter.inc`` is one attribute add;
   ``Histogram.observe`` one list append.  Digests (p50/p95/p99) are
   computed at read time, never at write time.
2. **Monotonic counters.**  ``inc`` rejects negative deltas, so a counter
   can only move forward — what lets tests *assert* accounting identities
   (hits + misses = lookups, tree_builds only grows) instead of spot
   checking.  ``reset`` exists for explicit cache-clear semantics and is
   the only way down.
3. **No global state.**  Each registry is an object; layers create their
   own by default and accept a shared one for cross-layer dashboards.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "percentile"]


def percentile(xs: Iterable[float], q: float) -> float:
    """The ONE percentile rule every digest in the repo uses (linear
    interpolation, numpy semantics); empty input reads as NaN so summary
    tables stay total without special-casing."""
    xs = list(xs)
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=float), q))


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc by {n})")
        self._value += n

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        """Explicit zeroing (cache clear / test isolation) — the only
        non-monotonic move, and it is deliberate, never incidental."""
        self._value = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter({self.name}={self._value})"


class Gauge:
    """Last-write-wins instantaneous value (queue depth, clock, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Append-only sample store with read-time percentile digests.

    Samples are kept exactly (these are bounded-cardinality simulation and
    serving runs, not unbounded production streams); ``summary`` returns
    the digest row the benchmarks and serving reports persist.
    """

    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = name
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def summary(self) -> dict:
        s = self.samples
        return {
            "count": len(s),
            "mean": float(np.mean(s)) if s else float("nan"),
            "p50": percentile(s, 50),
            "p95": percentile(s, 95),
            "p99": percentile(s, 99),
            "max": max(s) if s else float("nan"),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Histogram({self.name}, n={len(self.samples)})"


class MetricsRegistry:
    """Get-or-create namespace of metrics.  Asking for an existing name
    with a different kind is an error — two layers can share a registry
    without silently aliasing each other's instruments."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name)
            self._metrics[name] = m
        elif type(m) is not cls:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Flat JSON-able view: counters/gauges as numbers, histograms as
        digest dicts — what a dashboard or benchmark persists."""
        out: dict = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
            else:
                out[name] = m.summary()  # type: ignore[union-attr]
        return out

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"MetricsRegistry({len(self._metrics)} metrics)"
