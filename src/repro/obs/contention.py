"""Contention deconvolution: isolated-equivalent durations from busy traces.

Under :func:`repro.core.simulator.simulate_concurrent`'s fluid sharing, a
traced link interval is *stretched*: k transfers concurrently active on a
directed edge each flow at ``bandwidth / k``, so the interval covers more
wall (virtual) time than the same bytes would take alone.  Feeding those
stretched durations straight into :class:`repro.obs.FeedbackLoop` biases
every residual upward — the loop would "correct" a perfectly calibrated
model just because the engine was busy, which is exactly when production
traffic is available to learn from.

The fix needs no extra simulator state, only the intervals themselves.
With fair sharing, a transfer's payload obeys

    nbytes = integral over its flow interval of  bandwidth / k(t)  dt

where ``k(t)`` is the number of transfers active on the edge at time t —
and k(t) is fully determined by the *other recorded intervals on the same
edge in the same sharing group*.  Dividing each elementary overlap segment
by its occupancy therefore recovers the isolated streaming time exactly:

    integral of dt / k(t)  =  nbytes / bandwidth

:func:`deconvolve` computes that occupancy-weighted duration per interval
(plus the traced latency tail for ``first`` sends, which never occupied
the link) and returns samples in the exact shape
:meth:`Tracer.link_samples` produces, so
:meth:`FeedbackLoop.observe_trace` can ingest contended engine traffic
and still see unbiased per-link-class residuals.

Exactness, by sharing discipline:

* **Fair sharing** (the "fifo" engine policy): exact per interval, to
  float precision — every active transfer holds precisely ``1/k`` of the
  link.
* **Strict priority / aged priority**: a stalled transfer holds 0, not
  ``1/k``, of the link, so *per-interval* estimates split the overlap
  evenly instead of (full, nothing).  But the link is work-conserving
  (the eligible set always flows at full bandwidth), so the per-edge
  *sums* — and hence the per-link-class aggregate residuals
  :meth:`FeedbackLoop.drift` thresholds — remain exact: the per-interval
  errors cancel pairwise inside each overlap.

Sharing groups: intervals only couple within one simulator invocation
(one ``gid`` — see :meth:`Tracer.group`).  Two engine flushes may overlap
in virtual time on the trace, but the simulator never shared bandwidth
across them, so occupancy is computed per ``(gid, edge)``.  A lone
:func:`~repro.core.simulator.simulate_rounds` program has no self-overlap
on any edge (the sender NIC is FIFO), so deconvolution is a no-op on
exactly the traces PR 8's feedback loop already handled — the two feeding
paths agree on uncontended traffic by construction.
"""
from __future__ import annotations

__all__ = ["deconvolve", "occupancy"]


def _records(trace) -> list[tuple]:
    """Accept a Tracer or a raw list of link tuples."""
    recs = getattr(trace, "link_records", None)
    return recs() if recs is not None else list(trace)


def _groups(links: list[tuple]) -> dict[tuple, list[int]]:
    """Indices of ``links`` grouped by (sharing group, directed edge)."""
    groups: dict[tuple, list[int]] = {}
    for i, rec in enumerate(links):
        groups.setdefault((rec[10], rec[0], rec[1]), []).append(i)
    return groups


def deconvolve(trace) -> list[tuple]:
    """Isolated-equivalent link samples from a (possibly contended) trace.

    Returns ``(src, dst, level, seconds, nbytes, first)`` per recorded
    interval — the :meth:`Tracer.link_samples` shape — where ``seconds``
    is the occupancy-weighted flow time plus the traced latency tail.
    Uncontended intervals come back with their traced duration unchanged.
    """
    links = _records(trace)
    iso = [0.0] * len(links)
    for idxs in _groups(links).values():
        if len(idxs) == 1:
            i = idxs[0]
            iso[i] = links[i][9] - links[i][3]  # flow ran alone
            continue
        # sweep the elementary segments between flow boundaries; each
        # segment charges 1/occupancy to every interval covering it
        bounds = sorted({links[i][3] for i in idxs}
                        | {links[i][9] for i in idxs})
        for a, b in zip(bounds, bounds[1:]):
            if b <= a:
                continue
            covering = [i for i in idxs
                        if links[i][3] <= a and links[i][9] >= b]
            if not covering:
                continue
            share = (b - a) / len(covering)
            for i in covering:
                iso[i] += share
    return [(rec[0], rec[1], rec[2],
             iso[i] + (rec[4] - rec[9]),  # + observed latency tail
             rec[5], rec[7])
            for i, rec in enumerate(links)]


def occupancy(trace) -> dict[int, dict]:
    """Per-link-class contention summary of a trace.

    For each link class: ``transfer_s`` (sum of flow durations, counting
    overlap multiply), ``busy_s`` (union of flow intervals per edge and
    sharing group — the time the class's links actually carried traffic),
    ``mean_overlap`` (transfer_s / busy_s; 1.0 = never contended), and
    ``n`` intervals.  The :class:`~repro.obs.monitor.HealthMonitor` turns
    ``busy_s`` over its observation window into utilization.
    """
    links = _records(trace)
    out: dict[int, dict] = {}
    for lvl in sorted({rec[2] for rec in links}):
        out[lvl] = {"transfer_s": 0.0, "busy_s": 0.0,
                    "mean_overlap": 0.0, "n": 0}
    for idxs in _groups(links).values():
        by_level: dict[int, list[int]] = {}
        for i in idxs:
            by_level.setdefault(links[i][2], []).append(i)
        for lvl, lis in by_level.items():
            row = out[lvl]
            row["n"] += len(lis)
            union = 0.0
            end = None
            for i in sorted(lis, key=lambda i: links[i][3]):
                t0, fe = links[i][3], links[i][9]
                row["transfer_s"] += fe - t0
                if end is None or t0 > end:
                    union += fe - t0
                    end = fe
                elif fe > end:
                    union += fe - end
                    end = fe
            row["busy_s"] += union
    for row in out.values():
        if row["busy_s"] > 0:
            row["mean_overlap"] = row["transfer_s"] / row["busy_s"]
    return out
