"""Closed-form cost models + TPU hardware constants.

Two roles:
1. The paper's §4 napkin math — binomial vs multilevel bcast cost over C
   clusters of P processes — used to validate the simulator against the
   paper's own claim (log C -> 1 slow messages).
2. The roofline constants + three-term roofline used by benchmarks/ and
   EXPERIMENTS.md (compute / memory / collective terms for TPU v5e).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "HW",
    "TPU_V5E",
    "binomial_bcast_cost",
    "multilevel_bcast_cost",
    "two_level_bcast_cost",
    "bdp_segment_bytes",
    "pipeline_segment_bytes",
    "MAX_SEGMENTS",
    "MIN_CHUNK_BYTES",
    "roofline_terms",
    "kernel_roofline",
    "refit_hw",
    "link_affine_fit",
]


# ---------------------------------------------------------------------- #
# Paper §4 closed forms.  Params: slow link (l_s, b_s), fast link (l_f, b_f).
# ---------------------------------------------------------------------- #

def binomial_bcast_cost(P: int, C: int, nbytes: float,
                        l_s: float, b_s: float, l_f: float, b_f: float) -> float:
    """Topology-unaware binomial tree: >= log2(C) inter-cluster messages on
    the longest path plus log2(P/C) intra-cluster messages."""
    inter = math.log2(max(C, 1)) if C > 1 else 0.0
    intra = math.log2(max(P // max(C, 1), 1))
    return inter * (l_s + nbytes / b_s) + intra * (l_f + nbytes / b_f)


def multilevel_bcast_cost(P: int, C: int, nbytes: float,
                          l_s: float, b_s: float, l_f: float, b_f: float) -> float:
    """Paper's multilevel method: exactly 1 message on the slow link (flat
    inter-cluster stage overlaps across clusters), then log2(P/C) fast ones."""
    inter = 1.0 if C > 1 else 0.0
    intra = math.log2(max(P // max(C, 1), 1))
    return inter * (l_s + nbytes / b_s) + intra * (l_f + nbytes / b_f)


def two_level_bcast_cost(P: int, C: int, nbytes: float,
                         l_s: float, b_s: float, l_f: float, b_f: float) -> float:
    """MagPIe-style 2-level machine clustering: the root sends one message to
    EVERY other cluster across the slow network (C-1 sequential injections on
    one NIC), then binomial within clusters."""
    inter = (C - 1) * (l_s + nbytes / b_s) if C > 1 else 0.0
    intra = math.log2(max(P // max(C, 1), 1)) * (l_f + nbytes / b_f)
    return inter + intra


# ---------------------------------------------------------------------- #
# Pipelining: segment sizes from the bandwidth-delay product.
# ---------------------------------------------------------------------- #

# Bound on segments per transfer: keeps the lowered-plan size (and the cost
# of simulating one candidate in the "auto" argmin) linear in tree size
# rather than in message bytes.
MAX_SEGMENTS = 64

# Floor on the chunk size of scatter-based algorithms: chunks below this
# cannot amortise per-message latency/overhead, so small payloads fall back
# to fewer (down to one) chunks and the latency-optimal tree plan wins the
# argmin — the standard large/small-message switch.
MIN_CHUNK_BYTES = 8192.0


def bdp_segment_bytes(level) -> float:
    """Bandwidth-delay product of one link class: the bytes in flight when a
    sender streams continuously.  Segments smaller than this waste the link
    on per-message latency; much larger ones forfeit overlap between the
    levels of a multilevel tree."""
    return level.bandwidth * (level.latency + level.overhead)


def pipeline_segment_bytes(levels, nbytes: float,
                           max_segments: int = MAX_SEGMENTS) -> float:
    """Segment size for pipelining ``nbytes`` over a path using ``levels``.

    Per link class the natural segment is its bandwidth-delay product; a
    multilevel path is governed by the largest of them (the slowest stratum:
    segments below its BDP pay WAN latency per piece without increasing
    overlap).  Rounded to a power of two, clamped to [1 KiB, nbytes], and
    floored so no transfer shatters into more than ``max_segments`` pieces.
    """
    if nbytes <= 0:
        return nbytes
    bdp = max(bdp_segment_bytes(l) for l in levels)
    seg = 2.0 ** round(math.log2(max(bdp, 1024.0)))
    floor = nbytes / max_segments
    if seg < floor:
        # Round the floor back UP to a power of two: the raw quotient is
        # almost never one, and a non-power-of-two segment would violate
        # the documented invariant (and mis-bucket downstream plan keys).
        seg = 2.0 ** math.ceil(math.log2(floor))
    return min(seg, nbytes)


# ---------------------------------------------------------------------- #
# TPU roofline
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float        # bf16 FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    dcn_bw: float            # bytes/s per chip, inter-pod
    hbm_bytes: float         # HBM capacity per chip
    vmem_bytes: float


TPU_V5E = HW(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    dcn_bw=6.25e9,
    hbm_bytes=16e9,
    vmem_bytes=128 * 2**20,
)


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    ici_bytes: float,
    chips: int,
    hw: HW = TPU_V5E,
    dcn_bytes: float = 0.0,
) -> dict[str, float]:
    """The three roofline terms, in seconds, for one step on ``chips`` chips.

    ``hlo_flops`` / ``hlo_bytes`` are GLOBAL totals from cost_analysis();
    ``ici_bytes`` is the per-chip collective traffic on ICI links,
    ``dcn_bytes`` the per-chip traffic crossing the pod boundary.
    """
    compute = hlo_flops / (chips * hw.peak_flops)
    memory = hlo_bytes / (chips * hw.hbm_bw)
    collective = ici_bytes / hw.ici_bw + dcn_bytes / hw.dcn_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bound"] = max(terms, key=terms.get).replace("_s", "")
    terms["step_s"] = max(compute, memory, collective)
    return terms


def kernel_roofline(
    flops: float,
    hbm_bytes: float,
    hw: HW = TPU_V5E,
    wall_s: float | None = None,
) -> dict[str, float]:
    """Single-chip roofline for ONE kernel: analytic FLOPs + HBM bytes
    against the chip's two ceilings (no collective term — kernels are local).

    Returns compute_s / memory_s, the kernel's arithmetic intensity vs the
    chip's ridge point (FLOP/byte where the two ceilings meet), which
    ceiling binds, and the model wall ``model_s = max(...)``.  With a
    measured ``wall_s``, adds the achieved-vs-peak fractions
    (``achieved_flops_frac`` / ``achieved_bw_frac``) and the model/measured
    ratio — the numbers ``benchmarks/bench_kernels.py`` persists and
    :func:`refit_hw` consumes to derate the HW constants to a machine.
    """
    if flops < 0 or hbm_bytes <= 0:
        raise ValueError(f"kernel_roofline needs flops >= 0 and "
                         f"hbm_bytes > 0, got {flops=} {hbm_bytes=}")
    compute = flops / hw.peak_flops
    memory = hbm_bytes / hw.hbm_bw
    out = {
        "compute_s": compute,
        "memory_s": memory,
        "intensity": flops / hbm_bytes,
        "ridge": hw.peak_flops / hw.hbm_bw,
        "bound": "compute" if compute >= memory else "memory",
        "model_s": max(compute, memory),
    }
    if wall_s is not None:
        if wall_s <= 0:
            raise ValueError(f"wall_s must be positive, got {wall_s}")
        out["wall_s"] = wall_s
        out["achieved_flops_frac"] = (flops / wall_s) / hw.peak_flops
        out["achieved_bw_frac"] = (hbm_bytes / wall_s) / hw.hbm_bw
        out["model_over_wall"] = out["model_s"] / wall_s
    return out


def link_affine_fit(samples, *, fallback_latency: float = 0.0,
                    ) -> tuple[float, float]:
    """Fit postal parameters (latency, bandwidth) to observed transfers.

    ``samples`` are ``(nbytes, seconds, first)`` rows as harvested from
    traced link intervals (:meth:`repro.obs.Tracer.link_samples`): a
    *first* send's delivery takes ``latency + nbytes/bandwidth``, a
    pipelined follower just ``nbytes/bandwidth`` — so the design matrix is
    ``[first, nbytes]`` and least squares separates the intercept from the
    slope whenever the sample set mixes firsts with followers or spans
    more than one size.  When it does not (rank-deficient: one size, all
    firsts), latency is pinned to ``fallback_latency`` — the caller's
    current model value — and only bandwidth is solved; a feedback refit
    must never *invent* a latency the data cannot identify.

    Returns ``(latency_s, bandwidth_bytes_per_s)``, both clamped positive.
    """
    a = np.asarray([(n, t, 1.0 if f else 0.0) for n, t, f in samples],
                   dtype=float)
    if a.size == 0:
        raise ValueError("link_affine_fit needs at least one sample")
    n, t, f = a[:, 0], a[:, 1], a[:, 2]
    X = np.stack([f, n], axis=1)
    if np.linalg.matrix_rank(X) == 2:
        (lat, slope), *_ = np.linalg.lstsq(X, t, rcond=None)
        lat = max(float(lat), 0.0)
    else:
        lat = max(float(fallback_latency), 0.0)
        pos = n > 0
        if not pos.any():
            raise ValueError("cannot fit bandwidth from zero-byte samples")
        slope = float(np.mean((t[pos] - f[pos] * lat) / n[pos]))
    slope = max(float(slope), 1e-30)
    return lat, 1.0 / slope


def refit_hw(hw: HW, *, flops_frac: float, bw_frac: float, name: str) -> HW:
    """Derate a spec-sheet :class:`HW` to MEASURED ceilings: scale
    ``peak_flops`` / ``hbm_bw`` by the best achieved fractions observed by
    the kernel benchmark, so subsequent :func:`roofline_terms` /
    :func:`kernel_roofline` calls model this machine instead of the
    datasheet.  Fractions are clamped to (0, 1] — a kernel cannot beat the
    roof; measuring above it means the byte/FLOP model is wrong, not the
    silicon generous."""
    f = min(max(flops_frac, 1e-6), 1.0)
    b = min(max(bw_frac, 1e-6), 1.0)
    return dataclasses.replace(
        hw, name=name, peak_flops=hw.peak_flops * f, hbm_bw=hw.hbm_bw * b)
