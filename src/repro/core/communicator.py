"""The single public entry point for topology-aware collectives.

The paper (Karonis et al. §3.2) replaces MPICH-G2's hidden communicators with
explicit multilevel topology so every process can deterministically build the
same tree.  This module is the communicator-shaped front door over that
machinery: a :class:`Communicator` owns a :class:`~repro.core.topology.Topology`,
selects trees under a policy, **caches plans** so repeated collectives stop
re-running tree construction / cost-model argmin / round scheduling, and
dispatches to pluggable backends:

``"sim"``
    Postal-model simulator (:mod:`repro.core.simulator`).  Operands are byte
    counts; results are :class:`SimResult` per-rank completion times.  This is
    the reproduction/benchmark plane.
``"jax"``
    Axis-decomposed device collectives where XLA has a shortcut
    (:mod:`repro.core.collectives`): reduce-scatter intra-pod, exchange across
    pods, all-gather intra-pod.  Operands are jax arrays inside ``shard_map``
    over ``(slow_axis, *fast_axes)``.
``"ppermute"``
    The faithful §3.2 port (:mod:`repro.core.tree_exec`): one
    ``collective_permute`` per tree round over a single flattened mesh axis.
    Used for root-ful ops (bcast/reduce/gather/...) where XLA has no
    axis-decomposed shortcut.

Quickstart::

    topo = paper_fig8_topology()
    comm = Communicator(topo, policy="paper", backend="sim")
    t = comm.bcast(256e3, root=0).time          # seconds, postal model
    comm.cache_info()                           # plan-cache hits/misses

Ops live in a dispatch table (:data:`OPS`) that replaces the string-keyed
dict formerly buried in ``trees.best_tree``; new collectives register with
:func:`register_op`.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Callable, Sequence

from . import schedule as S
from .simulator import simulate
from .topology import Topology
from .trees import (LevelPolicy, PAPER_POLICY, Tree, adaptive_policy,
                    binomial_tree, build_multilevel_tree)

__all__ = [
    "OpSpec",
    "OPS",
    "register_op",
    "size_bucket",
    "select_tree",
    "Plan",
    "PlanCache",
    "CacheInfo",
    "SimResult",
    "Communicator",
    "BACKENDS",
]


# ---------------------------------------------------------------------- #
# Op dispatch table.
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One collective: how to schedule it over a tree and its data flow.

    ``schedule(tree, nbytes) -> Schedule`` is the simulator-plane form;
    backends with device execution provide their own methods keyed by name.
    ``rootful`` ops have a distinguished root (bcast/reduce/gather/scatter);
    ``sized`` ops take a byte count (barrier does not).
    """

    name: str
    schedule: Callable[[Tree, float], S.Schedule]
    rootful: bool
    sized: bool = True


OPS: dict[str, OpSpec] = {}


def register_op(name: str, schedule: Callable, *, rootful: bool,
                sized: bool = True) -> OpSpec:
    """Register a collective in the dispatch table (idempotent overwrite)."""
    spec = OpSpec(name, schedule, rootful=rootful, sized=sized)
    OPS[name] = spec
    return spec


register_op("bcast", S.bcast, rootful=True)
register_op("reduce", S.reduce, rootful=True)
register_op("barrier", lambda tree, nbytes=0.0: S.barrier(tree),
            rootful=False, sized=False)
register_op("gather", S.gather, rootful=True)
register_op("scatter", S.scatter, rootful=True)
register_op("allreduce", S.allreduce, rootful=False)
register_op("allgather", S.allgather, rootful=False)


# ---------------------------------------------------------------------- #
# Tree selection (the cost-model argmin that used to be trees.best_tree).
# ---------------------------------------------------------------------- #

def size_bucket(nbytes: float) -> int:
    """Power-of-two bucket for plan-cache keys: tree *choice* (adaptive /
    cost-model policies) is size-dependent, but varies slowly enough that one
    plan per size octave is the right cache granularity."""
    if nbytes is None or nbytes <= 0:
        return -1
    return max(0, int(math.log2(nbytes)))


def select_tree(topo: Topology, root: int, op: str, nbytes: float,
                members: Sequence[int] | None = None,
                policy: Any = "auto",
                view: Topology | None = None) -> tuple[Tree, int]:
    """Pick the tree for ``op`` under ``policy``; returns (tree, n_built).

    ``view`` builds the tree against a *different* (e.g. collapsed MagPIe, or
    deliberately oblivious) topology while the caller still charges costs on
    the true one — how the paper's baselines are reproduced.

    Policies: a :class:`LevelPolicy`, or one of
      "paper"     — flat at the WAN, binomial below (the paper's choice)
      "adaptive"  — per-level Bar-Noy/Kipnis shape from the latency ratio
      "oblivious" — rank-order binomial, no topology knowledge (MPICH)
      "auto"      — simulate paper/adaptive/oblivious candidates on the true
                    topology and take the argmin (beyond-paper; every process
                    reaches the identical choice with zero communication).
    """
    spec = OPS[op]
    build_topo = view if view is not None else topo
    if members is None:
        members = list(range(build_topo.nprocs))
    members = list(members)

    if isinstance(policy, LevelPolicy):
        return build_multilevel_tree(build_topo, root, members, policy), 1
    if policy == "paper":
        return build_multilevel_tree(build_topo, root, members,
                                     PAPER_POLICY), 1
    if policy == "adaptive":
        return build_multilevel_tree(
            build_topo, root, members,
            adaptive_policy(build_topo, nbytes or 0.0)), 1
    if policy == "oblivious":
        return binomial_tree(root, members), 1
    if policy in ("auto", "best"):
        candidates = [
            build_multilevel_tree(build_topo, root, members, PAPER_POLICY),
            build_multilevel_tree(build_topo, root, members,
                                  adaptive_policy(build_topo, nbytes or 0.0)),
            binomial_tree(root, members),
        ]
        nb = nbytes or 0.0
        times = [max(simulate(spec.schedule(t, nb), topo).values())
                 for t in candidates]
        return candidates[times.index(min(times))], len(candidates)
    raise ValueError(f"unknown policy {policy!r}")


# ---------------------------------------------------------------------- #
# Plans and the plan cache.
# ---------------------------------------------------------------------- #

class Plan:
    """A cached collective plan: the selected ``tree``, lazily-built message
    ``schedule(nbytes)`` (memoised per exact size), and the static ppermute
    ``rounds`` — everything that is pure function of (op, root, members,
    size-bucket) and therefore reusable across calls."""

    __slots__ = ("spec", "root", "tree", "_schedules", "_rounds")

    def __init__(self, spec: OpSpec, root: int, tree: Tree):
        self.spec = spec
        self.root = root
        self.tree = tree
        self._schedules: dict[float, S.Schedule] = {}
        self._rounds: list[list[tuple[int, int]]] | None = None

    @property
    def op(self) -> str:
        return self.spec.name

    def schedule(self, nbytes: float = 0.0) -> S.Schedule:
        key = float(nbytes or 0.0)
        if key not in self._schedules:
            if len(self._schedules) >= 16:  # bound the per-size memo
                self._schedules.clear()
            self._schedules[key] = (self.spec.schedule(self.tree, key)
                                    if self.spec.sized
                                    else self.spec.schedule(self.tree))
        return self._schedules[key]

    @property
    def rounds(self) -> list[list[tuple[int, int]]]:
        if self._rounds is None:
            from .tree_exec import tree_rounds  # lazy: pulls in jax
            self._rounds = tree_rounds(self.tree)
        return self._rounds

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Plan(op={self.op!r}, root={self.root}, "
                f"|members|={len(self.tree.members())})")


CacheInfo = collections.namedtuple(
    "CacheInfo", ["hits", "misses", "currsize", "maxsize", "tree_builds"])


class PlanCache:
    """Tiny LRU keyed by (op, root, size-bucket, members)."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._d: collections.OrderedDict = collections.OrderedDict()

    def get_or_build(self, key, build: Callable[[], Plan]) -> Plan:
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return self._d[key]
        self.misses += 1
        plan = build()
        self._d[key] = plan
        if len(self._d) > self.maxsize:
            self._d.popitem(last=False)
        return plan

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0


# ---------------------------------------------------------------------- #
# Backends.
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-rank completion times of one simulated collective."""

    op: str
    root: int
    nbytes: float
    completion: dict[int, float]

    @property
    def time(self) -> float:
        """Wall-clock of the collective: the last rank to finish."""
        return max(self.completion.values())


class SimBackend:
    """Postal-model simulation: operands are byte counts."""

    name = "sim"
    needs_plan = True

    def __init__(self, comm: "Communicator"):
        self.comm = comm

    def run(self, op: str, plan: Plan, x, root: int) -> SimResult:
        nbytes = float(x) if OPS[op].sized else 0.0
        completion = simulate(plan.schedule(nbytes), self.comm.topo)
        return SimResult(op, root, nbytes, completion)


class PpermuteBackend:
    """Faithful §3.2 execution: one lax.ppermute per tree round, inside
    shard_map over a single flattened mesh axis (``axis=``).  Root-ful ops
    return zeros on non-root ranks (mirroring MPI out-buffer semantics)."""

    name = "ppermute"
    needs_plan = True

    def __init__(self, comm: "Communicator"):
        if comm.axis is None:
            raise ValueError("backend='ppermute' requires axis=<mesh axis>")
        self.comm = comm
        self.axis = comm.axis

    def run(self, op: str, plan: Plan, x, root: int):
        return getattr(self, op)(plan, x, root)

    # -- ops ----------------------------------------------------------- #
    def bcast(self, plan, x, root):
        from . import tree_exec as TE
        return TE.tree_bcast(x, plan.tree, self.axis)

    def reduce(self, plan, x, root):
        import jax.numpy as jnp
        from jax import lax
        from . import tree_exec as TE
        r = TE.tree_reduce(x, plan.tree, self.axis)
        return jnp.where(lax.axis_index(self.axis) == root, r,
                         jnp.zeros_like(r))

    def allreduce(self, plan, x, root):
        from . import tree_exec as TE
        r = TE.tree_reduce(x, plan.tree, self.axis)
        return TE.tree_bcast(r, plan.tree, self.axis)

    def gather(self, plan, x, root):
        import jax.numpy as jnp
        from jax import lax
        from . import tree_exec as TE
        buf = TE.tree_gather_flat(x, plan.tree, self.axis,
                                  len(self.comm.members))
        return jnp.where(lax.axis_index(self.axis) == root, buf,
                         jnp.zeros_like(buf))

    def allgather(self, plan, x, root):
        from . import tree_exec as TE
        buf = TE.tree_gather_flat(x, plan.tree, self.axis,
                                  len(self.comm.members))
        return TE.tree_bcast(buf, plan.tree, self.axis)

    def scatter(self, plan, x, root):
        # Root holds the full [P, ...] buffer; ship it down the tree and let
        # each rank slice its row.  (A trimming scatter that sends only each
        # subtree's rows is the simulator-plane model; on-device we accept
        # the bcast-sized payload for a fixed ppermute program.)
        from jax import lax
        from . import tree_exec as TE
        full = TE.tree_bcast(x, plan.tree, self.axis)
        idx = lax.axis_index(self.axis)
        return lax.dynamic_index_in_dim(full, idx, axis=0, keepdims=False)

    def barrier(self, plan, x, root):
        import jax.numpy as jnp
        from . import tree_exec as TE
        token = jnp.zeros((), jnp.float32)
        token = TE.tree_reduce(token, plan.tree, self.axis)
        return TE.tree_bcast(token, plan.tree, self.axis)


class JaxBackend:
    """Axis-decomposed device collectives — the paths where XLA has a
    shortcut.  Runs inside shard_map over ``(slow_axis, *fast_axes)``;
    allreduce is the multilevel reduce-scatter/exchange/all-gather
    decomposition, the rest lower to a single (masked) psum.

    Rank space: flat row-major index over (slow_axis, *fast_axes) ONLY —
    the communicator's topology/members must cover exactly those ranks
    (``launch.mesh.mesh_communicator`` builds the dp-scoped topology for a
    mesh that also has a model axis)."""

    name = "jax"
    needs_plan = False

    def __init__(self, comm: "Communicator"):
        if not comm.fast_axes and comm.slow_axis is None:
            raise ValueError(
                "backend='jax' requires slow_axis= and/or fast_axes=")
        self.comm = comm
        self.slow_axis = comm.slow_axis
        self.fast_axes = tuple(comm.fast_axes)
        self.axes = (((comm.slow_axis,) if comm.slow_axis else ())
                     + self.fast_axes)

    def run(self, op: str, plan, x, root: int):
        return getattr(self, op)(x, root)

    # -- helpers -------------------------------------------------------- #
    def _index(self):
        """Flat device rank in row-major (slow, *fast) order — matches the
        member ordering of a Topology built over the same mesh."""
        from jax import lax
        idx = 0
        for ax in self.axes:
            idx = idx * lax.psum(1, ax) + lax.axis_index(ax)
        return idx

    def _nranks(self) -> int:
        from jax import lax
        n = 1
        for ax in self.axes:
            n *= int(lax.psum(1, ax))
        return n

    # -- ops ------------------------------------------------------------ #
    def allreduce(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        from .collectives import multilevel_psum
        fast = 1
        for ax in self.fast_axes:
            fast *= int(lax.psum(1, ax))
        shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.size) % max(fast, 1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        flat = multilevel_psum(flat, self.slow_axis, self.fast_axes)
        if pad:
            flat = flat[:flat.size - pad]
        return flat.reshape(shape)

    def bcast(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        masked = jnp.where(self._index() == root, x, jnp.zeros_like(x))
        return lax.psum(masked, self.axes)

    def reduce(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        full = lax.psum(x, self.axes)
        return jnp.where(self._index() == root, full, jnp.zeros_like(full))

    def gather(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        buf = self._placed(x)
        full = lax.psum(buf, self.axes)
        return jnp.where(self._index() == root, full, jnp.zeros_like(full))

    def allgather(self, x, root):
        from jax import lax
        return lax.psum(self._placed(x), self.axes)

    def scatter(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        masked = jnp.where(self._index() == root, x, jnp.zeros_like(x))
        full = lax.psum(masked, self.axes)
        return lax.dynamic_index_in_dim(full, self._index(), axis=0,
                                        keepdims=False)

    def barrier(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        return lax.psum(jnp.zeros((), jnp.float32), self.axes)

    def _placed(self, x):
        import jax.numpy as jnp
        buf = jnp.zeros((self._nranks(),) + x.shape, x.dtype)
        return buf.at[self._index()].set(x)


BACKENDS: dict[str, type] = {
    "sim": SimBackend,
    "ppermute": PpermuteBackend,
    "jax": JaxBackend,
}


# ---------------------------------------------------------------------- #
# The communicator.
# ---------------------------------------------------------------------- #

class Communicator:
    """Topology-aware collectives behind one object.

    Parameters
    ----------
    topo : the true multilevel topology costs are charged on.
    policy : "paper" | "adaptive" | "oblivious" | "auto" | LevelPolicy.
    backend : "sim" | "jax" | "ppermute" (see module docstring).
    members : participating ranks (default: all of ``topo``).
    view : optional topology the *trees* are built against (MagPIe/oblivious
        baselines) while simulation still charges true per-edge costs.
    axis : flattened mesh axis name (ppermute backend).
    slow_axis, fast_axes : mesh axis decomposition (jax backend).
    """

    def __init__(self, topo: Topology, *, policy: Any = "auto",
                 backend: str = "sim",
                 members: Sequence[int] | None = None,
                 view: Topology | None = None,
                 axis: str | None = None,
                 slow_axis: str | None = None,
                 fast_axes: Sequence[str] = (),
                 cache_size: int = 128):
        self.topo = topo
        self.policy = policy
        self.view = view
        self.members = tuple(members if members is not None
                             else range(topo.nprocs))
        if not self.members:
            raise ValueError("communicator needs at least one member")
        self.axis = axis
        self.slow_axis = slow_axis
        self.fast_axes = tuple(fast_axes)
        self.tree_builds = 0
        # only these policies choose a different tree per size octave; for
        # the rest, one plan per (op, root) serves every message size, so
        # plan() inspection and execution always share a cache entry
        self._size_dependent = policy in ("adaptive", "auto", "best")
        self._cache = PlanCache(cache_size)
        try:
            backend_cls = BACKENDS[backend]
        except KeyError:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {sorted(BACKENDS)}") from None
        self.backend = backend_cls(self)

    # -- planning -------------------------------------------------------- #
    def plan(self, op: str, *, root: int | None = None,
             nbytes: float = 0.0) -> Plan:
        """The (cached) plan for one collective.  Key: (op, root,
        size-bucket, members) — a second identical call re-runs nothing."""
        spec = OPS[op]  # KeyError on unknown op is the dispatch contract
        root = self.members[0] if root is None else root
        if root not in self.members:
            raise ValueError(f"root {root} is not a member")
        bucket = (size_bucket(nbytes) if self._size_dependent and spec.sized
                  else -1)
        key = (op, root, bucket, self.members)

        def build() -> Plan:
            tree, built = select_tree(self.topo, root, op, nbytes,
                                      members=self.members,
                                      policy=self.policy, view=self.view)
            self.tree_builds += built
            return Plan(spec, root, tree)

        return self._cache.get_or_build(key, build)

    def cache_info(self) -> CacheInfo:
        c = self._cache
        return CacheInfo(c.hits, c.misses, len(c), c.maxsize,
                         self.tree_builds)

    def clear_cache(self) -> None:
        self._cache.clear()
        self.tree_builds = 0

    # -- the seven collectives -------------------------------------------- #
    def bcast(self, x, *, root: int = 0):
        return self._run("bcast", x, root)

    def reduce(self, x, *, root: int = 0):
        return self._run("reduce", x, root)

    def barrier(self):
        return self._run("barrier", None, self.members[0])

    def gather(self, x, *, root: int = 0):
        return self._run("gather", x, root)

    def scatter(self, x, *, root: int = 0):
        return self._run("scatter", x, root)

    def allreduce(self, x):
        return self._run("allreduce", x, self.members[0])

    def allgather(self, x):
        return self._run("allgather", x, self.members[0])

    def _run(self, op: str, x, root: int):
        if root not in self.members:  # every backend, planned or not
            raise ValueError(f"root {root} is not a member")
        plan = None
        if self.backend.needs_plan:
            plan = self.plan(op, root=root, nbytes=self._nbytes_of(op, x))
        return self.backend.run(op, plan, x, root)

    def allreduce_tree(self, grads, *, mode: str = "multilevel",
                       mean_over: int | None = None):
        """All-reduce a gradient pytree (jax backend only): fuses all leaves
        into one flat buffer per level — see collectives.multilevel_psum_tree."""
        if not isinstance(self.backend, JaxBackend):
            raise ValueError("allreduce_tree requires backend='jax'")
        from .collectives import multilevel_psum_tree
        return multilevel_psum_tree(grads, self.slow_axis, self.fast_axes,
                                    mode=mode, mean_over=mean_over)

    # -- introspection ----------------------------------------------------- #
    def _nbytes_of(self, op: str, x) -> float:
        if not OPS[op].sized or x is None:
            return 0.0
        if isinstance(x, (int, float)):
            return float(x)
        # device operand (tracer or array): bytes of the local shard
        size = 1
        for d in getattr(x, "shape", ()):
            size *= int(d)
        itemsize = getattr(getattr(x, "dtype", None), "itemsize", 4)
        return float(size * itemsize)

    def slow_crossings(self, op: str, *, root: int = 0,
                       nbytes: float = 0.0) -> int:
        """Edges of the plan's tree that cross the slowest level — the
        paper's headline metric (log C -> C-1 -> 1 wide-area messages)."""
        tree = self.plan(op, root=root, nbytes=nbytes).tree
        return sum(1 for p, cs in tree.children.items() for c in cs
                   if self.topo.comm_level(p, c) == 0)

    def describe(self) -> str:
        lv = "/".join(l.name for l in self.topo.levels)
        pol = (self.policy if isinstance(self.policy, str)
               else type(self.policy).__name__)
        return (f"Communicator(P={len(self.members)}, levels={lv}, "
                f"policy={pol}, backend={self.backend.name})")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self.describe()
