"""The single public entry point for topology-aware collectives.

The paper (Karonis et al. §3.2) replaces MPICH-G2's hidden communicators with
explicit multilevel topology so every process can deterministically build the
same tree.  This module is the communicator-shaped front door over that
machinery: a :class:`Communicator` owns a :class:`~repro.core.topology.Topology`,
selects trees under a policy, **caches plans** so repeated collectives stop
re-running tree construction / cost-model argmin / round scheduling, and
dispatches to pluggable backends:

``"sim"``
    Postal-model simulator (:mod:`repro.core.simulator`).  Operands are byte
    counts; results are :class:`SimResult` per-rank completion times.  This is
    the reproduction/benchmark plane.
``"jax"``
    Axis-decomposed device collectives where XLA has a shortcut
    (:mod:`repro.core.collectives`): reduce-scatter intra-pod, exchange across
    pods, all-gather intra-pod.  Operands are jax arrays inside ``shard_map``
    over ``(slow_axis, *fast_axes)``.
``"ppermute"``
    The faithful §3.2 port (:mod:`repro.core.tree_exec`): one
    ``collective_permute`` per tree round over a single flattened mesh axis.
    Used for root-ful ops (bcast/reduce/gather/...) where XLA has no
    axis-decomposed shortcut.

Quickstart::

    topo = paper_fig8_topology()
    comm = Communicator(topo, policy="paper", backend="sim")
    t = comm.bcast(256e3, root=0).time          # seconds, postal model
    comm.cache_info()                           # plan-cache hits/misses

Ops live in a dispatch table (:data:`OPS`) that replaces the string-keyed
dict formerly buried in ``trees.best_tree``; new collectives register with
:func:`register_op`.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import os
from typing import Any, Callable, Sequence

from ..obs.metrics import MetricsRegistry
from ..obs.trace import PID_PLANNER, PID_PROGRAMS
from . import rounds as R
from . import schedule as S
from .simulator import simulate, simulate_rounds
from .topology import Topology
from .trees import (LevelPolicy, PAPER_POLICY, Tree, adaptive_policy,
                    binomial_tree, build_multilevel_tree, repair_tree)

__all__ = [
    "OpSpec",
    "OPS",
    "register_op",
    "size_bucket",
    "select_tree",
    "select_plan",
    "PlanChoice",
    "Plan",
    "PlanCache",
    "CacheInfo",
    "CommStats",
    "RepairReport",
    "RefreshReport",
    "SimResult",
    "Communicator",
    "BACKENDS",
]


# ---------------------------------------------------------------------- #
# Op dispatch table.
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One collective: how to schedule it over a tree and its data flow.

    ``schedule(tree, nbytes) -> Schedule`` is the whole-message simulator
    form; ``algorithms`` names the registered lowerings to the rounds IR
    (:mod:`repro.core.rounds`) — ``"tree"`` is the generic segmented tree
    lowering, large-message algorithms (``"sag"``, ``"rsag"``) register
    alongside it and the ``"auto"`` policy searches across them.
    ``rootful`` ops have a distinguished root (bcast/reduce/gather/scatter);
    ``sized`` ops take a byte count (barrier does not).
    """

    name: str
    schedule: Callable[[Tree, float], S.Schedule]
    rootful: bool
    sized: bool = True
    algorithms: tuple[str, ...] = ("tree",)


OPS: dict[str, OpSpec] = {}


def register_op(name: str, schedule: Callable, *, rootful: bool,
                sized: bool = True,
                algorithms: Sequence[str] = ("tree",)) -> OpSpec:
    """Register a collective in the dispatch table (idempotent overwrite)."""
    spec = OpSpec(name, schedule, rootful=rootful, sized=sized,
                  algorithms=tuple(algorithms))
    OPS[name] = spec
    return spec


register_op("bcast", S.bcast, rootful=True, algorithms=("tree", "sag"))
register_op("reduce", S.reduce, rootful=True)
register_op("barrier", lambda tree, nbytes=0.0: S.barrier(tree),
            rootful=False, sized=False)
register_op("gather", S.gather, rootful=True)
register_op("scatter", S.scatter, rootful=True)
register_op("allreduce", S.allreduce, rootful=False,
            algorithms=("tree", "rsag"))
register_op("allgather", S.allgather, rootful=False)


# ---------------------------------------------------------------------- #
# Tree selection (the cost-model argmin that used to be trees.best_tree).
# ---------------------------------------------------------------------- #

def size_bucket(nbytes: float) -> int:
    """Power-of-two bucket for plan-cache keys: tree *choice* (adaptive /
    cost-model policies) is size-dependent, but varies slowly enough that one
    plan per size octave is the right cache granularity."""
    if nbytes is None or nbytes <= 0:
        return -1
    return max(0, int(math.log2(nbytes)))


def select_tree(topo: Topology, root: int, op: str, nbytes: float,
                members: Sequence[int] | None = None,
                policy: Any = "auto",
                view: Topology | None = None) -> tuple[Tree, int]:
    """Pick the tree for ``op`` under ``policy``; returns (tree, n_built).

    ``view`` builds the tree against a *different* (e.g. collapsed MagPIe, or
    deliberately oblivious) topology while the caller still charges costs on
    the true one — how the paper's baselines are reproduced.

    Policies: a :class:`LevelPolicy`, or one of
      "paper"     — flat at the WAN, binomial below (the paper's choice)
      "adaptive"  — per-level Bar-Noy/Kipnis shape from the latency ratio
      "oblivious" — rank-order binomial, no topology knowledge (MPICH)
      "auto"      — simulate paper/adaptive/oblivious candidates on the true
                    topology and take the argmin (beyond-paper; every process
                    reaches the identical choice with zero communication).
    """
    spec = OPS[op]
    build_topo = view if view is not None else topo
    if members is None:
        members = list(range(build_topo.nprocs))
    members = list(members)

    if isinstance(policy, LevelPolicy):
        return build_multilevel_tree(build_topo, root, members, policy), 1
    if policy == "paper":
        return build_multilevel_tree(build_topo, root, members,
                                     PAPER_POLICY), 1
    if policy == "adaptive":
        return build_multilevel_tree(
            build_topo, root, members,
            adaptive_policy(build_topo, nbytes or 0.0)), 1
    if policy == "oblivious":
        return binomial_tree(root, members), 1
    if policy in ("auto", "best"):
        candidates = _candidate_trees(build_topo, root, members, nbytes)
        nb = nbytes or 0.0
        times = [max(simulate(spec.schedule(t, nb), topo).values())
                 for t in candidates]
        return candidates[times.index(min(times))], len(candidates)
    raise ValueError(f"unknown policy {policy!r}")


def _candidate_trees(build_topo: Topology, root: int, members: list,
                     nbytes: float) -> list[Tree]:
    """The ONE candidate-tree list every "auto" argmin searches."""
    return [
        build_multilevel_tree(build_topo, root, members, PAPER_POLICY),
        build_multilevel_tree(build_topo, root, members,
                              adaptive_policy(build_topo, nbytes or 0.0)),
        binomial_tree(root, members),
    ]


@dataclasses.dataclass(frozen=True)
class PlanChoice:
    """Outcome of plan selection: the tree, the rounds-IR algorithm, the
    segment policy (None | "bdp" | bytes) and how many trees were built."""

    tree: Tree
    algorithm: str
    segment: Any
    n_built: int


# Only these ops gain from sub-message segmentation (uniform payload down /
# up the tree); personalised ops pipeline at chunk granularity instead.
_SEGMENTABLE = ("bcast", "reduce", "allreduce")


def select_plan(topo: Topology, root: int, op: str, nbytes: float,
                members: Sequence[int] | None = None,
                policy: Any = "auto",
                view: Topology | None = None,
                algorithm: str | None = None,
                segment_bytes: Any = None) -> PlanChoice:
    """Pick (tree, algorithm, segment size) for one collective.

    Under a fixed tree policy the defaults stay faithful to that baseline:
    algorithm "tree", no segmentation.  Under ``policy="auto"`` (or an
    explicit ``algorithm="auto"``) the argmin searches the full product
    {tree shape} x {registered algorithm} x {segment size} by lowering each
    candidate to the rounds IR and simulating it on the true topology —
    every process reaches the identical choice with zero communication.
    """
    spec = OPS[op]
    build_topo = view if view is not None else topo
    if members is None:
        members = list(range(build_topo.nprocs))
    members = list(members)

    searching = policy in ("auto", "best")
    if searching:
        trees = _candidate_trees(build_topo, root, members, nbytes)
        n_built = len(trees)
    else:
        tree, n_built = select_tree(topo, root, op, nbytes,
                                    members=members, policy=policy,
                                    view=view)
        trees = [tree]

    # algorithm candidates: fixed policies default to the faithful "tree"
    # plan; searching policies (or algorithm="auto") consider everything
    # registered for the op.  Baselines built against a *view* stay on
    # "tree" — they model systems without the leaf-group machinery.
    nb = float(nbytes or 0.0)
    if algorithm not in (None, "auto"):
        algos = [algorithm]
    elif (algorithm == "auto" or searching) and view is None and nb > 0 \
            and len(members) > 1:
        algos = list(spec.algorithms)
    else:
        algos = ["tree"]

    # segment candidates
    if segment_bytes is None:
        segs = ([None, "bdp"] if searching and op in _SEGMENTABLE and nb > 0
                else [None])
    elif segment_bytes == "off":
        segs = [None]
    else:
        segs = [segment_bytes]

    combos: list[tuple[Tree, str, Any]] = []
    for seg in segs:
        for algo in algos:
            if algo == "tree":
                combos.extend((t, "tree", seg) for t in trees)
            else:
                combos.append((trees[0], algo, seg))

    if len(combos) == 1:
        tree, algo, seg = combos[0]
        if algo != "tree":  # forced algorithm: fail at plan time, curated
            try:
                R.lower(op, algo, tree, build_topo, nb, segment_bytes=seg,
                        members=members, root=root)
            except ValueError as e:
                raise ValueError(
                    f"no candidate of [{algo!r}] lowers op {op!r} on this "
                    f"topology ({e}); drop algorithm= to let the policy "
                    f"fall back to 'tree'") from e
        return PlanChoice(tree, algo, seg, n_built)

    best, best_t = None, math.inf
    for tree, algo, seg in combos:
        try:
            low = R.lower(op, algo, tree, build_topo, nb,
                          segment_bytes=seg, members=members, root=root)
        except ValueError:  # e.g. rsag on non-uniform leaf groups
            continue
        t = max(simulate_rounds(low, topo).values())
        if t < best_t:
            best, best_t = (tree, algo, seg), t
    if best is None:
        # only reachable when a non-"tree" algorithm was explicitly forced
        # and no candidate could lower it on this topology
        raise ValueError(
            f"no candidate of {sorted({a for _, a, _ in combos})} lowers "
            f"op {op!r} on this topology (rsag, e.g., needs uniform "
            f"leaf-group sizes); drop algorithm= to let the policy fall "
            f"back to 'tree'")
    return PlanChoice(best[0], best[1], best[2], n_built)


# ---------------------------------------------------------------------- #
# Plans and the plan cache.
# ---------------------------------------------------------------------- #

class Plan:
    """A cached collective plan: the selected ``tree`` + ``algorithm`` +
    ``segment`` policy, the lazily-built whole-message ``schedule(nbytes)``,
    the lowered rounds IR ``lower(nbytes)`` (both memoised per exact size),
    and the static ppermute ``rounds`` — everything that is pure function of
    (op, root, members, size-bucket) and therefore reusable across calls.

    The pipeline is select → **lower** → execute: selection fixes the plan,
    ``lower(nbytes)`` splits the payload into per-level segments and emits
    the per-rank timed rounds every backend consumes."""

    __slots__ = ("spec", "root", "tree", "algorithm", "segment", "_topo",
                 "_members", "_schedules", "_lowered", "_rounds",
                 "max_nbytes")

    def __init__(self, spec: OpSpec, root: int, tree: Tree,
                 topo: Topology | None = None,
                 members: Sequence[int] | None = None,
                 algorithm: str = "tree", segment: Any = None):
        self.spec = spec
        self.root = root
        self.tree = tree
        self.algorithm = algorithm
        self.segment = segment
        self._topo = topo
        self._members = tuple(members if members is not None
                              else tree.members())
        self._schedules: dict[float, S.Schedule] = {}
        self._lowered: dict[float, R.Lowered] = {}
        self._rounds: list[list[tuple[int, int]]] | None = None
        # largest size this plan ever served — survives the bounded memo
        # clears below; repair() splices at this scale
        self.max_nbytes = 0.0

    @property
    def op(self) -> str:
        return self.spec.name

    def schedule(self, nbytes: float = 0.0) -> S.Schedule:
        key = float(nbytes or 0.0)
        self.max_nbytes = max(self.max_nbytes, key)
        if key not in self._schedules:
            if len(self._schedules) >= 16:  # bound the per-size memo
                self._schedules.clear()
            self._schedules[key] = (self.spec.schedule(self.tree, key)
                                    if self.spec.sized
                                    else self.spec.schedule(self.tree))
        return self._schedules[key]

    def lower(self, nbytes: float = 0.0) -> R.Lowered:
        """The rounds IR for this plan at one exact size: payload split into
        segments (size from the cost model's bandwidth-delay product when
        ``segment == "bdp"``) and emitted as per-rank pipelined rounds."""
        if self._topo is None:
            raise ValueError("plan was built without a topology; "
                             "cannot lower")
        key = float(nbytes or 0.0)
        self.max_nbytes = max(self.max_nbytes, key)
        if key not in self._lowered:
            if len(self._lowered) >= 16:  # bound the per-size memo
                self._lowered.clear()
            self._lowered[key] = R.lower(
                self.op, self.algorithm, self.tree, self._topo, key,
                segment_bytes=self.segment, members=self._members,
                root=self.root)
        return self._lowered[key]

    @property
    def rounds(self) -> list[list[tuple[int, int]]]:
        if self._rounds is None:
            from .tree_exec import tree_rounds  # lazy: pulls in jax
            self._rounds = tree_rounds(self.tree)
        return self._rounds

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Plan(op={self.op!r}, root={self.root}, "
                f"algorithm={self.algorithm!r}, "
                f"|members|={len(self.tree.members())})")


CacheInfo = collections.namedtuple(
    "CacheInfo", ["hits", "misses", "currsize", "maxsize", "tree_builds"])


@dataclasses.dataclass(frozen=True)
class CommStats:
    """Plan-reuse and elasticity counters for one communicator.

    ``hits``/``misses`` are plan-cache lookups; ``evictions`` counts
    CAPACITY evictions only (``refresh``'s wholesale invalidation is a
    deliberate cost-model change, not cache pressure, and is reported by
    its own return value).  ``tree_builds`` is the number of candidate
    trees ever constructed and ``repairs`` the number of
    :meth:`Communicator.repair` calls that removed at least one member —
    together they let the engine and benchmarks *assert* plan reuse
    instead of inferring it from timing.
    """

    hits: int
    misses: int
    evictions: int
    currsize: int
    maxsize: int
    tree_builds: int
    repairs: int


@dataclasses.dataclass(frozen=True)
class RepairReport:
    """Outcome of one :meth:`Communicator.repair` call.

    ``repaired`` plans had their trees spliced in place (no tree rebuild);
    ``evicted`` plans were dropped and will re-plan lazily (dead root, or a
    leaf-group algorithm whose lowering is membership-shaped); ``kept``
    entries did not intersect the failed ranks and were untouched.
    """

    failed: tuple[int, ...]
    members: tuple[int, ...]
    repaired: int
    evicted: int
    kept: int


@dataclasses.dataclass(frozen=True)
class RefreshReport:
    """Outcome of one :meth:`Communicator.refresh` call.  ``drift`` maps
    link-class index -> the measured/modeled time ratio deviating most
    from 1.0 across both probe sizes (see
    :func:`repro.core.discovery.measure_drift`); ``worst`` is the largest
    |ratio - 1|."""

    refreshed: bool
    drift: dict[int, float]
    worst: float


class PlanCache:
    """Tiny LRU keyed by (op, root, size-bucket, members, policy).

    Counters live in a :class:`repro.obs.MetricsRegistry` (the
    communicator's, when owned by one) so cache behaviour shows up in the
    same sink as every other layer's metrics; ``hits``/``misses``/
    ``evictions`` remain plain-int reads, and monotonicity is now enforced
    by the Counter type rather than promised by convention.
    """

    def __init__(self, maxsize: int = 128, *, metrics=None):
        self.maxsize = maxsize
        m = metrics if metrics is not None else MetricsRegistry()
        self._hits = m.counter("comm.cache.hits")
        self._misses = m.counter("comm.cache.misses")
        self._evictions = m.counter("comm.cache.evictions")
        self._d: collections.OrderedDict = collections.OrderedDict()

    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def get_or_build(self, key, build: Callable[[], Plan]) -> Plan:
        if key in self._d:
            self._hits.inc()
            self._d.move_to_end(key)
            return self._d[key]
        self._misses.inc()
        plan = build()
        self._d[key] = plan
        if len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self._evictions.inc()
        return plan

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        self._d.clear()
        self._hits.reset()
        self._misses.reset()
        self._evictions.reset()

    # -- surgical access (elastic repair) ------------------------------- #
    def items(self) -> list[tuple[Any, Plan]]:
        """Snapshot of (key, plan) entries in LRU order, oldest first."""
        return list(self._d.items())

    def pop(self, key) -> Plan | None:
        """Drop one entry (stats untouched); None when absent."""
        return self._d.pop(key, None)

    def put(self, key, plan: Plan) -> None:
        """Insert/overwrite an entry directly — used to re-key repaired
        plans; counts as neither hit nor miss."""
        self._d[key] = plan
        self._d.move_to_end(key)
        if len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self._evictions.inc()

    def invalidate(self) -> None:
        """Drop every entry but keep hit/miss statistics (unlike
        :meth:`clear`) — used when topology refresh voids all plans."""
        self._d.clear()


# ---------------------------------------------------------------------- #
# Backends.
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-rank completion times of one simulated collective."""

    op: str
    root: int
    nbytes: float
    completion: dict[int, float]

    @property
    def time(self) -> float:
        """Wall-clock of the collective: the last rank to finish."""
        return max(self.completion.values())


class SimBackend:
    """Postal-model simulation: operands are byte counts.  Executes the
    plan's lowered rounds IR — segment events with per-send dependencies —
    not the whole-message schedule."""

    name = "sim"
    needs_plan = True

    def __init__(self, comm: "Communicator"):
        self.comm = comm

    def run(self, op: str, plan: Plan, x, root: int) -> SimResult:
        nbytes = float(x) if OPS[op].sized else 0.0
        tr = self.comm.tracer
        if tr is None:
            completion = simulate_rounds(plan.lower(nbytes), self.comm.topo)
            return SimResult(op, root, nbytes, completion)
        self.comm._collective_seq += 1
        label = f"{op}#{self.comm._collective_seq}"
        completion = simulate_rounds(plan.lower(nbytes), self.comm.topo,
                                     tracer=tr, label=label)
        t1 = max(completion.values())
        tr.span(PID_PROGRAMS, label, op, 0.0, t1,
                {"op": op, "root": root, "nbytes": nbytes,
                 "algorithm": plan.algorithm, "segment": plan.segment,
                 "measured_s": t1})
        return SimResult(op, root, nbytes, completion)


class PpermuteBackend:
    """Faithful §3.2 execution: one lax.ppermute per tree round, inside
    shard_map over a single flattened mesh axis (``axis=``).  Root-ful ops
    return zeros on non-root ranks (mirroring MPI out-buffer semantics)."""

    name = "ppermute"
    needs_plan = True

    def __init__(self, comm: "Communicator"):
        if comm.axis is None:
            raise ValueError("backend='ppermute' requires axis=<mesh axis>")
        self.comm = comm
        self.axis = comm.axis

    def run(self, op: str, plan: Plan, x, root: int):
        return getattr(self, op)(plan, x, root)

    # -- ops ----------------------------------------------------------- #
    def bcast(self, plan, x, root):
        from . import tree_exec as TE
        lowered = plan.lower(self.comm._nbytes_of("bcast", x))
        return TE.run_lowered(x, lowered, self.axis,
                              len(self.comm.members))

    def reduce(self, plan, x, root):
        import jax.numpy as jnp
        from jax import lax
        from . import tree_exec as TE
        r = TE.tree_reduce(x, plan.tree, self.axis)
        return jnp.where(lax.axis_index(self.axis) == root, r,
                         jnp.zeros_like(r))

    def allreduce(self, plan, x, root):
        from . import tree_exec as TE
        lowered = plan.lower(self.comm._nbytes_of("allreduce", x))
        return TE.run_lowered(x, lowered, self.axis,
                              len(self.comm.members))

    def gather(self, plan, x, root):
        import jax.numpy as jnp
        from jax import lax
        from . import tree_exec as TE
        buf = TE.tree_gather_flat(x, plan.tree, self.axis,
                                  len(self.comm.members))
        return jnp.where(lax.axis_index(self.axis) == root, buf,
                         jnp.zeros_like(buf))

    def allgather(self, plan, x, root):
        from . import tree_exec as TE
        buf = TE.tree_gather_flat(x, plan.tree, self.axis,
                                  len(self.comm.members))
        return TE.tree_bcast(buf, plan.tree, self.axis)

    def scatter(self, plan, x, root):
        # Root holds the full [P, ...] buffer; ship it down the tree and let
        # each rank slice its row.  (A trimming scatter that sends only each
        # subtree's rows is the simulator-plane model; on-device we accept
        # the bcast-sized payload for a fixed ppermute program.)
        from jax import lax
        from . import tree_exec as TE
        full = TE.tree_bcast(x, plan.tree, self.axis)
        idx = lax.axis_index(self.axis)
        return lax.dynamic_index_in_dim(full, idx, axis=0, keepdims=False)

    def barrier(self, plan, x, root):
        import jax.numpy as jnp
        from . import tree_exec as TE
        token = jnp.zeros((), jnp.float32)
        token = TE.tree_reduce(token, plan.tree, self.axis)
        return TE.tree_bcast(token, plan.tree, self.axis)


class JaxBackend:
    """Axis-decomposed device collectives — the paths where XLA has a
    shortcut.  Runs inside shard_map over ``(slow_axis, *fast_axes)``; the
    rest lower to a single (masked) psum.

    Allreduce consumes the plan's algorithm choice: ``"rsag"`` lowers to
    the reduce-scatter (``psum_scatter``) / exchange / ``all_gather``
    decomposition where the mesh decomposition allows it; ``"tree"`` (the
    small-message winner) lowers to XLA's fused single all-reduce — the
    latency-optimal native path.

    Rank space: flat row-major index over (slow_axis, *fast_axes) ONLY —
    the communicator's topology/members must cover exactly those ranks
    (``launch.mesh.mesh_communicator`` builds the dp-scoped topology for a
    mesh that also has a model axis)."""

    name = "jax"
    needs_plan = True

    def __init__(self, comm: "Communicator"):
        if not comm.fast_axes and comm.slow_axis is None:
            raise ValueError(
                "backend='jax' requires slow_axis= and/or fast_axes=")
        self.comm = comm
        self.slow_axis = comm.slow_axis
        self.fast_axes = tuple(comm.fast_axes)
        self.axes = (((comm.slow_axis,) if comm.slow_axis else ())
                     + self.fast_axes)

    def run(self, op: str, plan, x, root: int):
        if op == "allreduce":
            return self.allreduce(x, root, plan)
        return getattr(self, op)(x, root)

    # -- helpers -------------------------------------------------------- #
    def _index(self):
        """Flat device rank in row-major (slow, *fast) order — matches the
        member ordering of a Topology built over the same mesh."""
        from jax import lax
        idx = 0
        for ax in self.axes:
            idx = idx * lax.psum(1, ax) + lax.axis_index(ax)
        return idx

    def _nranks(self) -> int:
        from jax import lax
        n = 1
        for ax in self.axes:
            n *= int(lax.psum(1, ax))
        return n

    # -- ops ------------------------------------------------------------ #
    def allreduce(self, x, root, plan=None):
        import jax.numpy as jnp
        from jax import lax
        from .collectives import multilevel_psum
        if (plan is not None and plan.algorithm == "tree") \
                or not self.fast_axes:
            return lax.psum(x, self.axes)  # fused: latency-optimal
        fast = 1
        for ax in self.fast_axes:
            fast *= int(lax.psum(1, ax))
        shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.size) % max(fast, 1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        flat = multilevel_psum(flat, self.slow_axis, self.fast_axes)
        if pad:
            flat = flat[:flat.size - pad]
        return flat.reshape(shape)

    def bcast(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        masked = jnp.where(self._index() == root, x, jnp.zeros_like(x))
        return lax.psum(masked, self.axes)

    def reduce(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        full = lax.psum(x, self.axes)
        return jnp.where(self._index() == root, full, jnp.zeros_like(full))

    def gather(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        buf = self._placed(x)
        full = lax.psum(buf, self.axes)
        return jnp.where(self._index() == root, full, jnp.zeros_like(full))

    def allgather(self, x, root):
        from jax import lax
        return lax.psum(self._placed(x), self.axes)

    def scatter(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        masked = jnp.where(self._index() == root, x, jnp.zeros_like(x))
        full = lax.psum(masked, self.axes)
        return lax.dynamic_index_in_dim(full, self._index(), axis=0,
                                        keepdims=False)

    def barrier(self, x, root):
        import jax.numpy as jnp
        from jax import lax
        return lax.psum(jnp.zeros((), jnp.float32), self.axes)

    def _placed(self, x):
        import jax.numpy as jnp
        buf = jnp.zeros((self._nranks(),) + x.shape, x.dtype)
        return buf.at[self._index()].set(x)


BACKENDS: dict[str, type] = {
    "sim": SimBackend,
    "ppermute": PpermuteBackend,
    "jax": JaxBackend,
}


# ---------------------------------------------------------------------- #
# The communicator.
# ---------------------------------------------------------------------- #

class Communicator:
    """Topology-aware collectives behind one object.

    Parameters
    ----------
    topo : the true multilevel topology costs are charged on.
    policy : "paper" | "adaptive" | "oblivious" | "auto" | LevelPolicy.
    backend : "sim" | "jax" | "ppermute" (see module docstring).
    members : participating ranks (default: all of ``topo``).
    view : optional topology the *trees* are built against (MagPIe/oblivious
        baselines) while simulation still charges true per-edge costs.
    algorithm : None (policy decides: "tree" under fixed policies, searched
        under "auto") | "tree" | "sag" | "rsag" | "auto" (force the search).
    segment_bytes : None (policy decides: unsegmented under fixed policies,
        searched under "auto") | "bdp" (bandwidth-delay product) | "off" |
        explicit bytes.  Governs how ``Plan.lower`` splits payloads.
    axis : flattened mesh axis name (ppermute backend).
    slow_axis, fast_axes : mesh axis decomposition (jax backend).
    tracer : optional :class:`repro.obs.Tracer`; when set, every planned
        collective run by the sim backend records per-link busy intervals
        and a span with the selected algorithm × segment and predicted
        cost, and every ``plan()`` call emits a planner instant
        (hit/miss + choice) on the wall-clock track.
    metrics : optional shared :class:`repro.obs.MetricsRegistry`; the
        communicator's counters (``comm.cache.*``, ``comm.tree_builds``,
        ``comm.repairs``) register there.  Default: a private registry —
        communicators never alias each other's stats unless asked to.
    """

    def __init__(self, topo: Topology, *, policy: Any = "auto",
                 backend: str = "sim",
                 members: Sequence[int] | None = None,
                 view: Topology | None = None,
                 algorithm: str | None = None,
                 segment_bytes: Any = None,
                 axis: str | None = None,
                 slow_axis: str | None = None,
                 fast_axes: Sequence[str] = (),
                 cache_size: int = 128,
                 tracer=None,
                 metrics: MetricsRegistry | None = None):
        self.topo = topo
        self.policy = policy
        self.view = view
        self.algorithm = algorithm
        self.segment_bytes = segment_bytes
        self.members = tuple(members if members is not None
                             else range(topo.nprocs))
        if not self.members:
            raise ValueError("communicator needs at least one member")
        self.axis = axis
        self.slow_axis = slow_axis
        self.fast_axes = tuple(fast_axes)
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._tree_builds = self.metrics.counter("comm.tree_builds")
        self._repairs = self.metrics.counter("comm.repairs")
        self._collective_seq = 0
        self._cache = PlanCache(cache_size, metrics=self.metrics)
        try:
            backend_cls = BACKENDS[backend]
        except KeyError:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {sorted(BACKENDS)}") from None
        self.backend = backend_cls(self)

    # Registry-backed counters behind the historical plain-int attributes.
    # Read-only on purpose: external code asserting on these must not be
    # able to rewind them (monotonicity is part of the stats contract).
    @property
    def tree_builds(self) -> int:
        return self._tree_builds.value

    @property
    def repairs(self) -> int:
        return self._repairs.value

    # -- discovery ------------------------------------------------------- #
    @classmethod
    def from_probes(cls, probes, *, gap_factor: float | None = None,
                    path: str | None = None, refresh: bool = False,
                    **kwargs) -> "Communicator":
        """Build a communicator on a topology *discovered* from probes.

        ``probes`` is a :class:`repro.core.discovery.ProbeSet` (from
        :func:`~repro.core.discovery.simulated_probes` or
        :func:`~repro.core.discovery.device_probes`); the probe matrix is
        clustered into strata and per-stratum link classes are fitted —
        see :mod:`repro.core.discovery`.  ``path`` is the Fast-Tuning
        cache: when the file exists (and ``refresh`` is false) the fitted
        topology is loaded from it and the probe matrix is not consulted;
        otherwise the discovered topology is persisted there.  Remaining
        kwargs are the usual constructor knobs (policy/backend/...).
        """
        from . import discovery as D

        if path and not refresh and os.path.exists(path):
            topo = Topology.load(path)
        else:
            gf = (D.DEFAULT_GAP_FACTOR if gap_factor is None
                  else gap_factor)
            topo = D.fit_topology(probes, gap_factor=gf)
            if path:
                topo.save(path)
        return cls(topo, **kwargs)

    # -- planning -------------------------------------------------------- #
    def _size_dependent(self, policy) -> bool:
        """Only searching/adaptive policies (or a searched algorithm)
        choose a different plan per size octave; for the rest, one plan per
        (op, root) serves every message size, so plan() inspection and
        execution always share a cache entry."""
        return (policy in ("adaptive", "auto", "best")
                or self.algorithm == "auto")

    def plan(self, op: str, *, root: int | None = None,
             nbytes: float = 0.0, policy: Any = None) -> Plan:
        """The (cached) plan for one collective.  Key: (op, root,
        size-bucket, members, policy) — a second identical call re-runs
        nothing, and a per-call ``policy=`` override can never be served a
        plan built under a different policy (the override is part of the
        key, not just the build closure)."""
        spec = OPS[op]  # KeyError on unknown op is the dispatch contract
        root = self.members[0] if root is None else root
        if root not in self.members:
            raise ValueError(f"root {root} is not a member")
        policy = self.policy if policy is None else policy
        bucket = (size_bucket(nbytes)
                  if self._size_dependent(policy) and spec.sized else -1)
        # str policies and LevelPolicy (frozen, tuple field) are hashable
        key = (op, root, bucket, self.members, policy)

        def build() -> Plan:
            choice = select_plan(self.topo, root, op, nbytes,
                                 members=self.members,
                                 policy=policy, view=self.view,
                                 algorithm=self.algorithm,
                                 segment_bytes=self.segment_bytes)
            self._tree_builds.inc(choice.n_built)
            return Plan(spec, root, choice.tree,
                        topo=(self.view if self.view is not None
                              else self.topo),
                        members=self.members,
                        algorithm=choice.algorithm, segment=choice.segment)

        if self.tracer is None:
            return self._cache.get_or_build(key, build)
        misses_before = self._cache.misses
        plan = self._cache.get_or_build(key, build)
        hit = self._cache.misses == misses_before
        tr, ts, topo = self.tracer, self.tracer.wall(), self.topo

        def _instant():
            args = {"op": op, "root": root, "nbytes": nbytes, "hit": hit,
                    "algorithm": plan.algorithm, "segment": plan.segment}
            if not hit and spec.sized and nbytes > 0:
                # predicted makespan of the freshly selected plan under
                # the communicator's cost model — the number obs.feedback
                # compares against measured durations.  Deferred: the
                # extra simulation runs at trace-read time.
                args["predicted_s"] = max(
                    simulate_rounds(plan.lower(nbytes), topo).values())
            tr.instant(PID_PLANNER, "plan",
                       f"plan {op} {'hit' if hit else 'miss'}", ts, args)

        tr.defer_record(_instant)
        return plan

    def cache_info(self) -> CacheInfo:
        c = self._cache
        return CacheInfo(c.hits, c.misses, len(c), c.maxsize,
                         self.tree_builds)

    def stats(self) -> CommStats:
        """Plan-reuse counters (:class:`CommStats`): cache hits, misses,
        capacity evictions, tree builds, and repairs — what the async
        engine and the benchmarks assert plan reuse against."""
        c = self._cache
        return CommStats(c.hits, c.misses, c.evictions, len(c), c.maxsize,
                         self.tree_builds, self.repairs)

    def clear_cache(self) -> None:
        self._cache.clear()
        self._tree_builds.reset()

    def verify_plans(self) -> int:
        """Statically verify every cached plan (:mod:`repro.analysis.verify`:
        semantics, byte conservation, dependency DAG, member closure) at
        every payload size it has lowered — or at its largest observed
        traffic when it never lowered.  Returns the number of lowered
        programs checked; raises
        :class:`~repro.analysis.verify.VerificationError` on a plan that
        fails.  :meth:`repair` and :meth:`refresh` run this automatically,
        so a spliced or refitted cache is re-proven before serving traffic.
        """
        from ..analysis.verify import check_lowered  # no load-time cycle

        checked = 0
        for key, plan in self._cache.items():
            op, root, _bucket, _mem, _pol = key
            sizes = sorted(plan._lowered) or [max(plan.max_nbytes, 65536.0)]
            for nb in sizes:
                check_lowered(plan.lower(nb),
                              context=f"cached plan {op}/{plan.algorithm} "
                                      f"root={root} nbytes={nb:g}")
                checked += 1
        return checked

    # -- elasticity: survive failures without a full re-plan ------------- #
    def has_quorum(self, failed: Sequence[int], quorum: float = 0.5) -> bool:
        """True when removing ``failed`` leaves strictly more than
        ``quorum`` of the current membership — the threshold below which
        callers should fall back to checkpoint-restart instead of
        :meth:`repair`.  The rule itself lives in ONE place
        (:func:`repro.runtime.fault_tolerance.has_quorum`; imported lazily
        so the core package keeps no load-time runtime dependency)."""
        from repro.runtime.fault_tolerance import has_quorum

        dead = set(failed) & set(self.members)
        return has_quorum(len(self.members), len(dead), quorum)

    def repair(self, failed: Sequence[int], *,
               verify: bool = True) -> RepairReport:
        """Remove failed ranks and repair the plan cache IN PLACE.

        Every cached plan whose member set intersects ``failed`` is either
        *repaired* — its tree spliced by :func:`~repro.core.trees.repair_tree`
        (orphans reparent onto the cheapest surviving attach point; no tree
        is rebuilt, so ``tree_builds`` does not move) and re-keyed under the
        surviving membership — or *evicted* when it cannot be spliced (its
        root died, or it runs a leaf-group algorithm such as sag/rsag whose
        lowering is shaped by membership) and re-plans lazily on next use.
        Entries whose member sets do not intersect the failed ranks are
        untouched.  Unless ``verify=False``, the surviving cache is then
        re-proven by :meth:`verify_plans` — an in-place splice never gets
        to serve traffic unverified.
        """
        dead = set(failed) & set(self.members)
        survivors = tuple(m for m in self.members if m not in dead)
        if not survivors:
            raise ValueError("repair would leave no members")
        repaired = evicted = kept = 0
        for key, plan in self._cache.items():
            op, root, bucket, key_members, pol = key
            if not set(key_members) & dead:
                kept += 1
                continue
            self._cache.pop(key)
            if root in dead or plan.algorithm != "tree":
                evicted += 1
                continue
            new_members = tuple(m for m in key_members if m not in dead)
            build_topo = self.view if self.view is not None else self.topo
            # splice at the plan's largest executed size (1 MiB floor):
            # the repair cost model must weigh bandwidth, not just
            # latency — repairing too small serializes large transfers,
            # while repairing too large is measurably harmless
            nb = max(plan.max_nbytes, float(1 << 20))
            try:
                tree = repair_tree(plan.tree, build_topo, dead, nbytes=nb)
            except ValueError:
                evicted += 1
                continue
            new_plan = Plan(plan.spec, root, tree, topo=build_topo,
                            members=new_members, algorithm="tree",
                            segment=plan.segment)
            # a later repair (before any intervening collective) must
            # still splice at the true traffic scale
            new_plan.max_nbytes = plan.max_nbytes
            self._cache.put((op, root, bucket, new_members, pol), new_plan)
            repaired += 1
        self.members = survivors
        if dead:
            self._repairs.inc()
            if verify:
                self.verify_plans()
        return RepairReport(tuple(sorted(dead)), survivors,
                            repaired, evicted, kept)

    def refresh(self, probes, *, threshold: float = 0.1,
                verify: bool = True) -> RefreshReport:
        """Fold a targeted drift re-probe into the communicator.

        ``probes`` is a :class:`repro.core.discovery.TargetedProbes` taken
        at :func:`~repro.core.discovery.representative_pairs` of this
        topology — O(strata · group-count) measurements, not the O(P²) of
        full discovery.  When any link class has drifted by more than
        ``threshold`` (worst measured/modeled time ratio over both probe
        sizes), the level parameters are refitted (coordinates — i.e.
        membership and grouping — are untouched) and all cached plans are
        invalidated so the next call re-runs the argmin under the fresh
        costs.  Probe pairs touching non-members (e.g. ranks removed by an
        earlier :meth:`repair` when the pair list was built from the full
        topology) are ignored.
        """
        from . import discovery as D

        if self.view is not None:
            # a view's Level objects were copied at construction from an
            # unknown transform (collapse/flat) of some topology; refitting
            # self.topo alone would leave tree construction on stale costs
            # while claiming success
            raise ValueError(
                "refresh is not supported on a view-based communicator; "
                "rebuild the view from the refitted topology instead")
        members = set(self.members)
        if any(p not in members or q not in members
               for p, q, _ in probes.pairs):
            keep = [i for i, (p, q, _) in enumerate(probes.pairs)
                    if p in members and q in members]
            probes = D.TargetedProbes(
                tuple(probes.pairs[i] for i in keep), probes.sizes,
                probes.times[keep],
                None if probes.inject is None else probes.inject[keep])
        drift = D.measure_drift(self.topo, probes)
        worst = max((abs(r - 1.0) for r in drift.values()), default=0.0)
        if worst <= threshold:
            return RefreshReport(False, drift, worst)
        self.topo = D.refit_levels(self.topo, probes)
        self._cache.invalidate()  # stale costs; stats/counters stay
        if verify:
            # the cache was just invalidated, so this proves "no stale
            # plan survived the refit" rather than re-checking lowerings;
            # plans built later verify on the next repair()/verify_plans()
            self.verify_plans()
        return RefreshReport(True, drift, worst)

    # -- the seven collectives -------------------------------------------- #
    def bcast(self, x, *, root: int = 0):
        return self._run("bcast", x, root)

    def reduce(self, x, *, root: int = 0):
        return self._run("reduce", x, root)

    def barrier(self):
        return self._run("barrier", None, self.members[0])

    def gather(self, x, *, root: int = 0):
        return self._run("gather", x, root)

    def scatter(self, x, *, root: int = 0):
        return self._run("scatter", x, root)

    def allreduce(self, x):
        return self._run("allreduce", x, self.members[0])

    def allgather(self, x):
        return self._run("allgather", x, self.members[0])

    def _run(self, op: str, x, root: int):
        if root not in self.members:  # every backend, planned or not
            raise ValueError(f"root {root} is not a member")
        plan = None
        if self.backend.needs_plan:
            plan = self.plan(op, root=root, nbytes=self._nbytes_of(op, x))
        return self.backend.run(op, plan, x, root)

    def allreduce_tree(self, grads, *, mode: str = "multilevel",
                       mean_over: int | None = None, ef=None,
                       bucket_bytes: float | None = None):
        """All-reduce a gradient pytree (jax backend only): fuses all leaves
        into one flat buffer per level — see collectives.multilevel_psum_tree.

        ``bucket_bytes`` switches to SIZE-TARGETED BUCKETS in reverse leaf
        order (:func:`~repro.core.collectives.bucketed_psum_tree`): one
        collective per bucket instead of one monolithic buffer, so the
        device scheduler can overlap bucket k's sync with the backward of
        the layers below it.  Incompatible with ``ef`` / the compressed
        mode (the residual is shaped by the exchange).

        ``ef`` is the error-feedback residual for
        ``mode="multilevel_compress"`` (build it once with
        :func:`~repro.core.collectives.compress_ef_zeros`); when given the
        call returns ``(grads, new_ef)`` and the residual must be carried
        to the next step — without it the int8 rounding bias accumulates
        across steps."""
        if not isinstance(self.backend, JaxBackend):
            raise ValueError("allreduce_tree requires backend='jax'")
        if bucket_bytes is not None:
            if ef is not None:
                raise ValueError("bucketed sync does not thread an "
                                 "error-feedback residual")
            from .collectives import bucketed_psum_tree
            return bucketed_psum_tree(grads, self.slow_axis, self.fast_axes,
                                      bucket_bytes=bucket_bytes, mode=mode,
                                      mean_over=mean_over)
        from .collectives import multilevel_psum_tree
        return multilevel_psum_tree(grads, self.slow_axis, self.fast_axes,
                                    mode=mode, mean_over=mean_over, ef=ef)

    # -- introspection ----------------------------------------------------- #
    def _nbytes_of(self, op: str, x) -> float:
        """The plan-sizing byte count for one operand.

        PINNED SEMANTICS (plan selection, segment sizing, and the engine's
        bucketing argmin all key off this number):

        * ``bcast`` / ``reduce`` / ``allreduce`` — the full payload every
          rank holds (the schedule ships exactly this many bytes per edge).
        * ``gather`` / ``allgather`` / ``scatter`` — the PER-RANK
          contribution; aggregate traffic grows with subtree sizes
          (``S.gather`` message bytes are ``subtree_size * nbytes``), so
          sizing these by the aggregate would overshoot plan selection by
          a factor of P.

        Numeric operands are that quantity directly.  Device operands
        (arrays/tracers) are sized from the local shard — which IS the
        per-rank contribution for gather/allgather, but for ``scatter``
        the operand is the root's full ``[P, ...]`` buffer, so it is
        divided by the member count to recover the per-rank chunk.
        """
        if not OPS[op].sized or x is None:
            return 0.0
        if isinstance(x, (int, float)):
            return float(x)
        # device operand (tracer or array): bytes of the local shard
        size = 1
        for d in getattr(x, "shape", ()):
            size *= int(d)
        itemsize = getattr(getattr(x, "dtype", None), "itemsize", 4)
        nbytes = float(size * itemsize)
        if op == "scatter":
            nbytes /= max(len(self.members), 1)
        return nbytes

    def slow_crossings(self, op: str, *, root: int = 0,
                       nbytes: float = 0.0) -> int:
        """Edges of the plan's tree that cross the slowest level — the
        paper's headline metric (log C -> C-1 -> 1 wide-area messages)."""
        tree = self.plan(op, root=root, nbytes=nbytes).tree
        return sum(1 for p, cs in tree.children.items() for c in cs
                   if self.topo.comm_level(p, c) == 0)

    def describe(self) -> str:
        lv = "/".join(l.name for l in self.topo.levels)
        pol = (self.policy if isinstance(self.policy, str)
               else type(self.policy).__name__)
        return (f"Communicator(P={len(self.members)}, levels={lv}, "
                f"policy={pol}, backend={self.backend.name})")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self.describe()
