"""Collective-operation schedules over an explicit tree.

Converts a ``Tree`` into a message schedule for each of the paper's five
collectives (Bcast, Reduce, Barrier, Gather, Scatter) plus the training-era
extensions (Allreduce, Allgather, ReduceScatter).  A schedule is a pure data
structure the simulator executes and property tests inspect.

In the plan pipeline (select → lower → execute) this is the WHOLE-MESSAGE
form: one ``Msg`` per tree edge per phase, simulated with per-rank phase
hand-off by :func:`repro.core.simulator.simulate`.  Execution goes through
the segmented rounds IR instead (:mod:`repro.core.rounds`), which splits
these payloads into pipelined per-level segments; the ``Schedule`` form
remains the analytical baseline the IR must converge to as segment size →
nbytes (see tests/test_rounds.py).
"""
from __future__ import annotations

import dataclasses
from enum import Enum

from .trees import Tree

__all__ = ["Direction", "Phase", "Schedule", "bcast", "reduce", "barrier",
           "gather", "scatter", "allreduce", "allgather"]


class Direction(Enum):
    DOWN = "down"  # root -> leaves (bcast, scatter)
    UP = "up"      # leaves -> root (reduce, gather)


@dataclasses.dataclass(frozen=True)
class Msg:
    src: int
    dst: int
    nbytes: float


@dataclasses.dataclass(frozen=True)
class Phase:
    """One tree traversal.  ``msgs[p]`` lists p's outgoing messages in send
    order.  DOWN: node sends after its inbound message arrives.  UP: node
    sends after all its children's messages arrive."""

    tree: Tree
    direction: Direction
    msgs: dict[int, list[Msg]]

    def all_msgs(self) -> list[Msg]:
        return [m for ms in self.msgs.values() for m in ms]


@dataclasses.dataclass(frozen=True)
class Schedule:
    name: str
    phases: tuple[Phase, ...]


# ---------------------------------------------------------------------- #

def _down_phase(tree: Tree, size_of) -> Phase:
    msgs = {
        p: [Msg(p, c, size_of(c)) for c in cs]
        for p, cs in tree.children.items()
    }
    return Phase(tree, Direction.DOWN, msgs)


def _up_phase(tree: Tree, size_of) -> Phase:
    pm = tree.parent_map()
    msgs: dict[int, list[Msg]] = {}
    for c, p in pm.items():
        msgs.setdefault(c, []).append(Msg(c, p, size_of(c)))
    return Phase(tree, Direction.UP, msgs)


def bcast(tree: Tree, nbytes: float) -> Schedule:
    return Schedule("bcast", (_down_phase(tree, lambda c: nbytes),))


def reduce(tree: Tree, nbytes: float) -> Schedule:
    return Schedule("reduce", (_up_phase(tree, lambda c: nbytes),))


def barrier(tree: Tree) -> Schedule:
    # Fan-in then fan-out of zero-byte tokens over the same tree.
    return Schedule(
        "barrier",
        (_up_phase(tree, lambda c: 0.0), _down_phase(tree, lambda c: 0.0)),
    )


def gather(tree: Tree, nbytes: float) -> Schedule:
    sizes = tree.subtree_sizes()
    return Schedule("gather", (_up_phase(tree, lambda c: sizes[c] * nbytes),))


def scatter(tree: Tree, nbytes: float) -> Schedule:
    sizes = tree.subtree_sizes()
    return Schedule("scatter", (_down_phase(tree, lambda c: sizes[c] * nbytes),))


def allreduce(tree: Tree, nbytes: float) -> Schedule:
    """Reduce-to-root then broadcast (the composition the paper's five ops
    support directly; per-level ring reduce-scatter is the JAX-side upgrade)."""
    return Schedule(
        "allreduce",
        (_up_phase(tree, lambda c: nbytes), _down_phase(tree, lambda c: nbytes)),
    )


def allgather(tree: Tree, nbytes: float) -> Schedule:
    sizes = tree.subtree_sizes()
    total = sizes[tree.root] * nbytes
    return Schedule(
        "allgather",
        (_up_phase(tree, lambda c: sizes[c] * nbytes),
         _down_phase(tree, lambda c: total)),
    )
