"""The lowered *rounds IR*: segmented, pipelined collective plans.

``Plan.lower(nbytes)`` turns a selected plan (tree + algorithm + segment
policy) into a flat program of :class:`SegSend` events — the IR every backend
consumes:

* the **simulator** executes the sends under the postal model
  (:func:`repro.core.simulator.simulate_rounds`): per-rank FIFO injection,
  per-send dependencies, so a node forwards segment *k* down the tree while
  segment *k+1* is still in flight toward it — no global barrier between the
  phases of a reduce→bcast allreduce;
* the **ppermute backend** collapses segments and maps the send DAG to
  ``lax.ppermute`` rounds (:meth:`Lowered.device_rounds`,
  :func:`repro.core.tree_exec.run_lowered`);
* the **jax backend** recognises the ``rsag`` algorithm choice and lowers it
  to ``psum_scatter``/``all_gather`` where the mesh decomposition allows.

Three lowering families:

``lower_tree``
    Any registered collective over an explicit tree.  Uniform-payload phases
    (bcast / reduce / allreduce / barrier) are split into segments sized by
    the cost model's bandwidth-delay product; personalised ops (gather /
    scatter / allgather) are pipelined at *chunk* (per-rank payload)
    granularity.
``lower_sag_bcast``
    Bandwidth-optimal large-message broadcast: scatter chunks inside the
    root's leaf group, route each chunk plane along a tree over leaf groups
    (segmented — the WAN hop of one segment overlaps the LAN hop of the
    next), ring-allgather inside every leaf group.  "Ring at the leaf
    stratum, tree above."
``lower_rsag_allreduce``
    Bandwidth-optimal large-message allreduce: ring reduce-scatter inside
    each leaf group, fold chunk planes up the group tree, broadcast them back
    down, ring-allgather inside each leaf group.

:func:`interpret` is the IR's executable semantics — a symbolic interpreter
tracking which ranks' contributions each (rank, chunk, segment) cell holds.
Property tests use it to prove every lowering delivers every byte exactly
once per receiver and folds every contribution exactly once.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .costmodel import MAX_SEGMENTS, MIN_CHUNK_BYTES, pipeline_segment_bytes
from .topology import Topology
from .trees import PAPER_POLICY, Tree, build_multilevel_tree

__all__ = [
    "SegSend",
    "Lowered",
    "lower",
    "lower_tree",
    "lower_sag_bcast",
    "lower_rsag_allreduce",
    "interpret",
    "check_semantics",
]


@dataclasses.dataclass(frozen=True)
class SegSend:
    """One point-to-point transfer of a segment of one payload chunk.

    ``seg`` is the segment index within the chunk, or ``None`` for a send
    carrying the whole chunk (all segments at once — ring steps).  ``deps``
    are indices of earlier sends in the program whose *delivery* must
    complete before this send can be injected (the forwarded data).  A
    rank's sends additionally execute in program order (FIFO NIC).
    ``first`` marks the start of a wire message: only it pays latency and
    sender overhead; later chunks of an aggregated message stream behind it.
    """

    src: int
    dst: int
    nbytes: float
    chunk: int
    seg: int | None
    kind: str  # "copy" | "reduce"
    first: bool
    deps: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Lowered:
    """A lowered plan: the rounds IR for one (op, algorithm, size).

    ``sends`` is topologically ordered (deps point backward) and its
    per-rank subsequences are each rank's injection program.  ``nchunks``
    payload chunks of ``chunk_bytes`` each; segmented chunks split into
    ``nsegs`` equal pieces.  For personalised ops (gather/scatter/allgather)
    chunk ids are member ranks; for bcast/allreduce they are 0..nchunks-1
    contiguous blocks of the flat payload (what device execution reshapes).
    """

    op: str
    algorithm: str
    root: int
    nbytes: float
    members: tuple[int, ...]
    nchunks: int
    chunk_bytes: float
    nsegs: int
    sends: tuple[SegSend, ...]

    def seg_bytes(self) -> float:
        return self.chunk_bytes / self.nsegs

    def device_rounds(self) -> list[list[tuple[int, int, int, str]]]:
        """Segment-collapsed rounds for device execution: each round is a
        list of (src, dst, chunk, kind) edges where every rank sends at most
        one chunk and receives at most one — exactly one ``lax.ppermute``.
        Dependencies and per-sender serialization order the rounds."""
        key_to_id: dict[tuple, int] = {}
        coll: list[tuple[int, int, int, str, set[int]]] = []
        send_coll: list[int] = []
        for s in self.sends:
            key = (s.src, s.dst, s.chunk, s.kind)
            if key not in key_to_id:
                key_to_id[key] = len(coll)
                coll.append((s.src, s.dst, s.chunk, s.kind, set()))
            cid = key_to_id[key]
            send_coll.append(cid)
            coll[cid][4].update(send_coll[d] for d in s.deps)
        rounds: list[tuple[list, set, set]] = []
        assigned: list[int] = []
        for cid, (src, dst, chunk, kind, deps) in enumerate(coll):
            deps.discard(cid)
            r = 1 + max((assigned[d] for d in deps), default=-1)
            while True:
                while r >= len(rounds):
                    rounds.append(([], set(), set()))
                edges, srcs, dsts = rounds[r]
                if src not in srcs and dst not in dsts:
                    break
                r += 1
            edges.append((src, dst, chunk, kind))
            srcs.add(src)
            dsts.add(dst)
            assigned.append(r)
        return [edges for edges, _, _ in rounds if edges]


# ---------------------------------------------------------------------- #
# Lowering entry point (what Plan.lower dispatches through).
# ---------------------------------------------------------------------- #

def lower(op: str, algorithm: str, tree: Tree, topo: Topology,
          nbytes: float, segment_bytes=None,
          members: Sequence[int] | None = None,
          root: int | None = None) -> Lowered:
    """Lower (op, algorithm) to the rounds IR.  ``segment_bytes``: ``None``
    for unsegmented, ``"bdp"`` for the cost model's bandwidth-delay choice,
    or an explicit byte count."""
    members = tuple(members if members is not None else tree.members())
    root = tree.root if root is None else root
    if algorithm == "tree":
        return lower_tree(op, tree, topo, nbytes, segment_bytes)
    if algorithm == "sag" and op == "bcast":
        return lower_sag_bcast(topo, root, members, nbytes, segment_bytes)
    if algorithm == "rsag" and op == "allreduce":
        return lower_rsag_allreduce(topo, members, nbytes, segment_bytes,
                                    root=root)
    raise ValueError(f"no lowering for op={op!r} algorithm={algorithm!r}")


def _resolve_nsegs(topo: Topology, levels_used, nbytes: float,
                   segment_bytes) -> int:
    if segment_bytes is None or nbytes <= 0:
        return 1
    if segment_bytes == "bdp":
        levels = [topo.levels[l] for l in sorted(levels_used)] or \
            list(topo.levels)
        seg = pipeline_segment_bytes(levels, nbytes)
    else:
        seg = max(float(segment_bytes), nbytes / MAX_SEGMENTS)
    return max(1, min(MAX_SEGMENTS, int(math.ceil(nbytes / seg))))


def _edge_levels(tree: Tree, topo: Topology) -> set[int]:
    return {topo.comm_level(p, c)
            for p, cs in tree.children.items() for c in cs}


# ---------------------------------------------------------------------- #
# Tree lowering: any registered op over an explicit tree.
# ---------------------------------------------------------------------- #

def lower_tree(op: str, tree: Tree, topo: Topology, nbytes: float,
               segment_bytes=None) -> Lowered:
    members = tuple(tree.members())
    sends: list[SegSend] = []

    def emit(*args, **kw) -> int:
        sends.append(SegSend(*args, **kw))
        return len(sends) - 1

    pm = tree.parent_map()
    uniform = op in ("bcast", "reduce", "allreduce", "barrier")
    nb = 0.0 if op == "barrier" else float(nbytes)
    if uniform:
        nsegs = _resolve_nsegs(topo, _edge_levels(tree, topo), nb,
                               segment_bytes)
        piece = nb / nsegs
        preorder = members  # Tree.members() is preorder
        post = _postorder(tree)
        up_idx: dict[tuple[int, int], int] = {}

        def up_phase(kind: str):
            for k in range(nsegs):
                for c in post:
                    if c == tree.root:
                        continue
                    deps = tuple(up_idx[(d, k)]
                                 for d in tree.children.get(c, []))
                    up_idx[(c, k)] = emit(c, pm[c], piece, 0, k,
                                          kind, True, deps)

        def down_phase(root_deps=None):
            inbound: dict[tuple[int, int], int] = {}
            for k in range(nsegs):
                for p in preorder:
                    for c in tree.children.get(p, []):
                        if p == tree.root:
                            deps = root_deps(k) if root_deps else ()
                        else:
                            deps = (inbound[(p, k)],)
                        inbound[(c, k)] = emit(p, c, piece, 0, k, "copy",
                                               True, deps)

        if op == "bcast":
            down_phase()
        elif op == "reduce":
            up_phase("reduce")
        else:  # allreduce, barrier: reduce to root, then bcast — the down
            # send of segment k waits only on the ROOT's fold of segment k.
            up_phase("reduce")
            root_cs = tree.children.get(tree.root, [])
            down_phase(lambda k: tuple(up_idx[(c, k)] for c in root_cs))
        return Lowered(op, "tree", tree.root, nb, members, 1, nb, nsegs,
                       tuple(sends))

    # Personalised ops: pipeline at chunk (= per-rank payload) granularity.
    sub = _subtree_orders(tree)
    if op == "gather":
        _chunk_up(tree, pm, sub, nb, emit)
    elif op == "scatter":
        _chunk_down(tree, sub, nb, emit)
    elif op == "allgather":
        up = _chunk_up(tree, pm, sub, nb, emit)
        _chunk_bcast_down(tree, sub, up, nb, emit)
    else:
        raise ValueError(f"no tree lowering for op {op!r}")
    return Lowered(op, "tree", tree.root, nb, members, len(members), nb, 1,
                   tuple(sends))


def _postorder_from(children: dict, root) -> list:
    """Iterative post-order over a children map (deep-chain safe)."""
    out: list = []
    stack: list[tuple] = [(root, False)]
    while stack:
        n, expanded = stack.pop()
        cs = children.get(n, [])
        if cs and not expanded:
            stack.append((n, True))
            stack.extend((c, False) for c in cs)
        else:
            out.append(n)
    return out


def _postorder(tree: Tree) -> list[int]:
    return _postorder_from(tree.children, tree.root)


def _subtree_orders(tree: Tree) -> dict[int, list[int]]:
    """For each node: its subtree's chunks in the order the node ships them
    (own chunk first, then each child's subtree in child order)."""
    orders: dict[int, list[int]] = {}
    for n in _postorder(tree):
        order = [n]
        for c in tree.children.get(n, []):
            order.extend(orders[c])
        orders[n] = order
    return orders


def _chunk_up(tree, pm, sub, nbytes, emit) -> dict[tuple[int, int], int]:
    """Gather flow: each node streams its subtree's chunks to its parent as
    they become available (its own immediately, descendants' on arrival)."""
    up: dict[tuple[int, int], int] = {}
    for c in _postorder(tree):
        if c == tree.root:
            continue
        p = pm[c]
        first = True
        for x in sub[c]:
            deps = () if x == c else (up[(c, x)],)
            up[(p, x)] = emit(c, p, nbytes, x, 0, "copy", first, deps)
            first = False
    return up


def _chunk_down(tree, sub, nbytes, emit) -> None:
    """Trimming scatter: each edge carries exactly the child's subtree
    chunks, forwarded as they arrive from above."""
    down: dict[tuple[int, int], int] = {}
    for p in tree.members():
        for c in tree.children.get(p, []):
            first = True
            for x in sub[c]:
                deps = () if p == tree.root else (down[(p, x)],)
                down[(c, x)] = emit(p, c, nbytes, x, 0, "copy", first, deps)
                first = False


def _chunk_bcast_down(tree, sub, up, nbytes, emit) -> None:
    """Allgather's down sweep: broadcast every chunk down the tree in the
    order the root receives them — chunk x starts down while x+1 is still
    being gathered up.  Edges into a subtree that already holds x (x's own
    up path) are trimmed, so each chunk crosses each stratum once."""
    sub_set = {n: set(order) for n, order in sub.items()}
    started: dict[tuple[int, int], bool] = {}
    down: dict[tuple[int, int], int] = {}
    for x in sub[tree.root]:
        for p in tree.members():
            for c in tree.children.get(p, []):
                if x in sub_set[c]:
                    continue  # c received x on its way up
                if p == x:
                    deps: tuple[int, ...] = ()
                elif x in sub_set[p]:
                    deps = (up[(p, x)],)  # p holds x from the up flow
                else:
                    deps = (down[(p, x)],)
                first = not started.get((p, c), False)
                started[(p, c)] = True
                down[(c, x)] = emit(p, c, nbytes, x, 0, "copy", first, deps)


# ---------------------------------------------------------------------- #
# Leaf-group machinery shared by the bandwidth-optimal algorithms.
# ---------------------------------------------------------------------- #

def _leaf_groups(topo: Topology, members: Sequence[int]) -> list[list[int]]:
    """Members partitioned into leaf groups (finest stratum), in member
    order — the stratum where rings run.  A stratum-less topology (e.g. a
    discovered homogeneous network) is one big leaf group."""
    if topo.nstrata == 0:
        return [list(members)]
    return list(topo.groups_at(list(members), topo.nstrata - 1).values())


def _group_tree(topo: Topology, groups: list[list[int]], root_gi: int,
                root_rep: int) -> tuple[list[tuple[int, int]], dict]:
    """A multilevel tree over one representative per leaf group; returns the
    group-index edges in preorder plus children-of-group map."""
    reps = [root_rep if gi == root_gi else g[0]
            for gi, g in enumerate(groups)]
    gi_of_rep = {r: gi for gi, r in enumerate(reps)}
    if len(reps) == 1:
        return [], {}
    rep_tree = build_multilevel_tree(topo, root_rep, reps, PAPER_POLICY)
    edges = [(gi_of_rep[p], gi_of_rep[c])
             for p in rep_tree.members()
             for c in rep_tree.children.get(p, [])]
    children: dict[int, list[int]] = {}
    for p, c in edges:
        children.setdefault(p, []).append(c)
    return edges, children


# ---------------------------------------------------------------------- #
# Scatter-allgather broadcast.
# ---------------------------------------------------------------------- #

def lower_sag_bcast(topo: Topology, root: int, members: Sequence[int],
                    nbytes: float, segment_bytes=None) -> Lowered:
    """Bandwidth-optimal broadcast: scatter nchunks over the root's leaf
    group, ship each chunk's *plane* along the group tree (one parallel
    slow-link transfer per chunk instead of the whole payload on one edge),
    ring-allgather inside every leaf group."""
    members = tuple(members)
    groups = _leaf_groups(topo, members)
    root_gi = next(gi for gi, g in enumerate(groups) if root in g)
    g0 = groups[root_gi]
    # chunk floor: tiny chunks cannot amortise per-message costs, so small
    # payloads use fewer chunks (down to 1 -> pure group-tree + rings)
    nchunks = max(1, min(len(g0), int(float(nbytes) // MIN_CHUNK_BYTES)))
    chunk = float(nbytes) / nchunks
    edges, _ = _group_tree(topo, groups, root_gi, root)
    lvls = {topo.comm_level(groups[p][0], groups[c][0]) for p, c in edges}
    lvls.add(topo.nstrata)
    nsegs = _resolve_nsegs(topo, lvls, chunk, segment_bytes)
    piece = chunk / nsegs

    sends: list[SegSend] = []

    def emit(*args) -> int:
        sends.append(SegSend(*args))
        return len(sends) - 1

    # Phase 1: scatter within the root's leaf group (flat: distinct data).
    scat: dict[tuple[int, int], int] = {}
    for k in range(nsegs):
        for j in range(nchunks):
            m = g0[j]
            if m != root:
                scat[(j, k)] = emit(root, m, piece, j, k, "copy", True, ())

    # Phase 2: chunk planes along the group tree, segment-pipelined.
    plane: dict[tuple[int, int, int], int] = {}
    for k in range(nsegs):
        for j in range(nchunks):
            for pg, cg in edges:
                src = groups[pg][j % len(groups[pg])]
                dst = groups[cg][j % len(groups[cg])]
                if pg == root_gi:
                    deps = () if src == root else (scat[(j, k)],)
                else:
                    deps = (plane[(pg, j, k)],)
                plane[(cg, j, k)] = emit(src, dst, piece, j, k, "copy",
                                         True, deps)

    # Phase 3: ring allgather inside every leaf group.
    def have(gi: int, j: int) -> tuple[int, ...]:
        if gi == root_gi:
            m = groups[gi][j % len(groups[gi])]
            return () if m == root else tuple(scat[(j, k)]
                                              for k in range(nsegs))
        return tuple(plane[(gi, j, k)] for k in range(nsegs))

    _ring_allgather(groups, nchunks, chunk, have, emit)
    return Lowered("bcast", "sag", root, float(nbytes), members, nchunks,
                   chunk, nsegs, tuple(sends))


def _ring_allgather(groups, nchunks, chunk_bytes, have, emit,
                    kind: str = "copy") -> None:
    """Circulate every chunk around each leaf group's ring; chunk j starts
    at its owner (position j mod group size) once ``have(gi, j)`` delivered
    it there.  Emitted step-major so rings across groups and chunks overlap."""
    prev: dict[tuple[int, int], tuple[int, ...]] = {}
    max_s = max(len(g) for g in groups)
    for t in range(max_s - 1):
        for gi, g in enumerate(groups):
            s = len(g)
            if t >= s - 1:
                continue
            for j in range(nchunks):
                o = j % s
                u, v = g[(o + t) % s], g[(o + t + 1) % s]
                deps = prev.get((gi, j)) if t else have(gi, j)
                prev[(gi, j)] = (emit(u, v, chunk_bytes, j, None, kind,
                                      True, deps or ()),)


# ---------------------------------------------------------------------- #
# Reduce-scatter + allgather allreduce.
# ---------------------------------------------------------------------- #

def lower_rsag_allreduce(topo: Topology, members: Sequence[int],
                         nbytes: float, segment_bytes=None,
                         root: int | None = None) -> Lowered:
    """Bandwidth-optimal allreduce: ring reduce-scatter inside each leaf
    group, fold the chunk planes up the group tree and broadcast them back
    down (segment-pipelined on the slow strata), ring-allgather inside each
    leaf group.  Requires uniform leaf-group sizes (chunk planes must align
    by position); raises ValueError otherwise so callers fall back to the
    tree algorithm."""
    members = tuple(members)
    groups = _leaf_groups(topo, members)
    sizes = {len(g) for g in groups}
    if len(sizes) != 1:
        raise ValueError(
            f"rsag needs uniform leaf-group sizes, got {sorted(sizes)}")
    s = sizes.pop()
    nchunks = s
    chunk = float(nbytes) / nchunks
    root = members[0] if root is None else root
    root_gi = next(gi for gi, g in enumerate(groups) if root in g)
    edges, gkids = _group_tree(topo, groups, root_gi, groups[root_gi][0])
    lvls = {topo.comm_level(groups[p][0], groups[c][0]) for p, c in edges}
    lvls.add(topo.nstrata)
    nsegs = _resolve_nsegs(topo, lvls, chunk, segment_bytes)
    piece = chunk / nsegs

    sends: list[SegSend] = []

    def emit(*args) -> int:
        sends.append(SegSend(*args))
        return len(sends) - 1

    # Phase 1: ring reduce-scatter inside each leaf group.  Chunk j travels
    # g[j+1] -> g[j+2] -> ... -> g[j], folding at every stop.
    rs_final: dict[tuple[int, int], tuple[int, ...]] = {}
    prev: dict[tuple[int, int], tuple[int, ...]] = {}
    for t in range(s - 1):
        for gi, g in enumerate(groups):
            for j in range(nchunks):
                u = g[(j + 1 + t) % s]
                v = g[(j + 2 + t) % s]
                idx = emit(u, v, chunk, j, None, "reduce", True,
                           prev.get((gi, j), ()))
                prev[(gi, j)] = (idx,)
                rs_final[(gi, j)] = (idx,)

    post_g = _postorder_from(gkids, root_gi)
    gparent = {c: p for p, c in edges}

    # Phase 2: fold chunk planes up the group tree (segmented).
    up: dict[tuple[int, int, int], int] = {}
    for k in range(nsegs):
        for j in range(nchunks):
            for cg in post_g:
                if cg == root_gi:
                    continue
                pg = gparent[cg]
                deps = rs_final.get((cg, j), ()) + tuple(
                    up[(d, j, k)] for d in gkids.get(cg, []))
                up[(cg, j, k)] = emit(groups[cg][j], groups[pg][j], piece,
                                      j, k, "reduce", True, deps)

    # Phase 3: broadcast the folded planes back down.  The down send of
    # segment k leaves as soon as the plane root has folded segment k.
    down: dict[tuple[int, int, int], int] = {}
    for k in range(nsegs):
        for j in range(nchunks):
            for pg, cg in edges:
                if pg == root_gi:
                    deps = rs_final.get((root_gi, j), ()) + tuple(
                        up[(d, j, k)] for d in gkids.get(root_gi, []))
                else:
                    deps = (down[(pg, j, k)],)
                down[(cg, j, k)] = emit(groups[pg][j], groups[cg][j], piece,
                                        j, k, "copy", True, deps)

    # Phase 4: ring allgather inside each leaf group.
    def have(gi: int, j: int) -> tuple[int, ...]:
        if gi == root_gi:
            return rs_final.get((gi, j), ()) + tuple(
                up[(d, j, k)] for d in gkids.get(gi, [])
                for k in range(nsegs))
        return tuple(down[(gi, j, k)] for k in range(nsegs))

    _ring_allgather(groups, nchunks, chunk, have, emit)
    return Lowered("allreduce", "rsag", root, float(nbytes), members,
                   nchunks, chunk, nsegs, tuple(sends))


# ---------------------------------------------------------------------- #
# Executable semantics: the symbolic interpreter.
# ---------------------------------------------------------------------- #

_INIT_HOLDINGS = {
    # op -> which (rank, chunk) cells start populated, and with what.
    "bcast": "root_all",      # root holds every chunk (value {root})
    "scatter": "root_all",
    "reduce": "everyone_all",  # every rank holds every chunk as {rank}
    "allreduce": "everyone_all",
    "barrier": "everyone_all",
    "gather": "own",           # rank r holds chunk r as {r}
    "allgather": "own",
}


def interpret(lowered: Lowered) -> dict:
    """Execute the IR symbolically.  Each (rank, chunk, seg) cell holds a
    frozenset of member ranks whose contribution it contains.  Raises
    ValueError on: sending data the source does not hold, folding a
    contribution twice, or delivering a copy to the same cell twice.
    Returns the final state as {(rank, chunk): [set per seg]}."""
    members = lowered.members
    nsegs = lowered.nsegs
    state: dict[tuple[int, int], list] = {}
    mode = _INIT_HOLDINGS[lowered.op]
    if mode == "root_all":
        chunks = (range(lowered.nchunks) if lowered.op == "bcast"
                  else members)
        for x in chunks:
            state[(lowered.root, x)] = [frozenset([lowered.root])] * nsegs
    elif mode == "everyone_all":
        for r in members:
            for x in range(lowered.nchunks):
                state[(r, x)] = [frozenset([r])] * nsegs
    else:  # own
        for r in members:
            state[(r, r)] = [frozenset([r])] * nsegs

    copies: dict[tuple[int, int, int], int] = {}
    for i, snd in enumerate(lowered.sends):
        src_cell = state.get((snd.src, snd.chunk))
        segs = range(nsegs) if snd.seg is None else (snd.seg,)
        dst_cell = state.setdefault((snd.dst, snd.chunk), [None] * nsegs)
        for k in segs:
            if src_cell is None or src_cell[k] is None:
                raise ValueError(
                    f"send #{i} {snd}: source holds no data for "
                    f"chunk {snd.chunk} seg {k}")
            carried = src_cell[k]
            if snd.kind == "reduce":
                cur = dst_cell[k] or frozenset()
                if cur & carried:
                    raise ValueError(
                        f"send #{i} {snd}: contributions {sorted(cur & carried)} "
                        f"folded twice at rank {snd.dst}")
                dst_cell[k] = cur | carried
            else:
                n = copies.get((snd.dst, snd.chunk, k), 0) + 1
                if n > 1:
                    raise ValueError(
                        f"send #{i} {snd}: chunk {snd.chunk} seg {k} "
                        f"delivered to rank {snd.dst} more than once")
                copies[(snd.dst, snd.chunk, k)] = n
                dst_cell[k] = carried
    return state


def check_semantics(lowered: Lowered) -> None:
    """Assert the lowering computes its op: run :func:`interpret` and check
    the op's final-state contract.  Raises ValueError on any violation."""
    state = interpret(lowered)
    members = lowered.members
    full = frozenset(members)
    root = lowered.root

    def expect(rank, chunk, want, what):
        cell = state.get((rank, chunk))
        for k in range(lowered.nsegs):
            got = cell[k] if cell else None
            if got != want:
                raise ValueError(
                    f"{lowered.op}/{lowered.algorithm}: {what}: rank {rank} "
                    f"chunk {chunk} seg {k} holds {got}, want {want}")

    op = lowered.op
    if op == "bcast":
        for r in members:
            for x in range(lowered.nchunks):
                expect(r, x, frozenset([root]), "every rank gets the payload")
    elif op == "reduce":
        expect(root, 0, full, "root folds every contribution")
    elif op in ("allreduce", "barrier"):
        for r in members:
            for x in range(lowered.nchunks):
                expect(r, x, full, "every rank gets the full fold")
    elif op == "gather":
        for m in members:
            expect(root, m, frozenset([m]), "root gets every member's chunk")
    elif op == "scatter":
        for m in members:
            expect(m, m, frozenset([root]), "each member gets its chunk")
    elif op == "allgather":
        for r in members:
            for m in members:
                expect(r, m, frozenset([m]), "every rank gets every chunk")
    else:  # pragma: no cover - future ops must add a contract
        raise ValueError(f"no semantic contract for op {op!r}")

    if op in ("gather", "scatter"):
        # Routing minimality for the single-consumer personalised ops: the
        # final-state contract above inspects only the terminal cells, so a
        # *leaked* extra copy of chunk x to a bystander rank would pass it
        # (delivered-once is per receiver, not per chunk).  Statically,
        # chunk x's copy sends must form a simple relay path: every rank
        # that receives x and is not its terminal consumer (gather: the
        # root; scatter: rank x itself) forwards it exactly once, and the
        # terminal never forwards it.
        fwd: dict[tuple[int, int], int] = {}
        recv: dict[int, set[int]] = {}
        for snd in lowered.sends:
            if snd.kind != "copy":
                continue
            fwd[(snd.src, snd.chunk)] = fwd.get((snd.src, snd.chunk), 0) + 1
            recv.setdefault(snd.chunk, set()).add(snd.dst)
        for x, dsts in sorted(recv.items()):
            terminal = root if op == "gather" else x
            for r in sorted(dsts):
                want = 0 if r == terminal else 1
                got = fwd.get((r, x), 0)
                if got != want:
                    raise ValueError(
                        f"{lowered.op}/{lowered.algorithm}: chunk routing: "
                        f"rank {r} received chunk {x} and forwarded it "
                        f"{got}x, want {want} "
                        f"({'terminal consumer' if want == 0 else 'relay'})")
