"""Tree builders: flat, binomial, chain, postal-optimal — and the paper's
MULTILEVEL composer.

A tree is represented explicitly (paper §3.2 replaced hidden communicators
with integer vectors precisely to gain this freedom): ``Tree`` maps each rank
to an *ordered* list of children.  Children order matters under the postal
model — a parent injects messages sequentially, so larger subtrees are served
first.

This module is the tree-construction ENGINE; user code should go through
:class:`repro.core.communicator.Communicator`, which selects, caches, and
executes trees behind one API.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from .topology import Topology

__all__ = [
    "Tree",
    "flat_tree",
    "binomial_tree",
    "chain_tree",
    "postal_tree",
    "build_multilevel_tree",
    "repair_tree",
    "LevelPolicy",
    "PAPER_POLICY",
]


@dataclasses.dataclass
class Tree:
    root: int
    children: dict[int, list[int]]  # rank -> ordered children

    # ------------------------------------------------------------------ #
    def members(self) -> list[int]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(reversed(self.children.get(n, [])))
        return out

    def parent_map(self) -> dict[int, int]:
        return {c: p for p, cs in self.children.items() for c in cs}

    def subtree_sizes(self) -> dict[int, int]:
        # Iterative post-order: chains of 10k+ ranks must not hit the
        # Python recursion limit.
        sizes: dict[int, int] = {}
        stack: list[tuple[int, bool]] = [(self.root, False)]
        while stack:
            n, expanded = stack.pop()
            cs = self.children.get(n, [])
            if cs and not expanded:
                stack.append((n, True))
                stack.extend((c, False) for c in cs)
            else:
                sizes[n] = 1 + sum(sizes[c] for c in cs)
        return sizes

    def depth(self) -> int:
        best = 0
        stack: list[tuple[int, int]] = [(self.root, 0)]
        while stack:
            n, d = stack.pop()
            best = max(best, d)
            stack.extend((c, d + 1) for c in self.children.get(n, []))
        return best

    def validate(self) -> None:
        """Spanning-tree invariants; raises ValueError on violation (real
        exceptions, not `assert` — they must survive ``python -O``).

        Traverses with a seen-set rather than ``members()`` so that cyclic
        children maps are reported as errors instead of looping forever.
        """
        seen: set[int] = set()
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n in seen:
                raise ValueError(
                    f"invalid tree: rank {n} reachable twice from root "
                    f"{self.root} (duplicate child or cycle)")
            seen.add(n)
            stack.extend(self.children.get(n, []))
        pm = self.parent_map()
        if self.root in pm:
            raise ValueError(f"invalid tree: root {self.root} has a parent")
        if set(pm) | {self.root} != seen:
            raise ValueError("invalid tree: parent map does not cover "
                             "exactly the reachable ranks")


# ---------------------------------------------------------------------- #
# Single-level builders.  All take (root, members) where members includes
# the root, and are deterministic in the order of `members`.
# ---------------------------------------------------------------------- #

def _rotate(root: int, members: Sequence[int]) -> list[int]:
    """members with root first, preserving relative order of the rest."""
    rest = [m for m in members if m != root]
    if len(rest) == len(members):
        raise ValueError("root not in members")
    return [root] + rest


def flat_tree(root: int, members: Sequence[int]) -> Tree:
    """Root sends directly to everyone — optimal on high-latency links
    (Bar-Noy & Kipnis), used by the paper at the wide-area level."""
    order = _rotate(root, members)
    return Tree(root, {root: order[1:]})


def binomial_tree(root: int, members: Sequence[int]) -> Tree:
    """Classic binomial tree B_k over n ranks; the i-th child of the root is
    the root of B_{k-i} (largest subtree served first)."""
    order = _rotate(root, members)
    n = len(order)
    children: dict[int, list[int]] = {m: [] for m in order}
    # In round r, node i (< 2^r) sends to i + 2^r.  Natural round order IS
    # largest-subtree-first: a child acquired earlier has more remaining
    # rounds to fan out (paper's B_k: the i-th child roots B_{k-i}).
    r = 0
    while (1 << r) < n:
        for i in range(min(1 << r, n - (1 << r))):
            children[order[i]].append(order[i + (1 << r)])
        r += 1
    return Tree(root, {m: cs for m, cs in children.items() if cs})


def chain_tree(root: int, members: Sequence[int]) -> Tree:
    """Pipeline chain — optimal for very large segmented messages."""
    order = _rotate(root, members)
    return Tree(root, {order[i]: [order[i + 1]] for i in range(len(order) - 1)})


def postal_tree(root: int, members: Sequence[int], lam: int = 2) -> Tree:
    """Bar-Noy & Kipnis postal-model optimal tree for integer latency ``lam``
    (in units of sender overhead).  lam=1 degenerates to the binomial tree;
    large lam approaches the flat tree.

    N(t) = N(t-1) + N(t-lam): a node that finishes receiving at time T can
    start new sends at T, T+1, ...; each lands lam later.
    """
    lam = max(1, int(lam))
    order = _rotate(root, members)
    n = len(order)
    if n == 1:
        return Tree(root, {})
    # Find minimal completion time t with N(t) >= n.
    N = [1]
    while N[-1] < n:
        t = len(N)
        N.append(N[t - 1] + (N[t - lam] if t - lam >= 0 else 1))

    children: dict[int, list[int]] = {m: [] for m in order}
    next_free = 1  # next unassigned index in `order`

    def grow(node_idx: int, recv_time: int, deadline: int) -> None:
        nonlocal next_free
        t = recv_time
        while t + lam <= deadline and next_free < n:
            child = next_free
            next_free += 1
            children[order[node_idx]].append(order[child])
            grow(child, t + lam, deadline)
            t += 1

    grow(0, 0, len(N) - 1)
    # Any stragglers (rounding) hang off the root, flat.
    while next_free < n:
        children[order[0]].append(order[next_free])
        next_free += 1
    return Tree(root, {m: cs for m, cs in children.items() if cs})


BUILDERS: dict[str, Callable[[int, Sequence[int]], Tree]] = {
    "flat": flat_tree,
    "binomial": binomial_tree,
    "chain": chain_tree,
    "postal": postal_tree,
}


# ---------------------------------------------------------------------- #
# The paper's multilevel composer (§2.3, §3.2).
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class LevelPolicy:
    """Tree shape per level: shapes[l] for the inter-group tree at stratum l,
    shapes[-1] for the leaf level.  Paper's choice: flat at the wide-area
    level, binomial below (§3.2).  Shapes may carry a postal parameter, e.g.
    "postal:3"."""

    shapes: tuple[str, ...]

    def builder(self, level: int) -> Callable[[int, Sequence[int]], Tree]:
        shape = self.shapes[min(level, len(self.shapes) - 1)]
        if shape.startswith("postal:"):
            lam = int(shape.split(":")[1])
            return lambda r, m: postal_tree(r, m, lam=lam)
        return BUILDERS[shape]


PAPER_POLICY = LevelPolicy(("flat", "binomial", "binomial"))
ALL_BINOMIAL = LevelPolicy(("binomial",))


def adaptive_policy(topo, nbytes: float) -> LevelPolicy:
    """Beyond-paper (the paper's §6 future work): pick each level's tree
    shape from the Bar-Noy & Kipnis latency ratio of that level's links.

    lambda_l = (full message time) / (sender occupancy) — when a sender can
    inject many messages before the first lands, flat trees win (pipelined
    latency); when injection is as expensive as delivery (bandwidth-bound or
    intra-machine), binomial wins; in between, the postal tree with
    parameter round(lambda) is optimal.
    """
    shapes = []
    for lvl in topo.levels:
        xfer = lvl.latency + nbytes / lvl.bandwidth
        occupy = max(lvl.occupy(nbytes), 1e-12)
        lam = xfer / occupy
        if lam <= 1.5:
            shapes.append("binomial")
        elif lam >= 64:
            shapes.append("flat")
        else:
            shapes.append(f"postal:{max(2, int(round(lam)))}")
    return LevelPolicy(tuple(shapes))


def best_tree(topo, root: int, op_name: str, nbytes: float,
              members: Sequence[int] | None = None) -> Tree:
    """DEPRECATED shim — use ``Communicator(topo, policy="auto")`` instead.

    The cost-model argmin (and the op dispatch table that used to live here
    as a string-keyed dict) moved to :mod:`repro.core.communicator`, where
    plans are also cached across calls — and where selection now covers
    {tree shape} x {algorithm} x {segment size}, not just the tree
    (``select_plan`` / the ``algorithm=``/``segment_bytes=`` knobs).  This
    shim returns only the tree leg of that choice.  The repo's test suite
    escalates this warning to an error (pytest.ini), so in-tree callers
    cannot silently stay on it.
    """
    import warnings

    warnings.warn(
        "trees.best_tree is deprecated; use "
        "repro.core.Communicator(topo, policy='auto').plan(op, ...).tree "
        "(plans now also carry the algorithm and segment-size choice)",
        DeprecationWarning, stacklevel=2)
    from .communicator import select_tree

    tree, _ = select_tree(topo, root, op_name, nbytes,
                          members=members, policy="auto")
    return tree


def build_multilevel_tree(
    topo: Topology,
    root: int,
    members: Sequence[int] | None = None,
    policy: LevelPolicy = PAPER_POLICY,
) -> Tree:
    """Construct the multilevel topology-aware tree, deterministically.

    Mirrors MPICH-G2: cluster at the coarsest stratum, pick one coordinator
    per group (the root's group keeps the root; other groups use their first
    member in rank order), build the inter-group tree over coordinators with
    the level's shape, then recurse within each group.  At a node, slow-level
    children are served before fast-level children (root sends across the WAN
    first — Fig. 4).
    """
    if members is None:
        members = list(range(topo.nprocs))
    members = list(members)
    if root not in members:
        raise ValueError("root must be a member")

    def rec(root: int, members: list[int], stratum: int) -> Tree:
        if len(members) == 1:
            return Tree(root, {})
        if stratum == topo.nstrata:
            return policy.builder(stratum)(root, members)
        groups = topo.groups_at(members, stratum)
        if len(groups) == 1:
            return rec(root, members, stratum + 1)
        coordinators = []
        root_gid = int(topo.coords[root, stratum])
        for gid, gmembers in groups.items():
            coordinators.append(root if gid == root_gid else gmembers[0])
        inter = policy.builder(stratum)(root, coordinators)
        # Recurse inside every group and graft under its coordinator.
        children: dict[int, list[int]] = {}
        for gid, gmembers in groups.items():
            coord = root if gid == root_gid else gmembers[0]
            sub = rec(coord, gmembers, stratum + 1)
            for p, cs in sub.children.items():
                children.setdefault(p, []).extend(cs)
        # Prepend inter-group (slow) children so they are served first.
        for p, cs in inter.children.items():
            children[p] = cs + children.get(p, [])
        return Tree(root, children)

    tree = rec(root, members, 0)
    tree.validate()
    return tree


# ---------------------------------------------------------------------- #
# Elastic repair: splice failed ranks out of an existing tree.
# ---------------------------------------------------------------------- #

def repair_tree(tree: Tree, topo: Topology, failed, nbytes: float = 0.0) -> Tree:
    """Remove ``failed`` ranks from ``tree`` without rebuilding it.

    Dead nodes are spliced out in preorder (dead ancestors before their
    dead descendants).  At each splice the dead node's *deputy* — the
    surviving child sharing its finest stratum (a dead coordinator's
    stand-in from its own group), ties broken by cheapest edge to the
    parent — is promoted into the dead node's exact service slot, so the
    repaired tree keeps the same slow-link structure the builder would
    choose from scratch.  The remaining orphaned subtrees reparent onto
    the cheapest surviving attach point under the postal cost model —
    estimated payload *arrival* at the orphan: the candidate's own
    root-to-node path time, plus the injection occupancy of the children
    the candidate serves first, plus the new edge's transfer.  Pricing
    arrivals (not just edges) balances width against depth: it spreads
    equal-distance orphans across NICs and refuses to hang a large
    subtree below an already-late node.  Candidates are the promoted
    deputy, the lost parent's ancestor
    chain, the surviving children of that chain (the orphan's "uncles" —
    what lets it rejoin a same-stratum subtree instead of paying its own
    slow crossing), and orphan siblings already re-attached in this
    splice.  Children lists stay ordered so slower-level subtrees keep
    being served first (Fig. 4's rule survives the splice).

    Raises ``ValueError`` when the root itself failed (the plan's root is
    semantic; the caller must re-plan) or when no member survives.
    """
    dead = set(failed) & set(tree.members())
    if tree.root in dead:
        raise ValueError(f"cannot repair: root {tree.root} failed")
    children = {p: list(cs) for p, cs in tree.children.items()}
    parent = {c: p for p, cs in children.items() for c in cs}
    if not dead:
        return Tree(tree.root, {p: cs for p, cs in children.items() if cs})

    def occupy(a: int, upto_level: int) -> float:
        """a's injection occupancy for the children served at or before a
        new child of class ``upto_level`` (slow-first service order)."""
        return sum(topo.levels[topo.comm_level(a, x)].occupy(nbytes)
                   for x in children.get(a, [])
                   if topo.comm_level(a, x) <= upto_level)

    def est_ready(a: int) -> float:
        """Postal estimate of when ``a`` holds the payload: queue + xfer
        along its current root path (root is ready at 0)."""
        path = [a]
        while path[-1] in parent:
            path.append(parent[path[-1]])
        t = 0.0
        for node, y in zip(path[::-1], path[-2::-1]):
            lvl = topo.comm_level(node, y)
            idx = children[node].index(y)
            t += sum(topo.levels[topo.comm_level(node, x)].occupy(nbytes)
                     for x in children[node][:idx])
            t += topo.levels[lvl].xfer(nbytes)
        return t

    def cost(a: int, b: int) -> float:
        """Estimated arrival of the payload at ``b`` if attached under
        ``a`` (appended after a's same-or-slower-level children)."""
        lvl = topo.comm_level(a, b)
        return (est_ready(a) + occupy(a, lvl)
                + topo.levels[lvl].xfer(nbytes))

    for d in tree.members():  # preorder: parents before children
        if d not in dead:
            continue
        # d's current parent (and its whole chain) is alive: dead original
        # ancestors were spliced earlier in preorder, and re-attachment
        # only ever targets live nodes
        p, orphans = parent[d], children.pop(d, [])
        slot = children[p].index(d)
        children[p].pop(slot)
        del parent[d]
        chain = [p] + _ancestors(parent, p)
        # uncles root subtrees disjoint from d's, so attaching an orphan
        # (a subtree of d's) under one can never form a cycle
        cands = chain + [c for a in chain for c in children.get(a, [])
                         if c not in dead]
        live = [c for c in orphans if c not in dead]
        if live:
            deputy = min(live, key=lambda c: (-topo.comm_level(d, c),
                                              cost(p, c)))
            orphans.remove(deputy)
            children[p].insert(slot, deputy)
            parent[deputy] = p
            cands.insert(0, deputy)
        for c in orphans:
            best = min(cands, key=lambda a: cost(a, c))
            lvl = topo.comm_level(best, c)
            cs = children.setdefault(best, [])
            pos = sum(1 for x in cs if topo.comm_level(best, x) <= lvl)
            cs.insert(pos, c)
            parent[c] = best
            if c not in dead:  # a dead orphan is spliced on its own visit
                cands.append(c)
    out = Tree(tree.root, {p: cs for p, cs in children.items() if cs})
    out.validate()
    return out


def _ancestors(parent: dict[int, int], n: int) -> list[int]:
    out = []
    while n in parent:
        n = parent[n]
        out.append(n)
    return out
