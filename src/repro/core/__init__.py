"""Topology-aware collective operations (the paper's system plane).

Public API — one front door:

  :class:`Communicator`   build once per (topology, policy, backend), then
                          call ``bcast/reduce/barrier/gather/scatter/
                          allreduce/allgather``; plans are cached.

Supporting vocabulary re-exported for construction and inspection:
topologies (:class:`Topology` + canned grids), tree builders and policies,
the op dispatch table, and simulation results.

The heavier device modules (:mod:`repro.core.collectives`,
:mod:`repro.core.tree_exec`) import jax and are pulled in lazily by the
``jax``/``ppermute`` backends — importing :mod:`repro.core` stays light for
simulator-only use.
"""
from .communicator import (BACKENDS, CacheInfo, CommStats, Communicator,
                           OPS, OpSpec, Plan, PlanCache, PlanChoice,
                           RefreshReport, RepairReport, SimResult,
                           register_op, select_plan, select_tree,
                           size_bucket)
from .engine import (Engine, EngineStats, Handle, overlapped_step_times,
                     partition_buckets)
from .discovery import (ProbeSet, TargetedProbes, cluster_probes,
                        device_probes, discover, environment_topology,
                        fit_levels, fit_topology, measure_drift,
                        refit_levels, representative_pairs,
                        simulated_probes, targeted_probes)
from .rounds import Lowered, SegSend
from .topology import (Level, Topology, flat_view, magpie_machine_view,
                       magpie_site_view, paper_fig8_topology,
                       tpu_v5e_multipod)
from .trees import (LevelPolicy, PAPER_POLICY, Tree, adaptive_policy,
                    binomial_tree, build_multilevel_tree, chain_tree,
                    flat_tree, postal_tree, repair_tree)

__all__ = [
    # the front door
    "Communicator", "Plan", "PlanCache", "PlanChoice", "SimResult",
    "CacheInfo", "CommStats", "RepairReport", "RefreshReport",
    # the async engine (nonblocking handles + concurrent scheduling)
    "Engine", "EngineStats", "Handle", "partition_buckets",
    "overlapped_step_times",
    # topology discovery (probe -> cluster -> fit)
    "ProbeSet", "simulated_probes", "environment_topology", "device_probes",
    "cluster_probes", "fit_levels", "fit_topology", "discover",
    # elastic refresh (targeted re-probe -> drift -> refit)
    "TargetedProbes", "representative_pairs", "targeted_probes",
    "measure_drift", "refit_levels",
    # the rounds IR (select -> lower -> execute)
    "Lowered", "SegSend",
    # op dispatch
    "OPS", "OpSpec", "register_op", "select_plan", "select_tree",
    "size_bucket", "BACKENDS",
    # topology
    "Topology", "Level", "paper_fig8_topology", "tpu_v5e_multipod",
    "magpie_machine_view", "magpie_site_view", "flat_view",
    # trees & policies
    "Tree", "LevelPolicy", "PAPER_POLICY", "adaptive_policy",
    "binomial_tree", "build_multilevel_tree", "chain_tree", "flat_tree",
    "postal_tree", "repair_tree",
]
