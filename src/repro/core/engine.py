"""The async collective engine: nonblocking handles over the plan machinery.

The paper's trees minimise the cost of ONE collective; this module is the
subsystem that issues, orders, and overlaps MANY.  A training step does not
run one monolithic gradient all-reduce after the full backward pass — it
streams size-targeted buckets into the network while backward is still
producing gradients, and a serving host runs several requests' collectives
at once.  Both need three things the :class:`~repro.core.Communicator`
alone does not give:

**Handles** — ``engine.issue(op, nbytes, ...) -> Handle`` returns
immediately; ``handle.wait()`` / ``engine.wait_all()`` resolve.  Legal
interleavings are enforced, not assumed: collectives on the SAME member set
execute in issue order (the MPI same-communicator rule — every rank must
see the same sequence), and explicit cross-set orderings are declared with
``after=``.

**Contention-aware costing** — a batch of live handles is priced by
:func:`repro.core.simulator.simulate_concurrent`: per-link fair bandwidth
sharing, so two plans crossing the same WAN edge slow each other down
exactly as far as the fluid postal model says they must.

**Scheduler policies** — per issue-batch:

``"fifo"``
    Every handle released at its ready time; concurrent handles share
    links fairly.
``"priority"``
    Strict-priority link arbitration: small/latency-bound collectives
    (default priority ``-nbytes``) preempt fat transfers on shared links
    instead of halving their bandwidth for the fat transfer's whole
    lifetime.  ``age_rate`` bounds starvation: a preempted transfer's
    effective priority rises by ``age_rate`` per second of waiting (from
    its release time), so a fat broadcast under a sustained stream of
    small high-priority ops eventually outranks newly released ones and
    completes — strict priority would starve it for the stream's whole
    lifetime.
``"sim"``
    Candidate orderings (fair, priority, serial issue-order, serial
    shortest-first) are each simulated under contention and the argmin
    makespan wins — the engine *measures* instead of guessing.

The bucketing helpers at the bottom (:func:`partition_buckets`,
:func:`overlapped_step_times`) model the bucketed, overlapped gradient
sync: backward produces per-layer gradients in reverse-layer order; each
size-targeted bucket is issued the moment its last layer's gradient
exists, so the all-reduce of bucket k rides under the backward compute of
the layers below it.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Sequence

from ..obs.metrics import MetricsRegistry
from ..obs.trace import PID_PROGRAMS
from .communicator import OPS, Communicator, SimResult
from .simulator import simulate_concurrent, simulate_rounds

__all__ = ["Handle", "Engine", "EngineStats", "POLICIES",
           "partition_buckets", "overlapped_step_times"]

POLICIES = ("fifo", "priority", "sim")


class Handle:
    """One in-flight collective.  Created by :meth:`Engine.issue`; resolved
    by :meth:`wait` (which flushes the engine's pending batch).

    ``started``/``finished`` are simulation-clock times; ``result`` is the
    :class:`~repro.core.SimResult` with per-rank completion times."""

    __slots__ = ("engine", "hid", "op", "root", "nbytes", "members", "at",
                 "after", "priority", "result", "started", "finished")

    def __init__(self, engine: "Engine", hid: int, op: str, root: int,
                 nbytes: float, members: tuple[int, ...], at: float,
                 after: tuple["Handle", ...], priority: float | None):
        self.engine = engine
        self.hid = hid
        self.op = op
        self.root = root
        self.nbytes = nbytes
        self.members = members
        self.at = at
        self.after = after
        self.priority = priority
        self.result: SimResult | None = None
        self.started: float | None = None
        self.finished: float | None = None

    @property
    def done(self) -> bool:
        return self.result is not None

    def wait(self) -> SimResult:
        """Resolve this handle (flushes every pending handle — the batch is
        scheduled as a whole; see :meth:`Engine.wait_all`)."""
        if self.result is None:
            self.engine._flush()
        if self.result is None:  # pragma: no cover - flush resolves batch
            raise RuntimeError(
                f"handle #{self.hid} still unresolved after flush")
        return self.result

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self.done else "pending"
        return (f"Handle#{self.hid}({self.op}, {self.nbytes:.0f}B, "
                f"|members|={len(self.members)}, {state})")


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """Engine-side counters.  Plan-level reuse lives on the communicator:
    ``engine.comm.stats()`` (see :meth:`~repro.core.Communicator.stats`)."""

    issued: int
    completed: int
    batches: int
    replanned: int      # pending handles re-issued by repair()
    last_policy: str    # strategy the last flush actually ran
    now: float          # simulation clock after the last flush


class Engine:
    """Nonblocking collective engine over one :class:`Communicator`.

    ``comm`` supplies the topology and the plan cache; the engine prices
    execution on the simulation plane (any backend's communicator works —
    planning is backend-independent).  ``policy`` is one of
    :data:`POLICIES` and may be overridden per :meth:`wait_all` call.
    ``check=True`` runs the static hazard analyzer
    (:mod:`repro.analysis.hazards`) at every :meth:`issue` (error-severity
    hazards only) and :meth:`wait_all` (the full analysis, warnings
    included) — a deadlock cycle or dangling dependency fails fast with a
    precise diagnosis instead of surfacing as a cryptic simulation error.

    Member subsets: ``issue(..., members=...)`` plans over a sub-group of
    the communicator's ranks.  Sub-group plans are cached in per-subset
    communicators sharing the same topology/policy, so repeated traffic on
    a subset reuses its plans like the main set does.
    """

    def __init__(self, comm: Communicator, *, policy: str = "fifo",
                 now: float = 0.0, age_rate: float = 0.0, check: bool = False,
                 tracer=None, metrics: MetricsRegistry | None = None,
                 truth=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {POLICIES}")
        if age_rate < 0:
            raise ValueError("age_rate must be >= 0")
        if truth is not None and truth.nprocs != comm.topo.nprocs:
            raise ValueError("truth topology has a different rank count")
        self.comm = comm
        # ``truth`` splits planning from execution: plans (and the
        # predicted_s the spans carry) come from comm.topo, but the batch
        # is *priced* on this topology — the simulation stand-in for the
        # real network, same role as FeedbackLoop.run(truth=).  Swapping
        # it mid-run injects link drift the model has not seen yet.
        self.truth = truth
        # set via HealthMonitor(engine=...): receives every resolved batch
        self.monitor = None
        self.policy = policy
        self.check = bool(check)
        self.age_rate = float(age_rate)
        self.now = float(now)
        # a traced communicator traces its engine too — one tracer covers
        # the whole stack unless the caller splits them explicitly
        self.tracer = tracer if tracer is not None else comm.tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._pending: list[Handle] = []
        self._hid = itertools.count()
        self._subcomms: dict[tuple[int, ...], Communicator] = {}
        self._last_finish: dict[tuple[int, ...], float] = {}
        self._issued = self.metrics.counter("engine.issued")
        self._completed = self.metrics.counter("engine.completed")
        self._batches = self.metrics.counter("engine.batches")
        self._replanned = self.metrics.counter("engine.replanned")
        self._wait_s = self.metrics.histogram("engine.wait_s")
        self._last_policy = policy

    # -- issue ----------------------------------------------------------- #
    def issue(self, op: str, x: Any = None, *, root: int | None = None,
              at: float | None = None,
              after: Sequence[Handle] = (),
              priority: float | None = None,
              members: Sequence[int] | None = None) -> Handle:
        """Enqueue one collective; returns immediately with a Handle.

        ``x`` sizes the op exactly like a Communicator call (bytes, or a
        device-shaped operand — see ``Communicator._nbytes_of`` for the
        per-rank semantics of gather/allgather/scatter).  ``at`` releases
        the collective no earlier than that simulation time (default: the
        engine clock ``now`` — e.g. when the producing backward layer has
        finished).  ``after`` adds explicit dependencies on other handles;
        same-member-set FIFO order is implicit and always enforced.
        ``priority``: larger preempts smaller under the "priority" policy
        (default ``-nbytes``: small collectives jump fat ones).
        """
        if op not in OPS:
            raise KeyError(op)
        mem = (self.comm.members if members is None
               else tuple(members))
        if not mem:
            raise ValueError("collective needs at least one member")
        if any(m not in self.comm.members for m in mem):
            raise ValueError(f"members {sorted(set(mem) - set(self.comm.members))} "
                             f"are not members of the communicator")
        root = mem[0] if root is None else root
        if root not in mem:
            raise ValueError(f"root {root} is not a member")
        for d in after:
            if d.engine is not self:
                raise ValueError("dependency handle belongs to a "
                                 "different engine")
        # size against the communicator that will PLAN the op: a device
        # scatter operand divides by ITS member count (pinned per-rank
        # semantics), which differs from the parent's on a subset
        nbytes = self._comm_for(mem)._nbytes_of(op, x)
        h = Handle(self, next(self._hid), op, root, nbytes, mem,
                   self.now if at is None else float(at), tuple(after),
                   priority)
        self._pending.append(h)
        if self.check:
            from ..analysis.hazards import HazardError, check_hazards

            try:
                check_hazards(self, errors_only=True)
            except HazardError:
                self._pending.remove(h)  # don't poison the batch
                raise
        self._issued.inc()
        return h

    def wait(self, handle: Handle) -> SimResult:
        if handle.engine is not self:
            raise ValueError("handle was issued on a different engine")
        return handle.wait()

    def wait_all(self, handles: Sequence[Handle] | None = None,
                 policy: str | None = None,
                 check: bool | None = None) -> list[SimResult]:
        """Resolve every pending handle (the whole batch is scheduled
        together) and return the results of ``handles`` (default: the
        batch just flushed, in issue order).  Handles issued on a different
        engine are rejected — accepting one would silently flush BOTH
        engines and return results that were never part of this batch.
        ``check`` overrides the engine's ``check=`` flag for this flush."""
        if handles is not None:
            for h in handles:
                if h.engine is not self:
                    raise ValueError("handle was issued on a different "
                                     "engine")
        if self.check if check is None else check:
            from ..analysis.hazards import check_hazards

            check_hazards(self)
        batch = self._flush(policy=policy)
        out = batch if handles is None else list(handles)
        return [h.wait() for h in out]

    # -- internals ------------------------------------------------------- #
    def _comm_for(self, members: tuple[int, ...]) -> Communicator:
        if members == self.comm.members:
            return self.comm
        sub = self._subcomms.get(members)
        if sub is None:
            # shares the tracer (one trace for the whole engine) but NOT
            # the metrics registry: the main communicator's counters must
            # not move when a subset plans
            sub = Communicator(self.comm.topo, policy=self.comm.policy,
                               backend="sim", members=members,
                               view=self.comm.view,
                               algorithm=self.comm.algorithm,
                               segment_bytes=self.comm.segment_bytes,
                               tracer=self.tracer)
            self._subcomms[members] = sub
        return sub

    def _flush(self, policy: str | None = None) -> list[Handle]:
        batch, self._pending = self._pending, []
        if not batch:
            return []
        policy = self.policy if policy is None else policy
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}")

        programs, releases = [], []
        depsets: list[set[int]] = []
        index = {h: i for i, h in enumerate(batch)}
        last_in_batch: dict[tuple[int, ...], int] = {}
        for i, h in enumerate(batch):
            comm = self._comm_for(h.members)
            plan = comm.plan(h.op, root=h.root, nbytes=h.nbytes)
            programs.append(plan.lower(h.nbytes))
            rel = h.at
            ds: set[int] = set()
            for d in h.after:
                if d.done:
                    rel = max(rel, d.finished)
                elif d in index:
                    ds.add(index[d])
                else:  # pragma: no cover - handles resolve batch-wise
                    raise ValueError("dependency handle neither done nor "
                                     "in this batch")
            prev = last_in_batch.get(h.members)
            if prev is not None:
                ds.add(prev)  # same member set: strict issue order
            else:
                rel = max(rel, self._last_finish.get(h.members, 0.0))
            last_in_batch[h.members] = i
            releases.append(rel)
            depsets.append(ds)

        prios = [h.priority if h.priority is not None else -h.nbytes
                 for h in batch]
        if self.age_rate:
            prios = [(p, self.age_rate) for p in prios]
        topo = self.comm.topo  # the model: plans + predicted_s
        net = topo if self.truth is None else self.truth  # what executes
        tr = self.tracer
        labels = [f"{h.op}#{h.hid}" for h in batch] if tr is not None \
            else None

        def run(deps, priorities, tracer=None):
            # trace_programs=False: the engine emits its own, richer,
            # handle spans on the same tracks below
            return simulate_concurrent(programs, net, starts=releases,
                                       deps=deps, priorities=priorities,
                                       tracer=tracer, labels=labels,
                                       trace_programs=False)

        ran = depsets  # the dependency sets the winning schedule executed
        if policy == "fifo":
            results, self._last_policy = run(depsets, None, tr), "fifo"
        elif policy == "priority":
            results, self._last_policy = run(depsets, prios, tr), "priority"
        else:  # "sim": simulate candidate orderings, keep the best
            cands = {"fair": (depsets, None), "priority": (depsets, prios)}
            for label, order in (("serial", range(len(batch))),
                                 ("serial-sjf", _sjf_order(batch, depsets))):
                chained = [set(d) for d in depsets]
                prev = None
                for i in order:
                    if prev is not None:
                        chained[i].add(prev)
                    prev = i
                cands[label] = (chained, None)
            best = None
            for label, (deps, pr) in cands.items():
                res = run(deps, pr)  # candidates stay untraced: only the
                for_pr = pr         # winner's traffic really "happened"
                makespan = max(max(c.values()) for c in res)
                if best is None or makespan < best[0]:
                    best = (makespan, label, res, deps, for_pr)
            results, self._last_policy = best[2], f"sim:{best[1]}"
            ran = best[3]
            if tr is not None:
                run(best[3], best[4], tr)  # deterministic re-run to record

        finishes = [max(c.values()) for c in results]
        for i, h in enumerate(batch):
            h.result = SimResult(h.op, h.root, h.nbytes, results[i])
            h.started = max([releases[i]]
                            + [finishes[d] for d in ran[i]])
            h.finished = finishes[i]
            self._last_finish[h.members] = max(
                self._last_finish.get(h.members, 0.0), finishes[i])
            self._wait_s.observe(h.started - h.at)
        self.now = max(self.now, max(finishes))
        self._completed.inc(len(batch))
        self._batches.inc()
        if tr is not None:
            for i, h in enumerate(batch):
                lb = labels[i]
                if h.started > h.at:
                    tr.span(PID_PROGRAMS, lb, "queued", h.at, h.started,
                            {"reason": "release+deps"})

                def _span(lb=lb, h=h, prog=programs[i],
                          pr=(prios[i] if isinstance(prios[i], float)
                              else prios[i][0]),
                          t0=h.started, t1=h.finished):
                    # isolated (contention-free) makespan of this handle's
                    # program = the plan's predicted cost; the gap to
                    # measured is what obs.feedback aggregates.  Deferred:
                    # the extra simulation runs at trace-read time, not on
                    # the engine's critical path.
                    pred = max(simulate_rounds(prog, topo).values())
                    tr.span(PID_PROGRAMS, lb, h.op, t0, t1,
                            {"op": h.op, "nbytes": h.nbytes,
                             "members": len(h.members),
                             "priority": pr,
                             "predicted_s": pred,
                             "measured_s": t1 - t0})

                tr.defer_record(_span)
            tr.instant(PID_PROGRAMS, "engine", f"flush {self._last_policy}",
                       self.now, {"policy": self._last_policy,
                                  "batch": len(batch)})
        if self.monitor is not None:
            self.monitor.observe_handles(batch)
        return batch

    def refresh_plans(self) -> None:
        """Propagate a topology refit to every cached plan surface.

        ``FeedbackLoop.maybe_refit`` / ``Communicator.refresh`` replace
        ``comm.topo`` and invalidate the *main* communicator's plan cache,
        but the engine's per-subset communicators still point at the old
        topology object.  This re-points them and invalidates their
        caches, so the next flush re-runs every argmin under the refit
        costs — the health monitor calls it after each mid-run refit."""
        self.comm._cache.invalidate()
        for sub in self._subcomms.values():
            sub.topo = self.comm.topo
            sub._cache.invalidate()

    # -- elasticity ------------------------------------------------------ #
    def repair(self, failed: Sequence[int]):
        """Compose with :meth:`Communicator.repair`: shrink the member set
        and splice cached plans, then reconcile in-flight handles.

        Already-resolved handles DRAIN — their results stand (the traffic
        completed before the failure was acted on).  Pending handles are
        RE-ISSUED on the repaired plans: dead ranks leave their member
        sets, a dead root is replaced by the first survivor, and the next
        flush plans over the spliced trees.  Returns the communicator's
        :class:`~repro.core.RepairReport`.

        Atomic: a pending handle whose members ALL died makes the whole
        call raise BEFORE anything — communicator, subcomms, or other
        handles — is touched.
        """
        dead = set(failed) & set(self.comm.members)
        for h in self._pending:
            if h.members and not set(h.members) - dead:
                raise ValueError(
                    f"handle #{h.hid} would lose every member to the "
                    f"failure; cancel it before repairing")
        report = self.comm.repair(failed)
        dead = set(report.failed)
        for mem, sub in list(self._subcomms.items()):
            if set(mem) & dead:
                del self._subcomms[mem]
                survivors = tuple(m for m in mem if m not in dead)
                if survivors:
                    sub.repair(failed)
                    self._subcomms[survivors] = sub
        for key in list(self._last_finish):
            if set(key) & dead:
                survivors = tuple(m for m in key if m not in dead)
                t = self._last_finish.pop(key)
                if survivors:
                    self._last_finish[survivors] = max(
                        self._last_finish.get(survivors, 0.0), t)
        for h in self._pending:
            if not set(h.members) & dead:
                continue
            survivors = tuple(m for m in h.members if m not in dead)
            h.members = survivors
            if h.root not in survivors:
                h.root = survivors[0]
            self._replanned.inc()
        return report

    # -- introspection --------------------------------------------------- #
    def stats(self) -> EngineStats:
        return EngineStats(self._issued.value, self._completed.value,
                           self._batches.value, self._replanned.value,
                           self._last_policy, self.now)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Engine(policy={self.policy!r}, pending="
                f"{len(self._pending)}, now={self.now:.6f})")


def _sjf_order(batch: list[Handle], depsets: list[set[int]]) -> list[int]:
    """Shortest-job-first order that respects the dependency sets (a small
    collective may jump a fat one, never its own member-set predecessor)."""
    placed: set[int] = set()
    order: list[int] = []
    while len(order) < len(batch):
        ready = [i for i in range(len(batch)) if i not in placed
                 and depsets[i] <= placed]
        nxt = min(ready, key=lambda i: (batch[i].nbytes, i))
        order.append(nxt)
        placed.add(nxt)
    return order


# ---------------------------------------------------------------------- #
# Gradient bucketing: size-targeted buckets in reverse-layer order.
# ---------------------------------------------------------------------- #

def partition_buckets(sizes: Sequence[float], bucket_bytes: float,
                      reverse: bool = True) -> list[list[int]]:
    """Greedy partition of per-item byte sizes into buckets of at least
    ``bucket_bytes`` (the last bucket may be smaller).  ``reverse`` walks
    items back-to-front — gradient availability order under backward.
    Returns index lists in emission order."""
    if bucket_bytes <= 0:
        raise ValueError("bucket_bytes must be positive")
    order = range(len(sizes) - 1, -1, -1) if reverse else range(len(sizes))
    buckets: list[list[int]] = []
    cur: list[int] = []
    acc = 0.0
    for i in order:
        cur.append(i)
        acc += sizes[i]
        if acc >= bucket_bytes:
            buckets.append(cur)
            cur, acc = [], 0.0
    if cur:
        buckets.append(cur)
    return buckets


def overlapped_step_times(comm: Communicator,
                          layer_bytes: Sequence[float],
                          layer_compute_s: Sequence[float],
                          *, bucket_bytes: float,
                          policy: str = "fifo") -> dict:
    """Price one training step's gradient sync, serial vs overlapped.

    Backward visits layers last-to-first; layer i's compute takes
    ``layer_compute_s[i]`` and yields ``layer_bytes[i]`` of gradient.
    *Serial* runs the full backward then ONE monolithic all-reduce of every
    byte.  *Overlapped* partitions gradients into size-targeted buckets
    (:func:`partition_buckets`) and issues each bucket's all-reduce through
    an :class:`Engine` the moment its last layer's backward finishes — the
    sync of layer k overlaps the backward of the layers below it.

    Returns a dict with ``serial_s``, ``overlapped_s``, ``speedup``,
    ``overlap_efficiency`` (fraction of the ideal ``min(compute, comm)``
    hiding actually achieved), bucket count and the engine used.
    """
    if len(layer_bytes) != len(layer_compute_s):
        raise ValueError("layer_bytes and layer_compute_s must align")
    total_bytes = float(sum(layer_bytes))
    compute_s = float(sum(layer_compute_s))
    comm_serial_s = comm.allreduce(total_bytes).time
    serial_s = compute_s + comm_serial_s

    buckets = partition_buckets(layer_bytes, bucket_bytes)
    eng = Engine(comm, policy=policy)
    handles = []
    t = 0.0
    done_at = [0.0] * len(layer_bytes)
    for i in range(len(layer_bytes) - 1, -1, -1):
        t += layer_compute_s[i]
        done_at[i] = t
    for idx in buckets:
        nb = float(sum(layer_bytes[i] for i in idx))
        ready = max(done_at[i] for i in idx)
        handles.append(eng.issue("allreduce", nb, at=ready))
    eng.wait_all()
    overlapped_s = max([compute_s] + [h.finished for h in handles])
    hidden = serial_s - overlapped_s
    ideal = min(compute_s, comm_serial_s)
    return {
        "total_bytes": total_bytes,
        "bucket_bytes": float(bucket_bytes),
        "n_buckets": len(buckets),
        "compute_s": compute_s,
        "comm_serial_s": comm_serial_s,
        "serial_s": serial_s,
        "overlapped_s": overlapped_s,
        "speedup": serial_s / overlapped_s,
        "overlap_efficiency": (hidden / ideal) if ideal > 0 else 0.0,
        "engine": eng,
    }
