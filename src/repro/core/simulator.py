"""Event-driven postal-model executor for collective schedules and plans.

Charges every message its TRUE per-edge cost from a ``Topology`` — even when
the tree was built from an oblivious (flat) or 2-level (MagPIe) view.  This is
how we reproduce the paper's Fig. 8 on one CPU: build trees under different
views, simulate them all on the real multilevel network.

Model per message (postal / LogP-flavoured):
  sender occupied  [t, t + overhead + nbytes/bw]   (sequential injections)
  arrival at dst    t + latency + nbytes/bw
Receivers of fold (reduce) messages drain inbound messages sequentially with
the same occupancy term, which penalises high fan-in on slow links — the
effect that makes flat trees lose at low latency.

Two executors:

:func:`simulate`
    Whole-message :class:`~repro.core.schedule.Schedule` phases.  Phase
    hand-off is **per-rank**: a rank starts phase i+1 work the moment its own
    phase-i role ends (the root of a reduce→bcast allreduce broadcasts as
    soon as *it* has folded — not when the slowest leaf has finished
    injecting).
:func:`simulate_rounds`
    The lowered rounds IR (:class:`~repro.core.rounds.Lowered`): a single
    linear pass over the send program.  Each send starts at
    max(dependencies delivered, sender NIC free); per-rank program order is
    FIFO.  This is where segment pipelining is priced: a node forwards
    segment k while segment k+1 is still in flight toward it.
"""
from __future__ import annotations

import math

from .schedule import Direction, Schedule
from .topology import Topology

__all__ = ["simulate", "simulate_rounds", "simulate_op", "probe_time"]


def simulate(sched: Schedule, topo: Topology, start: float = 0.0) -> dict[int, float]:
    """Run ``sched`` on ``topo``; return per-rank completion times.

    Phases hand off per rank: ``done[r]`` after phase i seeds rank r's
    availability in phase i+1 (no global barrier between phases).
    """
    done = {r: start for r in sched.phases[0].tree.members()}
    for phase in sched.phases:
        if phase.direction is Direction.DOWN:
            done = _run_down(phase, topo, done)
        else:
            done = _run_up(phase, topo, done)
    return done


def _run_down(phase, topo: Topology, prev: dict[int, float]) -> dict[int, float]:
    tree = phase.tree
    ready = {tree.root: prev[tree.root]}
    order = tree.members()  # preorder: parents before children
    for p in order:
        t = ready[p]
        for msg in phase.msgs.get(p, []):
            lvl = topo.level_of_edge(msg.src, msg.dst)
            arrival = t + lvl.latency + msg.nbytes / lvl.bandwidth
            # the receiver is available once it holds the data AND has
            # finished its own earlier-phase role
            ready[msg.dst] = max(arrival, prev[msg.dst])
            t += lvl.occupy(msg.nbytes)  # next injection after this one
    return ready


def _run_up(phase, topo: Topology, prev: dict[int, float]) -> dict[int, float]:
    tree = phase.tree
    done: dict[int, float] = {}

    # Iterative post-order (children before parent): deep trees — e.g. a
    # chain over thousands of ranks — must not blow the recursion limit.
    # done[p] = time p has received (and folded) all of its subtree.
    # Children send as soon as their own subtrees finish; p drains their
    # messages sequentially (receive occupancy).
    stack: list[tuple[int, bool]] = [(tree.root, False)]
    while stack:
        p, expanded = stack.pop()
        cs = tree.children.get(p, [])
        if cs and not expanded:
            stack.append((p, True))
            stack.extend((c, False) for c in cs)
            continue
        t = prev[p]  # p joins the fan-in once its prior phase ended
        for c in cs:
            (msg,) = phase.msgs[c]
            lvl = topo.level_of_edge(c, p)
            arrival = done[c] + lvl.latency + msg.nbytes / lvl.bandwidth
            t = max(t, arrival) + lvl.overhead
        done[p] = t

    # Leaves are "done" immediately; completion of the phase per rank: a rank
    # finishes when its own up-message has been *injected* (it is then free),
    # the root when it has folded everything.
    pm = tree.parent_map()
    out = {}
    for p in tree.members():
        if p == tree.root:
            out[p] = done[p]
        else:
            (msg,) = phase.msgs[p]
            lvl = topo.level_of_edge(p, pm[p])
            out[p] = done[p] + lvl.occupy(msg.nbytes)
    return out


# ---------------------------------------------------------------------- #
# The rounds-IR executor.
# ---------------------------------------------------------------------- #

def simulate_rounds(lowered, topo: Topology, start: float = 0.0,
                    fail_at: dict[int, float] | None = None,
                    ) -> dict[int, float]:
    """Execute a :class:`~repro.core.rounds.Lowered` program on ``topo``.

    One linear pass: the send list is topologically ordered and each rank's
    subsequence is its FIFO injection program, so every timing input (dep
    delivery, sender NIC, receiver fold occupancy) is already known when a
    send is reached.  Returns per-rank completion times over
    ``lowered.members``.

    ``fail_at`` injects failures: ``{rank: death_time}``.  A send is LOST
    when any dependency was lost, the sender dies before finishing its
    injection, or the receiver dies before arrival.  A surviving rank
    blocked on lost data reports ``math.inf`` — the signature a failure
    detector observes; dead ranks report their death time.  With
    ``fail_at`` empty/None the timing is bit-identical to the fault-free
    path.
    """
    death = fail_at or {}
    sender_free: dict[int, float] = {}
    recv_free: dict[int, float] = {}
    delivered: list[float] = []
    completion = {r: start for r in lowered.members}

    for snd in lowered.sends:
        lvl = topo.level_of_edge(snd.src, snd.dst)
        t0 = max(start, sender_free.get(snd.src, start),
                 *(delivered[d] for d in snd.deps)) if snd.deps else \
            max(start, sender_free.get(snd.src, start))
        xfer = snd.nbytes / lvl.bandwidth
        inject_end = t0 + xfer + (lvl.overhead if snd.first else 0.0)
        arrival = t0 + xfer + (lvl.latency if snd.first else 0.0)
        if death and (t0 == math.inf
                      or inject_end > death.get(snd.src, math.inf)
                      or arrival > death.get(snd.dst, math.inf)):
            # lost: deps never delivered, sender died mid-injection, or
            # receiver died before arrival.  A live sender blocked on lost
            # data waits forever; downstream consumers inherit the loss.
            delivered.append(math.inf)
            if snd.src not in death:
                if t0 == math.inf:
                    completion[snd.src] = math.inf
                else:  # injected into a dead peer: the NIC time is real
                    sender_free[snd.src] = inject_end
                    completion[snd.src] = max(completion[snd.src],
                                              inject_end)
            elif t0 == math.inf or inject_end > death[snd.src]:
                # the dying rank's NIC never frees: its LATER queued sends
                # must not jump the FIFO and get spuriously delivered
                sender_free[snd.src] = math.inf
            else:  # lost to the receiver's death; sender still alive here
                sender_free[snd.src] = inject_end
            if snd.dst not in death:
                completion[snd.dst] = math.inf
            continue
        sender_free[snd.src] = inject_end
        if snd.kind == "reduce":
            # folds drain sequentially at the receiver (postal occupancy)
            done = max(arrival, recv_free.get(snd.dst, start)) + lvl.overhead
            recv_free[snd.dst] = done
        else:
            done = arrival
        delivered.append(done)
        completion[snd.src] = max(completion[snd.src], sender_free[snd.src])
        completion[snd.dst] = max(completion[snd.dst], done)
    for r, t in death.items():
        if r in completion:
            completion[r] = min(completion[r], t)
    return completion


def simulate_op(op_fn, tree, topo: Topology, nbytes: float) -> float:
    """Convenience: max completion time of op_fn(tree, nbytes) on topo."""
    sched = op_fn(tree, nbytes) if nbytes is not None else op_fn(tree)
    return max(simulate(sched, topo).values())


def probe_time(topo: Topology, p: int, q: int, nbytes: float) -> float:
    """One-way delivery time of a single point-to-point probe p→q.

    This is the postal-model quantity a timed ping observes: the sender's
    per-message cost (overhead) is on the critical path of a lone message,
    so the measured time is ``overhead + latency + nbytes/bandwidth``.
    :func:`repro.core.discovery.simulated_probes` is the vectorised
    all-pairs version of exactly this expression; keeping the scalar form
    here pins the probe semantics to the simulator's cost model.
    """
    lvl = topo.level_of_edge(p, q)
    return lvl.overhead + lvl.latency + nbytes / lvl.bandwidth
