"""Event-driven postal-model executor for collective schedules.

Charges every message its TRUE per-edge cost from a ``Topology`` — even when
the tree was built from an oblivious (flat) or 2-level (MagPIe) view.  This is
how we reproduce the paper's Fig. 8 on one CPU: build trees under different
views, simulate them all on the real multilevel network.

Model per message (postal / LogP-flavoured):
  sender occupied  [t, t + overhead + nbytes/bw]   (sequential injections)
  arrival at dst    t + latency + nbytes/bw
Receivers in UP phases drain inbound messages sequentially with the same
occupancy term, which penalises high fan-in on slow links — the effect that
makes flat trees lose at low latency.
"""
from __future__ import annotations

from .schedule import Direction, Schedule
from .topology import Topology

__all__ = ["simulate", "simulate_op"]


def simulate(sched: Schedule, topo: Topology, start: float = 0.0) -> dict[int, float]:
    """Run ``sched`` on ``topo``; return per-rank completion times."""
    done: dict[int, float] = {}
    t = start
    for phase in sched.phases:
        if phase.direction is Direction.DOWN:
            done = _run_down(phase, topo, t)
        else:
            done = _run_up(phase, topo, t)
        t = max(done.values())
    return done


def _run_down(phase, topo: Topology, start: float) -> dict[int, float]:
    tree = phase.tree
    ready = {tree.root: start}
    order = tree.members()  # preorder: parents before children
    for p in order:
        t = ready[p]
        for msg in phase.msgs.get(p, []):
            lvl = topo.level_of_edge(msg.src, msg.dst)
            arrival = t + lvl.latency + msg.nbytes / lvl.bandwidth
            ready[msg.dst] = arrival
            t += lvl.occupy(msg.nbytes)  # next injection after this one
    return ready


def _run_up(phase, topo: Topology, start: float) -> dict[int, float]:
    tree = phase.tree
    done: dict[int, float] = {}

    # Iterative post-order (children before parent): deep trees — e.g. a
    # chain over thousands of ranks — must not blow the recursion limit.
    # done[p] = time p has received (and folded) all of its subtree.
    # Children send as soon as their own subtrees finish; p drains their
    # messages sequentially (receive occupancy).
    stack: list[tuple[int, bool]] = [(tree.root, False)]
    while stack:
        p, expanded = stack.pop()
        cs = tree.children.get(p, [])
        if cs and not expanded:
            stack.append((p, True))
            stack.extend((c, False) for c in cs)
            continue
        t = start
        for c in cs:
            (msg,) = phase.msgs[c]
            lvl = topo.level_of_edge(c, p)
            arrival = done[c] + lvl.latency + msg.nbytes / lvl.bandwidth
            t = max(t, arrival) + lvl.overhead
        done[p] = t

    # Leaves are "done" immediately; completion of the phase per rank: a rank
    # finishes when its own up-message has been *injected* (it is then free),
    # the root when it has folded everything.
    pm = tree.parent_map()
    out = {}
    for p in tree.members():
        if p == tree.root:
            out[p] = done[p]
        else:
            (msg,) = phase.msgs[p]
            lvl = topo.level_of_edge(p, pm[p])
            out[p] = done[p] + lvl.occupy(msg.nbytes)
    return out


def simulate_op(op_fn, tree, topo: Topology, nbytes: float) -> float:
    """Convenience: max completion time of op_fn(tree, nbytes) on topo."""
    sched = op_fn(tree, nbytes) if nbytes is not None else op_fn(tree)
    return max(simulate(sched, topo).values())
