"""Event-driven postal-model executor for collective schedules and plans.

Charges every message its TRUE per-edge cost from a ``Topology`` — even when
the tree was built from an oblivious (flat) or 2-level (MagPIe) view.  This is
how we reproduce the paper's Fig. 8 on one CPU: build trees under different
views, simulate them all on the real multilevel network.

Model per message (postal / LogP-flavoured):
  sender occupied  [t, t + overhead + nbytes/bw]   (sequential injections)
  arrival at dst    t + latency + nbytes/bw
Receivers of fold (reduce) messages drain inbound messages sequentially with
the same occupancy term, which penalises high fan-in on slow links — the
effect that makes flat trees lose at low latency.

Two executors:

:func:`simulate`
    Whole-message :class:`~repro.core.schedule.Schedule` phases.  Phase
    hand-off is **per-rank**: a rank starts phase i+1 work the moment its own
    phase-i role ends (the root of a reduce→bcast allreduce broadcasts as
    soon as *it* has folded — not when the slowest leaf has finished
    injecting).
:func:`simulate_rounds`
    The lowered rounds IR (:class:`~repro.core.rounds.Lowered`): a single
    linear pass over the send program.  Each send starts at
    max(dependencies delivered, sender NIC free); per-rank program order is
    FIFO.  This is where segment pipelining is priced: a node forwards
    segment k while segment k+1 is still in flight toward it.
:func:`simulate_concurrent`
    Several ``Lowered`` programs live on the network AT ONCE (what the
    async engine in :mod:`repro.core.engine` schedules).  Contention is
    charged per *link* — a link is one directed edge (src, dst) at its
    level's bandwidth — as fluid fair sharing: k concurrent transfers on a
    link each proceed at bandwidth/k (or, under strict priorities, only the
    highest-priority program's transfers proceed).  A program alone on its
    links prices bit-identically to :func:`simulate_rounds`.
"""
from __future__ import annotations

import heapq
import math
import weakref
from typing import Mapping, Sequence

from ..obs.trace import PID_PROGRAMS
from .schedule import Direction, Schedule
from .topology import Topology

# Critical-path instants carry at most this many edges: enough to read the
# bottleneck chain in a viewer, bounded so a 64-segment pipelined transfer
# cannot bloat the trace.
_CRIT_PATH_CAP = 64

class _IdWeakSet:
    """Identity-keyed weak set.  ``Lowered`` is a frozen dataclass whose
    field-derived hash walks the whole send list — O(n_sends) per lookup —
    so a plain WeakSet memo would cost ~10% of the simulation itself.
    Keying on ``id()`` with a death callback keeps the lookup O(1) without
    keeping evicted plans alive (the callback runs before the interpreter
    can reuse the address)."""

    def __init__(self) -> None:
        self._refs: dict[int, "weakref.ref"] = {}

    def __contains__(self, obj) -> bool:
        ref = self._refs.get(id(obj))
        return ref is not None and ref() is obj

    def add(self, obj) -> None:
        key = id(obj)
        self._refs[key] = weakref.ref(
            obj, lambda _r, k=key: self._refs.pop(k, None))

    def discard(self, obj) -> None:
        if obj in self:
            del self._refs[id(obj)]


# Programs that already passed the sanitize gate this process: Lowered is
# frozen (its send list cannot change), so each object needs checking once
# — the memo makes ``sanitize=True`` free on cached-plan re-runs.
_SANITIZED = _IdWeakSet()


def _sanitize(lowered) -> None:
    if lowered in _SANITIZED:
        return
    from ..analysis.verify import quick_check  # no load-time cycle

    quick_check(lowered, context="sanitize")
    _SANITIZED.add(lowered)

__all__ = ["simulate", "simulate_rounds", "simulate_concurrent",
           "simulate_op", "probe_time"]


def simulate(sched: Schedule, topo: Topology, start: float = 0.0) -> dict[int, float]:
    """Run ``sched`` on ``topo``; return per-rank completion times.

    Phases hand off per rank: ``done[r]`` after phase i seeds rank r's
    availability in phase i+1 (no global barrier between phases).
    """
    done = {r: start for r in sched.phases[0].tree.members()}
    for phase in sched.phases:
        if phase.direction is Direction.DOWN:
            done = _run_down(phase, topo, done)
        else:
            done = _run_up(phase, topo, done)
    return done


def _run_down(phase, topo: Topology, prev: dict[int, float]) -> dict[int, float]:
    tree = phase.tree
    ready = {tree.root: prev[tree.root]}
    order = tree.members()  # preorder: parents before children
    for p in order:
        t = ready[p]
        for msg in phase.msgs.get(p, []):
            lvl = topo.level_of_edge(msg.src, msg.dst)
            arrival = t + lvl.latency + msg.nbytes / lvl.bandwidth
            # the receiver is available once it holds the data AND has
            # finished its own earlier-phase role
            ready[msg.dst] = max(arrival, prev[msg.dst])
            t += lvl.occupy(msg.nbytes)  # next injection after this one
    return ready


def _run_up(phase, topo: Topology, prev: dict[int, float]) -> dict[int, float]:
    tree = phase.tree
    done: dict[int, float] = {}

    # Iterative post-order (children before parent): deep trees — e.g. a
    # chain over thousands of ranks — must not blow the recursion limit.
    # done[p] = time p has received (and folded) all of its subtree.
    # Children send as soon as their own subtrees finish; p drains their
    # messages sequentially (receive occupancy).
    stack: list[tuple[int, bool]] = [(tree.root, False)]
    while stack:
        p, expanded = stack.pop()
        cs = tree.children.get(p, [])
        if cs and not expanded:
            stack.append((p, True))
            stack.extend((c, False) for c in cs)
            continue
        t = prev[p]  # p joins the fan-in once its prior phase ended
        for c in cs:
            (msg,) = phase.msgs[c]
            lvl = topo.level_of_edge(c, p)
            arrival = done[c] + lvl.latency + msg.nbytes / lvl.bandwidth
            t = max(t, arrival) + lvl.overhead
        done[p] = t

    # Leaves are "done" immediately; completion of the phase per rank: a rank
    # finishes when its own up-message has been *injected* (it is then free),
    # the root when it has folded everything.
    pm = tree.parent_map()
    out = {}
    for p in tree.members():
        if p == tree.root:
            out[p] = done[p]
        else:
            (msg,) = phase.msgs[p]
            lvl = topo.level_of_edge(p, pm[p])
            out[p] = done[p] + lvl.occupy(msg.nbytes)
    return out


# ---------------------------------------------------------------------- #
# The rounds-IR executor.
# ---------------------------------------------------------------------- #

def simulate_rounds(lowered, topo: Topology, start: float = 0.0,
                    fail_at: dict[int, float] | None = None,
                    *, tracer=None, label: str | None = None,
                    sanitize: bool = False) -> dict[int, float]:
    """Execute a :class:`~repro.core.rounds.Lowered` program on ``topo``.

    One linear pass: the send list is topologically ordered and each rank's
    subsequence is its FIFO injection program, so every timing input (dep
    delivery, sender NIC, receiver fold occupancy) is already known when a
    send is reached.  Returns per-rank completion times over
    ``lowered.members``.

    ``fail_at`` injects failures: ``{rank: death_time}``.  A send is LOST
    when any dependency was lost, the sender dies before finishing its
    injection, or the receiver dies before arrival.  A surviving rank
    blocked on lost data reports ``math.inf`` — the signature a failure
    detector observes; dead ranks report their death time.  With
    ``fail_at`` empty/None the timing is bit-identical to the fault-free
    path.

    ``lowered`` may also be a *sequence* of ``Lowered`` programs: they are
    handed to :func:`simulate_concurrent` (all released at ``start``, fair
    link sharing) and a list of per-program completion dicts is returned.
    ``fail_at`` is a single-program feature and is rejected there.

    With a ``tracer`` (:class:`repro.obs.Tracer`), every delivered send is
    recorded as a busy interval on its directed edge and the program's
    critical path (the chain of sends whose gates determined the last
    delivery) is emitted as an instant on track ``label``.  Tracing never
    perturbs the computed times — the timing code is byte-for-byte the
    untraced path.

    ``sanitize=True`` runs the cheap structural verifier
    (:func:`repro.analysis.verify.quick_check`: self-sends, member
    closure, dependency order/cycles) before executing; each ``Lowered``
    object is checked at most once per process, so re-running a cached
    plan costs one set lookup.
    """
    if isinstance(lowered, (list, tuple)):
        if fail_at:
            raise ValueError("fail_at is not supported for concurrent "
                             "programs; inject failures per single program")
        return simulate_concurrent(
            lowered, topo, starts=[start] * len(lowered), tracer=tracer,
            labels=[label] * len(lowered) if label is not None else None,
            sanitize=sanitize)
    if sanitize:
        _sanitize(lowered)
    if tracer is not None and tracer.defer:
        # zero-cost tracing on the live run: queue a deterministic replay
        # (this exact call, inline-recording) for when the trace is read,
        # and execute untraced now.  Both runs compute identical times.
        fa = dict(fail_at) if fail_at else None
        tracer.defer_record(
            lambda tr=tracer: simulate_rounds(lowered, topo, start, fa,
                                              tracer=tr, label=label))
        tracer = None
    death = fail_at or {}
    sender_free: dict[int, float] = {}
    recv_free: dict[int, float] = {}
    delivered: list[float] = []
    completion = {r: start for r in lowered.members}

    trace = tracer is not None
    if trace:
        # hot-path discipline: one pre-built tuple appended per delivered
        # send (plain-list level table, bound append) — the <5% tracing
        # overhead budget asserted by benchmarks/bench_obs.py lives here
        lvltab = topo.comm_level_table()
        lappend = tracer.links.append
        gid = tracer.group()  # one sharing group per invocation
        plabel = label if label is not None else "collective"
        cause: list[int | None] = []    # gate that set each send's t0
        last_send_of: dict[int, int] = {}
        last_fold_of: dict[int, int] = {}

    for i, snd in enumerate(lowered.sends):
        src, dst = snd.src, snd.dst
        lvl = topo.level_of_edge(src, dst)
        sf = sender_free.get(src, start)
        t0 = max(start, sf, *(delivered[d] for d in snd.deps)) \
            if snd.deps else max(start, sf)
        xfer = snd.nbytes / lvl.bandwidth
        inject_end = t0 + xfer + (lvl.overhead if snd.first else 0.0)
        arrival = t0 + xfer + (lvl.latency if snd.first else 0.0)
        if trace:
            c = None
            if t0 > start and sf == t0:
                c = last_send_of.get(src)
            for d in snd.deps:
                if delivered[d] == t0:
                    c = d
            cause.append(c)
        if death and (t0 == math.inf
                      or inject_end > death.get(src, math.inf)
                      or arrival > death.get(dst, math.inf)):
            # lost: deps never delivered, sender died mid-injection, or
            # receiver died before arrival.  A live sender blocked on lost
            # data waits forever; downstream consumers inherit the loss.
            delivered.append(math.inf)
            if src not in death:
                if t0 == math.inf:
                    completion[src] = math.inf
                else:  # injected into a dead peer: the NIC time is real
                    sender_free[src] = inject_end
                    completion[src] = max(completion[src], inject_end)
            elif t0 == math.inf or inject_end > death[src]:
                # the dying rank's NIC never frees: its LATER queued sends
                # must not jump the FIFO and get spuriously delivered
                sender_free[src] = math.inf
            else:  # lost to the receiver's death; sender still alive here
                sender_free[src] = inject_end
            if dst not in death:
                completion[dst] = math.inf
            continue
        sender_free[src] = inject_end
        if snd.kind == "reduce":
            # folds drain sequentially at the receiver (postal occupancy)
            done = max(arrival, recv_free.get(dst, start)) + lvl.overhead
            recv_free[dst] = done
        else:
            done = arrival
        delivered.append(done)
        if trace:
            lappend((src, dst, lvltab[src][dst], t0, arrival,
                     snd.nbytes, snd.kind, snd.first, plabel,
                     t0 + xfer, gid))
            if snd.kind == "reduce":
                if done - lvl.overhead > arrival:
                    # queued behind the receiver's fold drain: the delivery
                    # chain runs through the previous fold, not our injection
                    cause[i] = last_fold_of.get(dst, cause[i])
                last_fold_of[dst] = i
            last_send_of[src] = i
        completion[src] = max(completion[src], inject_end)
        completion[dst] = max(completion[dst], done)
    for r, t in death.items():
        if r in completion:
            completion[r] = min(completion[r], t)
    if trace and delivered:
        end = max((t for t in delivered if t != math.inf), default=None)
        if end is not None:
            k: int | None = delivered.index(end)
            path = []
            while k is not None and len(path) < _CRIT_PATH_CAP:
                s = lowered.sends[k]
                path.append(f"{s.src}->{s.dst}")
                k = cause[k]
            path.reverse()
            tracer.instant(PID_PROGRAMS, plabel, "critical_path", end,
                           {"edges": path, "hops": len(path),
                            "length_s": end - start})
    return completion


# ---------------------------------------------------------------------- #
# The concurrent executor: many live programs, per-link bandwidth sharing.
# ---------------------------------------------------------------------- #

_ACTIVATE, _FINISH = 0, 1


def simulate_concurrent(programs: Sequence, topo: Topology, *,
                        starts: Sequence[float] | None = None,
                        deps: "Mapping[int, Sequence[int]] | Sequence[Sequence[int]] | None" = None,
                        priorities: Sequence[float] | None = None,
                        tracer=None,
                        labels: Sequence[str | None] | None = None,
                        trace_programs: bool = True,
                        sanitize: bool = False,
                        ) -> list[dict[int, float]]:
    """Execute several ``Lowered`` programs concurrently on ``topo``.

    Returns one per-rank completion dict per program (same contract as
    :func:`simulate_rounds` per program).

    Model — the postal model extended with *fluid link sharing*:

    * A **link** is a directed edge (src, dst) charged at its level-class
      bandwidth.  The k transfers concurrently active on a link each flow at
      ``bandwidth / k`` (processor sharing); rates re-divide whenever a
      transfer joins or drains.  Within ONE program a sender's FIFO NIC
      admits at most one in-flight transfer, so a program that shares no
      link with another prices **bit-identically** to its isolated
      :func:`simulate_rounds` run — contention is the only coupling.
    * ``starts[j]`` releases program j at an absolute time (default 0.0).
    * ``deps[j]`` names programs that must COMPLETE (every rank done)
      before program j is released — how the engine encodes per-member-set
      FIFO order and explicit handle dependencies.
    * ``priorities[j]`` switches a link from fair sharing to strict
      priority: only the highest-priority transfers active on the link
      flow, lower ones stall until the link clears.  Equal priorities
      share fairly.  ``None`` means all-fair.
    * An entry may also be a ``(base, age_rate)`` pair: the program's
      effective priority at time t is ``base + age_rate * (t - release)``
      — a preempted transfer decays toward the front of the link the
      longer it waits (bounded starvation).  With one shared ``age_rate``
      the pairwise differences are CONSTANT in time (both grow at the
      same slope), so link eligibility can only flip at join/drain
      events, which the fluid executor already processes — no extra
      crossover events are needed.  (Heterogeneous rates are legal but
      re-evaluated only at link events.)  ``age_rate == 0`` is exactly
      the static-priority behaviour.

    Latency and sender/receiver overheads stay per-message quantities
    (charged once at flow end for ``first`` sends), and reduce messages
    still drain sequentially at the receiver — both exactly as in the
    single-program executor.

    With a ``tracer``, every completed transfer becomes a busy interval on
    its directed edge (labelled by ``labels[j]``), each program gets a
    release→finish span on :data:`~repro.obs.PID_PROGRAMS` (suppressed
    with ``trace_programs=False`` when the caller — the engine — emits its
    own richer handle spans on the same tracks) and a critical-path
    instant walking the chain of gates that produced the last delivery.
    Tracing is observation only: completion times are identical with and
    without it.
    """
    if tracer is not None and tracer.defer:
        # as in simulate_rounds: snapshot the arguments, queue an inline
        # replay for trace-read time, run untraced now
        ps = list(programs)
        ss = None if starts is None else list(starts)
        dd = (dict(deps) if isinstance(deps, Mapping)
              else None if deps is None else [list(d) for d in deps])
        pr = None if priorities is None else list(priorities)
        lb = None if labels is None else list(labels)
        tracer.defer_record(
            lambda tr=tracer: simulate_concurrent(
                ps, topo, starts=ss, deps=dd, priorities=pr, tracer=tr,
                labels=lb, trace_programs=trace_programs))
        tracer = None
    progs = list(programs)
    if sanitize:
        for p in progs:
            _sanitize(p)
    K = len(progs)
    rel = list(starts) if starts is not None else [0.0] * K
    if len(rel) != K:
        raise ValueError(f"need {K} start times, got {len(rel)}")
    if deps is None:
        pdeps: list[list[int]] = [[] for _ in range(K)]
    elif isinstance(deps, Mapping):
        pdeps = [sorted(set(deps.get(j, ()))) for j in range(K)]
    else:
        pdeps = [sorted(set(deps[j])) for j in range(K)]
    for j, ds in enumerate(pdeps):
        if any(d == j or not 0 <= d < K for d in ds):
            raise ValueError(f"bad program dependency list for #{j}: {ds}")
    if priorities is None:
        prio = age = None
    else:
        prio, age = [], []
        for p in priorities:
            if isinstance(p, tuple):
                base, rate = p
                if rate < 0:
                    raise ValueError("priority age_rate must be >= 0")
                prio.append(float(base))
                age.append(float(rate))
            else:
                prio.append(float(p))
                age.append(0.0)
        if not any(age):
            age = None

    # -- flatten the programs into one transfer table ------------------- #
    off = [0]
    for p in progs:
        off.append(off[-1] + len(p.sends))
    n = off[-1]
    prog_of = [0] * n
    send_of = [None] * n
    lvl_of = [None] * n
    gdeps: list[tuple[int, ...]] = [()] * n
    fifo_next: list[int | None] = [None] * n
    fifo_prev: list[int | None] = [None] * n
    rev: list[list[int]] = [[] for _ in range(n)]
    fold_chain: dict[tuple[int, int], list[int]] = {}
    for j, p in enumerate(progs):
        last_of_src: dict[int, int] = {}
        for i, snd in enumerate(p.sends):
            g = off[j] + i
            prog_of[g] = j
            send_of[g] = snd
            lvl_of[g] = topo.level_of_edge(snd.src, snd.dst)
            gdeps[g] = tuple(off[j] + d for d in snd.deps)
            for d in gdeps[g]:
                rev[d].append(g)
            prev = last_of_src.get(snd.src)
            if prev is not None:
                fifo_next[prev] = g
                fifo_prev[g] = prev
            last_of_src[snd.src] = g
            if snd.kind == "reduce":
                fold_chain.setdefault((j, snd.dst), []).append(g)

    # -- per-transfer dynamic state ------------------------------------- #
    released = [len(ds) == 0 for ds in pdeps]
    pdep_left = [len(ds) for ds in pdeps]
    completion: list[dict[int, float] | None] = [None] * K
    finish: list[float | None] = [None] * K
    left = [len(p.sends) for p in progs]
    rdeps: list[list[int]] = [[] for _ in range(K)]
    for j, ds in enumerate(pdeps):
        for d in ds:
            rdeps[d].append(j)

    delivered: list[float | None] = [None] * n
    arrived: list[float | None] = [None] * n      # reduce flow-arrivals
    sender_term: list[float | None] = [None] * n  # prev inject_end (FIFO)
    waiting = [0] * n
    remaining = [0.0] * n
    rate = [0.0] * n
    last_t = [0.0] * n
    flow_end = [math.inf] * n
    active = [False] * n
    done = [False] * n
    recv_free: dict[tuple[int, int], float] = {}
    chain_ptr: dict[tuple[int, int], int] = {k: 0 for k in fold_chain}
    edge_active: dict[tuple[int, int], list[int]] = {}

    trace = tracer is not None
    if trace:
        lvltab = topo.comm_level_table()
        gid = tracer.group()  # every transfer of this batch shared links
        lab = [labels[j] if labels is not None and labels[j] is not None
               else f"prog{j}" for j in range(K)]
        astart = [0.0] * n             # first activation (flow start)
        cause: list[int | None] = [None] * n   # gate that set each t0

    events: list[tuple[float, int, int, int]] = []
    seq = 0

    def push(t: float, kind: int, g: int) -> None:
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, g))
        seq += 1

    def ready(g: int) -> None:
        """All gates known: compute the injection start and schedule it."""
        j = prog_of[g]
        t0 = rel[j]
        st = sender_term[g]
        if st is not None and st > t0:
            t0 = st
            if trace:
                cause[g] = fifo_prev[g]
        for d in gdeps[g]:
            if delivered[d] > t0:  # type: ignore[operator]
                t0 = delivered[d]
                if trace:
                    cause[g] = d
        remaining[g] = send_of[g].nbytes
        push(t0, _ACTIVATE, g)

    def reshare(e: tuple[int, int], now: float) -> None:
        """Re-divide a link's bandwidth among its active transfers."""
        xs = edge_active.get(e)
        if not xs:
            return
        if prio is None:
            elig = xs
        else:
            if age is None:
                eff = prio
            else:
                # aged priority: differences are time-invariant under a
                # shared rate, so evaluating at `now` is exact for the
                # whole inter-event interval
                eff = [prio[j] + age[j] * (now - rel[j])
                       for j in range(len(prio))]
            top = max(eff[prog_of[x]] for x in xs)
            elig = [x for x in xs if eff[prog_of[x]] == top]
        bw = lvl_of[xs[0]].bandwidth
        each = bw / len(elig)
        for x in xs:
            if rate[x] > 0.0:
                remaining[x] = max(0.0, remaining[x]
                                   - (now - last_t[x]) * rate[x])
            last_t[x] = now
            if x in elig:
                rate[x] = each
                flow_end[x] = max(now, now + remaining[x] / each)
                push(flow_end[x], _FINISH, x)
            else:
                rate[x] = 0.0
                flow_end[x] = math.inf

    def gate_down(g: int) -> None:
        waiting[g] -= 1
        if waiting[g] == 0:
            ready(g)

    def deliver(g: int, t: float) -> None:
        """A transfer's payload is usable at the receiver: unblock waiters
        and retire it from its program."""
        delivered[g] = t
        snd = send_of[g]
        j = prog_of[g]
        c = completion[j]
        if c[snd.dst] < t:  # type: ignore[index]
            c[snd.dst] = t
        for w in rev[g]:
            gate_down(w)
        left[j] -= 1
        if left[j] == 0:
            finalize(j)

    def drain_folds(j: int, dst: int) -> None:
        """Sequential receive occupancy, in program order (exactly the
        single-program executor's recv_free rule)."""
        key = (j, dst)
        chain = fold_chain[key]
        p = chain_ptr[key]
        while p < len(chain) and arrived[chain[p]] is not None:
            g = chain[p]
            rf = recv_free.get(key, rel[j])
            if trace and p > 0 and rf > arrived[g]:
                # delivery waited on the receiver's fold drain: the chain
                # runs through the previous fold, not our own injection
                cause[g] = chain[p - 1]
            t = max(arrived[g], rf) + lvl_of[g].overhead
            recv_free[key] = t
            deliver(g, t)
            p += 1
        chain_ptr[key] = p

    def finalize(j: int) -> None:
        finish[j] = max(completion[j].values())  # type: ignore[union-attr]
        if trace:
            if trace_programs:
                tracer.span(PID_PROGRAMS, lab[j], lab[j], rel[j], finish[j],
                            {"sends": len(progs[j].sends),
                             "members": len(progs[j].members)})
            best, bt = None, -math.inf
            for i in range(off[j], off[j + 1]):
                d = delivered[i]
                if d is not None and d > bt:
                    best, bt = i, d
            if best is not None:
                path = []
                k: int | None = best
                while k is not None and len(path) < _CRIT_PATH_CAP:
                    s = send_of[k]
                    path.append(f"{s.src}->{s.dst}")
                    k = cause[k]
                path.reverse()
                tracer.instant(PID_PROGRAMS, lab[j], "critical_path",
                               finish[j],
                               {"edges": path, "hops": len(path),
                                "length_s": finish[j] - rel[j]})
        for k in rdeps[j]:
            pdep_left[k] -= 1
            if pdep_left[k] == 0:
                release(k)

    def release(j: int) -> None:
        t = rel[j]
        for d in pdeps[j]:
            if finish[d] > t:  # type: ignore[operator]
                t = finish[d]
        rel[j] = t
        released[j] = True
        completion[j] = {r: t for r in progs[j].members}
        if left[j] == 0:  # empty program: complete at release
            finalize(j)
            return
        for i in range(len(progs[j].sends)):
            gate_down(off[j] + i)

    # -- init ------------------------------------------------------------ #
    for g in range(n):
        j = prog_of[g]
        waiting[g] = 1 + len(gdeps[g])  # release gate + data deps
        # the FIFO gate: all but a rank's first send wait on a predecessor
    for g in range(n):
        nx = fifo_next[g]
        if nx is not None:
            waiting[nx] += 1
    for j in range(K):
        if released[j]:
            released[j] = False  # release() re-marks and opens the gate
            release(j)

    # -- event loop ------------------------------------------------------ #
    while events:
        t, _, kind, g = heapq.heappop(events)
        if done[g]:
            continue
        if kind == _ACTIVATE:
            e = (send_of[g].src, send_of[g].dst)
            edge_active.setdefault(e, []).append(g)
            active[g] = True
            last_t[g] = t
            if trace:
                astart[g] = t
            reshare(e, t)
            continue
        if not active[g] or flow_end[g] != t:
            continue  # stale finish event (rate changed since)
        snd = send_of[g]
        lvl = lvl_of[g]
        j = prog_of[g]
        done[g] = True
        active[g] = False
        e = (snd.src, snd.dst)
        edge_active[e].remove(g)
        reshare(e, t)
        inject_end = t + (lvl.overhead if snd.first else 0.0)
        c = completion[j]
        if c[snd.src] < inject_end:  # type: ignore[index]
            c[snd.src] = inject_end
        nx = fifo_next[g]
        if nx is not None:
            sender_term[nx] = inject_end
            gate_down(nx)
        arrival = t + (lvl.latency if snd.first else 0.0)
        if trace:
            tracer.link(snd.src, snd.dst, lvltab[snd.src][snd.dst],
                        astart[g], arrival, snd.nbytes, snd.kind, snd.first,
                        lab[j], t, gid)
        if snd.kind == "reduce":
            arrived[g] = arrival
            drain_folds(j, snd.dst)
        else:
            deliver(g, arrival)

    if any(f is None for f in finish):
        stuck = [j for j, f in enumerate(finish) if f is None]
        raise ValueError(
            f"programs {stuck} never completed — cyclic dependencies "
            f"between programs, or a malformed send program")
    return completion  # type: ignore[return-value]


def simulate_op(op_fn, tree, topo: Topology, nbytes: float) -> float:
    """Convenience: max completion time of op_fn(tree, nbytes) on topo."""
    sched = op_fn(tree, nbytes) if nbytes is not None else op_fn(tree)
    return max(simulate(sched, topo).values())


def probe_time(topo: Topology, p: int, q: int, nbytes: float) -> float:
    """One-way delivery time of a single point-to-point probe p→q.

    This is the postal-model quantity a timed ping observes: the sender's
    per-message cost (overhead) is on the critical path of a lone message,
    so the measured time is ``overhead + latency + nbytes/bandwidth``.
    :func:`repro.core.discovery.simulated_probes` is the vectorised
    all-pairs version of exactly this expression; keeping the scalar form
    here pins the probe semantics to the simulator's cost model.
    """
    lvl = topo.level_of_edge(p, q)
    return lvl.overhead + lvl.latency + nbytes / lvl.bandwidth
