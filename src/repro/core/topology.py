"""Multilevel network topology description.

The paper (Karonis et al., 2002) replaces MPICH-G2's "hidden communicators"
with *integer coordinate vectors*: every process carries one group id per
network stratum (site, machine, ...).  The communication level between two
processes is the first stratum at which their coordinates diverge.  This
module is the direct JAX-era port of that representation.

Strata are ordered coarsest (slowest links) first.  A topology with ``S``
strata has ``S + 1`` link classes ("levels"):

  level 0      — used when coords differ in column 0        (e.g. WAN)
  level l      — coords agree on columns < l, differ at l   (e.g. LAN)
  level S      — all columns agree: intra-leaf-group links  (e.g. SMP bus)
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

__all__ = [
    "Level",
    "Topology",
    "level_matrix",
    "paper_fig8_topology",
    "tpu_v5e_multipod",
    "magpie_machine_view",
    "magpie_site_view",
    "flat_view",
]


def level_matrix(coords: np.ndarray) -> np.ndarray:
    """(P, P) link-class index for every pair given (P, S) coordinates.

    ``[p, q]`` is the first stratum where p and q diverge, or ``S`` when
    all columns agree (including the diagonal).  This is THE pair-level
    rule — :meth:`Topology.comm_level_matrix` and the discovery fitter
    both defer to it so they can never disagree.
    """
    P, S = coords.shape
    if S == 0:
        return np.zeros((P, P), dtype=np.int64)
    mism = coords[:, None, :] != coords[None, :, :]
    return np.where(mism.any(axis=2), mism.argmax(axis=2), S).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class Level:
    """Link class parameters under the postal model.

    latency    seconds from send start until first byte visible at receiver
    bandwidth  bytes / second on the link
    overhead   seconds the *sender* is occupied per message (postal ``o``)
    """

    name: str
    latency: float
    bandwidth: float
    overhead: float = 0.0

    def xfer(self, nbytes: float) -> float:
        """End-to-end time for one message of ``nbytes``."""
        return self.latency + nbytes / self.bandwidth

    def occupy(self, nbytes: float) -> float:
        """Time the sender is busy injecting one message of ``nbytes``."""
        return self.overhead + nbytes / self.bandwidth


class Topology:
    """A multilevel topology: per-process coordinate vectors + link classes.

    coords : (P, S) int array.  Column ``l`` is the group id of each process
        at stratum ``l`` (0 = coarsest).  Group ids only need to be unique
        *within* the parent group path, but we canonicalise them to be
        globally unique per column for simplicity.
    levels : S + 1 ``Level`` objects, coarsest first.
    """

    def __init__(self, coords: np.ndarray, levels: Sequence[Level]):
        coords = np.asarray(coords, dtype=np.int64)
        if coords.ndim == 1:
            coords = coords[:, None]
        if len(levels) != coords.shape[1] + 1:
            raise ValueError(
                f"need {coords.shape[1] + 1} levels for {coords.shape[1]} "
                f"strata, got {len(levels)}"
            )
        # Canonicalise: make each column's group ids encode the full path so
        # that equal ids in column l imply equal ids in all columns < l.
        canon = np.zeros_like(coords)
        for l in range(coords.shape[1]):
            path = coords[:, : l + 1]
            _, canon[:, l] = np.unique(path, axis=0, return_inverse=True)
        self.coords = canon
        self.levels = tuple(levels)
        self._level_matrix: np.ndarray | None = None
        self._level_table: list[list[int]] | None = None

    # ------------------------------------------------------------------ #
    @property
    def nprocs(self) -> int:
        return self.coords.shape[0]

    @property
    def nstrata(self) -> int:
        return self.coords.shape[1]

    def comm_level_matrix(self) -> np.ndarray:
        """(P, P) int array: link-class index for every pair, in one
        broadcast pass (a single argmax over coordinate mismatches).

        ``[p, q]`` is the first stratum where p and q diverge, or
        ``nstrata`` when all columns agree — which includes the diagonal
        (a rank trivially shares every coordinate with itself; the scalar
        :meth:`comm_level` still rejects self links).  Built lazily once
        and reused: plan construction touches O(P²) pairs, and growing a
        dict entry-by-entry dominated tree building on 512-chip fleets.
        """
        if self._level_matrix is None:
            lm = level_matrix(self.coords)
            lm.setflags(write=False)
            self._level_matrix = lm
        return self._level_matrix

    def comm_level_table(self) -> list[list[int]]:
        """:meth:`comm_level_matrix` as nested Python lists, cached.

        The tracer's per-send hot path indexes one entry per recorded
        send; plain list indexing is ~5x cheaper than numpy scalar
        indexing, which is the difference between tracing fitting its
        <5% overhead budget and not."""
        if self._level_table is None:
            self._level_table = self.comm_level_matrix().tolist()
        return self._level_table

    def comm_level(self, p: int, q: int) -> int:
        """Index of the link class used between processes p and q."""
        if p == q:
            raise ValueError("no self link")
        return int(self.comm_level_matrix()[p, q])

    def level_of_edge(self, p: int, q: int) -> Level:
        return self.levels[self.comm_level(p, q)]

    def groups_at(self, members: Sequence[int], stratum: int) -> dict[int, list[int]]:
        """Partition ``members`` by their group id at ``stratum``.

        Insertion order follows the order of ``members`` so tree builders are
        deterministic given the member ordering (paper §3.2: every process
        builds the identical tree with no communication).
        """
        out: dict[int, list[int]] = {}
        for m in members:
            out.setdefault(int(self.coords[m, stratum]), []).append(m)
        return out

    # ------------------------------------------------------------------ #
    # Persistence — the "Fast Tuning" cache (Estefanel & Mounié,
    # cs/0408034): discovery runs once per fleet, the fitted topology is
    # written to disk, and later runs reload it instead of re-measuring.
    # ------------------------------------------------------------------ #
    def to_json(self) -> str:
        """Canonical JSON form: coords (already canonicalised) + levels."""
        doc = {
            "format": "repro.topology/v1",
            "coords": self.coords.tolist(),
            "levels": [
                {"name": l.name, "latency": l.latency,
                 "bandwidth": l.bandwidth, "overhead": l.overhead}
                for l in self.levels
            ],
        }
        return json.dumps(doc, indent=1)

    @classmethod
    def from_json(cls, doc: "str | dict") -> "Topology":
        """Inverse of :meth:`to_json`; accepts the string or parsed dict."""
        if isinstance(doc, str):
            doc = json.loads(doc)
        fmt = doc.get("format", "repro.topology/v1")
        if fmt != "repro.topology/v1":
            raise ValueError(f"unknown topology format {fmt!r}")
        coords = np.asarray(doc["coords"], dtype=np.int64)
        if coords.ndim == 1:  # S == 0 round-trips as a list of empty rows
            coords = coords.reshape(len(doc["coords"]), 0)
        levels = [Level(l["name"], l["latency"], l["bandwidth"],
                        l.get("overhead", 0.0)) for l in doc["levels"]]
        return cls(coords, levels)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Topology":
        with open(path) as f:
            return cls.from_json(f.read())

    # ------------------------------------------------------------------ #
    def collapse(self, stratum: int) -> "Topology":
        """A 2-level view keeping only one stratum (MagPIe-style baseline)."""
        return Topology(
            self.coords[:, stratum : stratum + 1],
            [self.levels[stratum], self.levels[-1]],
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Topology(P={self.nprocs}, strata={self.nstrata}, "
            f"levels={[l.name for l in self.levels]})"
        )


# ---------------------------------------------------------------------- #
# Canned topologies
# ---------------------------------------------------------------------- #

# Link classes of the paper's era (order-of-magnitude figures: TCP over WAN,
# TCP over LAN, shared-memory/switch inside a machine).
WAN = Level("wan", latency=30e-3, bandwidth=1.25e6, overhead=50e-6)     # ~10 Mb/s, 30 ms
LAN = Level("lan", latency=1e-3, bandwidth=12.5e6, overhead=20e-6)      # ~100 Mb/s, 1 ms
SMP = Level("smp", latency=30e-6, bandwidth=100e6, overhead=5e-6)       # intra-machine

# TPU v5e-era link classes for the Grid->TPU mapping (per chip).
DCN = Level("dcn", latency=10e-6, bandwidth=6.25e9, overhead=2e-6)      # inter-pod
ICI_FAR = Level("ici_far", latency=3e-6, bandwidth=50e9, overhead=1e-6)  # cross-slice ICI hops
ICI = Level("ici", latency=1e-6, bandwidth=100e9, overhead=0.5e-6)      # neighbour ICI


def paper_fig8_topology() -> Topology:
    """The paper's experiment: 16 procs on each of SDSC-SP, ANL-SP, ANL-O2K.

    Two sites (SDSC, ANL); ANL holds two machines.  Strata = [site, machine].
    """
    site = [0] * 16 + [1] * 32
    machine = [0] * 16 + [1] * 16 + [2] * 16
    coords = np.stack([site, machine], axis=1)
    return Topology(coords, [WAN, LAN, SMP])


def tpu_v5e_multipod(pods: int = 2, boards: int = 16, chips_per_board: int = 16) -> Topology:
    """A multi-pod TPU fleet: strata = [pod, board(=sub-slice)]; leaves = chips."""
    P = pods * boards * chips_per_board
    idx = np.arange(P)
    pod = idx // (boards * chips_per_board)
    board = idx // chips_per_board
    coords = np.stack([pod, board], axis=1)
    return Topology(coords, [DCN, ICI_FAR, ICI])


def magpie_machine_view(topo: Topology) -> Topology:
    """MagPIe baseline A: 2-level clustering on *machine* boundaries."""
    return topo.collapse(topo.nstrata - 1)


def magpie_site_view(topo: Topology) -> Topology:
    """MagPIe baseline B: 2-level clustering on *site* boundaries."""
    return topo.collapse(0)


def flat_view(topo: Topology) -> Topology:
    """Topology-unaware view: every pair communicates at the SLOWEST class.

    This models MPICH's assumption of uniform point-to-point cost; the
    simulator still charges true per-edge costs — ``flat_view`` is used only
    to *build* the (oblivious) tree, mirroring how MPICH's binomial tree is
    laid out over ranks with no topology knowledge.
    """
    coords = np.zeros((topo.nprocs, 1), dtype=np.int64)
    return Topology(coords, [topo.levels[0], topo.levels[-1]])
