"""Slow-link gradient compression: blockwise int8 quantisation + error
feedback.

Topology-aware by construction: compression is applied ONLY on the pod (DCN)
hop of the multilevel all-reduce — the paper's principle of spending effort
on the slowest level.  int8 halves/quarters the bytes crossing the DCN while
the fast intra-pod stages stay full precision.

The quantiser has Pallas kernels (`repro.kernels.quant`); on TPU the
EF-corrected path uses the FUSED ``quantize_ef_int8`` kernel (x+ef, quantise,
and the residual update in one VMEM pass — ~2.6x less HBM traffic than the
two-pass quantise/dequantise/subtract below, see ``BENCH_kernels.json``).
Off-TPU this module defaults to the pure-jnp reference implementation (the
interpreter would only slow CPU tests down); pass ``use_kernel=True`` to
force the kernel (interpret mode resolves per backend).

This module is also the single source of truth for the quantiser's tiling
constants: ``BLOCK`` (elements per scale), ``TILE`` (blocks per kernel VMEM
stage) and ``QTILE = BLOCK * TILE`` (elements per stage — the kernel's
divisibility requirement).  ``repro.kernels.quant`` imports them from here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["BLOCK", "TILE", "QTILE", "WIRE_BYTES_PER_ELEM", "pad_to_block",
           "quantize_int8", "dequantize_int8", "compressed_psum",
           "apply_error_feedback"]

BLOCK = 256        # elements per scale block
TILE = 32          # quant blocks per kernel grid step
QTILE = BLOCK * TILE   # elements per kernel VMEM stage (kernel granularity)

# int8 payload + one f32 scale per BLOCK: the compressed slow-hop wire cost
# per f32 element (vs 4.0 uncompressed) — used by the engine/benchmarks to
# price the DCN exchange.
WIRE_BYTES_PER_ELEM = 1.0 + 4.0 / BLOCK


def pad_to_block(x: jax.Array, multiple: int = BLOCK):
    """Zero-pad a 1-D buffer to a multiple.  Returns ``(padded, pad)`` with
    ``pad`` a python int, so callers can slice results back without
    re-deriving the quantiser's granularity."""
    if x.ndim != 1:
        raise ValueError(f"pad_to_block needs a 1-D buffer, got {x.shape}")
    pad = (-x.size) % multiple
    return (jnp.pad(x, (0, pad)) if pad else x), pad


def _kernel_default() -> bool:
    # compiled Pallas only pays off on real TPU; CPU tests keep the jnp path
    return jax.default_backend() == "tpu"


def quantize_int8(x: jax.Array, block: int = BLOCK) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantisation of a 1-D f32 buffer.

    Returns (q:int8 [N], scales:f32 [N/block]).  N must divide by block —
    callers pad (the multilevel allreduce already pads to the dp degree; we
    additionally pad to BLOCK, see :func:`pad_to_block`).
    """
    # real exceptions, not `assert`: a shape error here must not turn into
    # silently garbled gradients under `python -O`
    if x.ndim != 1 or x.size % block != 0:
        raise ValueError(
            f"quantize_int8 needs a 1-D buffer whose size is a multiple "
            f"of the block; got shape {x.shape} with block {block}")
    xb = x.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_int8(q: jax.Array, scales: jax.Array, block: int = BLOCK) -> jax.Array:
    return (q.reshape(-1, block).astype(jnp.float32)
            * scales[:, None]).reshape(-1)


def _resolve_use_kernel(use_kernel: bool | None, block: int) -> bool:
    if use_kernel is None:
        return block == BLOCK and _kernel_default()
    if use_kernel and block != BLOCK:
        raise ValueError(f"the Pallas quantiser is tiled for block={BLOCK}; "
                         f"pass use_kernel=False for block={block}")
    return bool(use_kernel)


def compressed_psum(x: jax.Array, axis: str, block: int = BLOCK,
                    ef: jax.Array | None = None,
                    use_kernel: bool | None = None):
    """All-reduce over ``axis`` sending int8 on the wire.

    int8 cannot be accumulated in-network; we all-gather the quantised shards
    (+ scales) across the slow axis and fold locally.  With the multilevel
    decomposition the payload is already 1/|data| of the gradient, so the
    gather across a handful of pods is small; wire bytes = N(int8) + N/block
    scales ≈ 0.26x of f32.

    ``ef`` is the error-feedback residual (same size as ``x``): when
    given, it is added to ``x`` before quantisation and the call returns
    ``(out, new_ef)`` where ``new_ef`` is the local quantisation error of
    the corrected buffer.  Carrying that residual across steps is what
    stops the int8 rounding bias from accumulating in the optimiser —
    without it, a multi-step compressed all-reduce drifts from the exact
    path (classic EF-SGD; see ``apply_error_feedback``).

    ``use_kernel``: None -> auto (Pallas kernel on TPU, jnp elsewhere).
    The kernel path pads to :data:`QTILE` instead of ``block`` (slightly
    more wire bytes on unaligned buffers; size residuals with
    ``collectives.compress_ef_zeros(..., tile=QTILE)`` to make the shard
    pad-free) and, with ``ef``, runs the FUSED quantise+EF kernel: one
    VMEM pass instead of quantise/dequantise/subtract round-trips.
    """
    use_kernel = _resolve_use_kernel(use_kernel, block)
    new_ef = None
    if use_kernel:
        from repro.kernels import quant as kq  # lazy: keep core import-light
        xp, pad = pad_to_block(x, QTILE)
        if ef is not None:
            efp, _ = pad_to_block(ef.reshape(-1), QTILE)
            q, s, new_ef = kq.quantize_ef_int8(xp, efp)
        else:
            q, s = kq.quantize_int8(xp)
    else:
        xin = x if ef is None else x + ef.reshape(x.shape)
        xp, pad = pad_to_block(xin, block)
        q, s = quantize_int8(xp, block)
    qs = lax.all_gather(q, axis)          # [npods, N] int8 on the wire
    ss = lax.all_gather(s, axis)          # [npods, N/block] f32 (tiny)
    full = jax.vmap(lambda qq, sc: dequantize_int8(qq, sc, block))(qs, ss)
    out = jnp.sum(full, axis=0)
    if pad:
        out = out[: out.size - pad]
    if ef is None:
        return out
    if use_kernel:
        return out, new_ef[: x.size]
    deq = dequantize_int8(q, s, block)[: xin.size]  # own shard, local
    return out, xin - deq


def apply_error_feedback(
    grad_flat: jax.Array, ef: jax.Array, block: int = BLOCK,
    use_kernel: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Classic EF: add residual, quantise-dequantise locally to compute the
    new residual.  Returns (corrected_grad, new_ef).  This is the local
    (no-collective) form of the correction :func:`compressed_psum` applies
    when handed an ``ef`` buffer.  ``use_kernel`` as in
    :func:`compressed_psum`: the fused kernel produces the residual in the
    same VMEM pass as the quantisation."""
    use_kernel = _resolve_use_kernel(use_kernel, block)
    g = grad_flat + ef
    if use_kernel:
        from repro.kernels import quant as kq
        gp, _ = pad_to_block(grad_flat, QTILE)
        efp, _ = pad_to_block(ef, QTILE)
        _, _, new_ef = kq.quantize_ef_int8(gp, efp)
        return g, new_ef[: g.size]
    gp, _ = pad_to_block(g, block)
    q, s = quantize_int8(gp, block)
    deq = dequantize_int8(q, s, block)
    deq = deq[: g.size]
    return g, g - deq
