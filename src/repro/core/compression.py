"""Slow-link gradient compression: blockwise int8 quantisation + error
feedback.

Topology-aware by construction: compression is applied ONLY on the pod (DCN)
hop of the multilevel all-reduce — the paper's principle of spending effort
on the slowest level.  int8 halves/quarters the bytes crossing the DCN while
the fast intra-pod stages stay full precision.

The quantiser has a Pallas kernel (`repro.kernels.quant`) for the TPU target;
this module falls back to the pure-jnp reference implementation when the
kernel is disabled (e.g. under vmap tracing on CPU tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "apply_error_feedback"]

BLOCK = 256  # elements per scale block


def quantize_int8(x: jax.Array, block: int = BLOCK) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantisation of a 1-D f32 buffer.

    Returns (q:int8 [N], scales:f32 [N/block]).  N must divide by block —
    callers pad (the multilevel allreduce already pads to the dp degree; we
    additionally pad to BLOCK).
    """
    # real exceptions, not `assert`: a shape error here must not turn into
    # silently garbled gradients under `python -O`
    if x.ndim != 1 or x.size % block != 0:
        raise ValueError(
            f"quantize_int8 needs a 1-D buffer whose size is a multiple "
            f"of the block; got shape {x.shape} with block {block}")
    xb = x.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_int8(q: jax.Array, scales: jax.Array, block: int = BLOCK) -> jax.Array:
    return (q.reshape(-1, block).astype(jnp.float32)
            * scales[:, None]).reshape(-1)


def compressed_psum(x: jax.Array, axis: str, block: int = BLOCK,
                    ef: jax.Array | None = None):
    """All-reduce over ``axis`` sending int8 on the wire.

    int8 cannot be accumulated in-network; we all-gather the quantised shards
    (+ scales) across the slow axis and fold locally.  With the multilevel
    decomposition the payload is already 1/|data| of the gradient, so the
    gather across a handful of pods is small; wire bytes = N(int8) + N/block
    scales ≈ 0.26x of f32.

    ``ef`` is the error-feedback residual (same shape as ``x``): when
    given, it is added to ``x`` before quantisation and the call returns
    ``(out, new_ef)`` where ``new_ef`` is the local quantisation error of
    the corrected buffer.  Carrying that residual across steps is what
    stops the int8 rounding bias from accumulating in the optimiser —
    without it, a multi-step compressed all-reduce drifts from the exact
    path (classic EF-SGD; see ``apply_error_feedback``).
    """
    xin = x if ef is None else x + ef.reshape(x.shape)
    pad = (-xin.size) % block
    xp = jnp.pad(xin, (0, pad)) if pad else xin
    q, s = quantize_int8(xp, block)
    qs = lax.all_gather(q, axis)          # [npods, N] int8 on the wire
    ss = lax.all_gather(s, axis)          # [npods, N/block] f32 (tiny)
    full = jax.vmap(lambda qq, sc: dequantize_int8(qq, sc, block))(qs, ss)
    out = jnp.sum(full, axis=0)
    if pad:
        out = out[: out.size - pad]
    if ef is None:
        return out
    deq = dequantize_int8(q, s, block)[: xin.size]  # own shard, local
    return out, xin - deq


def apply_error_feedback(
    grad_flat: jax.Array, ef: jax.Array, block: int = BLOCK
) -> tuple[jax.Array, jax.Array]:
    """Classic EF: add residual, quantise-dequantise locally to compute the
    new residual.  Returns (corrected_grad, new_ef).  This is the local
    (no-collective) form of the correction :func:`compressed_psum` applies
    when handed an ``ef`` buffer."""
    g = grad_flat + ef
    pad = (-g.size) % block
    gp = jnp.pad(g, (0, pad)) if pad else g
    q, s = quantize_int8(gp, block)
    deq = dequantize_int8(q, s, block)
    deq = deq[: g.size]
    return g, g - deq
