"""JAX hierarchical (axis-decomposed) collectives — the paper's technique as
it applies to TPU training.

ENGINE MODULE: these are the primitives behind the ``backend="jax"`` path of
:class:`repro.core.communicator.Communicator`, which is the public entry
point (``Communicator(topo, backend="jax", slow_axis=..., fast_axes=...)``;
``allreduce_tree`` for fused gradient pytrees).  Call these directly only
when composing new inside-shard_map code.

The Grid mapping: the ``pod`` mesh axis is the WAN (slow DCN links), the
intra-pod axes are the LAN/machine levels (fast ICI).  The paper's rule —
*minimise traffic on the slowest level* — becomes, for a data-parallel
gradient all-reduce over axes (pod, data):

  flat        :  psum(g, ("pod","data"))          # |g| bytes cross the DCN
  multilevel  :  s = psum_scatter(g, "data")      # intra-pod, fast
                 s = psum(s, "pod")               # |g|/|data| bytes on DCN
                 g = all_gather(s, "data")        # intra-pod, fast

i.e. inter-pod traffic drops by the intra-pod degree — the direct analogue of
the paper's "log C -> 1 wide-area messages".

All functions here are *inside-shard_map* primitives operating on the local
shard; `multilevel_psum_tree` is the user-facing pytree version that fuses
all gradient leaves into one flat buffer (single collective per level instead
of one per parameter — a beyond-paper optimization recorded in EXPERIMENTS).
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import compression

__all__ = [
    "flat_psum",
    "multilevel_psum",
    "multilevel_psum_tree",
    "bucketed_psum_tree",
    "compress_ef_zeros",
    "flatten_tree",
    "unflatten_tree",
]


def flat_psum(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """Topology-unaware baseline: one all-reduce over the full device set."""
    return lax.psum(x, tuple(axes))


def multilevel_psum(
    x: jax.Array,
    slow_axis: str | None,
    fast_axes: Sequence[str],
    compress_slow: bool = False,
    ef: jax.Array | None = None,
):
    """Multilevel all-reduce of a 1-D buffer whose length divides the product
    of ``fast_axes`` sizes.  reduce-scatter intra-pod, (optionally int8-
    compressed) exchange across pods, all-gather intra-pod.

    ``ef`` is the error-feedback residual for the compressed slow hop: it
    must match the post-reduce-scatter shard (see :func:`compress_ef_zeros`)
    and makes the call return ``(result, new_ef)``.  Passing it through the
    uncompressed path returns it unchanged, so callers can thread one
    residual buffer regardless of mode.
    """
    if x.ndim != 1:
        raise ValueError("multilevel_psum operates on flat 1-D buffers")
    for ax in fast_axes:
        x = lax.psum_scatter(x, ax, scatter_dimension=0, tiled=True)
    new_ef = ef
    if slow_axis is not None:
        if compress_slow and ef is not None:
            x, new_ef = compression.compressed_psum(x, slow_axis, ef=ef)
        elif compress_slow:
            x = compression.compressed_psum(x, slow_axis)
        else:
            x = lax.psum(x, slow_axis)
    for ax in reversed(fast_axes):
        x = lax.all_gather(x, ax, axis=0, tiled=True)
    return x if ef is None else (x, new_ef)


def compress_ef_zeros(grads: Any, fast_degree: int,
                      tile: int = 1) -> jax.Array:
    """Zero-initialised error-feedback residual for
    ``multilevel_psum_tree(..., mode="multilevel_compress", ef=...)``:
    shaped like the post-reduce-scatter shard of the fused flat buffer
    (total padded leaf count divided by the fast-axis degree).  This is
    the PER-RANK shard; residuals diverge across dp ranks, so when
    entering ``shard_map`` from the outside, tile it by the dp degree and
    shard it over ``(slow, *fast)``.

    ``tile``: additionally round the PER-RANK shard up to a multiple —
    pass ``compression.QTILE`` so the fused Pallas quantiser sees a
    pad-free shard (``multilevel_psum_tree`` pads the flat buffer to
    ``ef.size * fast_degree`` to match)."""
    total = sum(int(l.size) for l in jax.tree.leaves(grads))
    fd = max(fast_degree, 1)
    padded = total + (-total) % (fd * max(tile, 1))
    return jnp.zeros((padded // fd,), jnp.float32)


# ---------------------------------------------------------------------- #
# Pytree fusion: one flat buffer per step.
# ---------------------------------------------------------------------- #

def _sizes(tree: Any) -> tuple[list[Any], list[int], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, [l.size for l in leaves], treedef


def flatten_tree(tree: Any, pad_multiple: int) -> tuple[jax.Array, Any]:
    """Ravel + concat all leaves (f32 accumulate) and pad to a multiple."""
    leaves, sizes, treedef = _sizes(tree)
    flat = jnp.concatenate([l.ravel().astype(jnp.float32) for l in leaves])
    pad = (-flat.size) % pad_multiple
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, (treedef, [l.shape for l in leaves], [l.dtype for l in leaves], sizes, pad)


def unflatten_tree(flat: jax.Array, spec: Any) -> Any:
    treedef, shapes, dtypes, sizes, pad = spec
    if pad:
        flat = flat[: flat.size - pad]
    out, off = [], 0
    for shape, dtype, size in zip(shapes, dtypes, sizes):
        out.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def multilevel_psum_tree(
    grads: Any,
    slow_axis: str | None,
    fast_axes: Sequence[str],
    mode: str = "multilevel",
    mean_over: int | None = None,
    ef: jax.Array | None = None,
) -> Any:
    """All-reduce a gradient pytree across (slow_axis, *fast_axes).

    mode: "flat" | "multilevel" | "multilevel_compress".
    ``mean_over``: divide by this count (global DP degree) when averaging.
    ``ef``: error-feedback residual for the compressed mode (see
    :func:`compress_ef_zeros`); when given the call returns
    ``(grads, new_ef)`` and the residual must be threaded to the next step.
    """
    axes = ([slow_axis] if slow_axis else []) + list(fast_axes)
    new_ef = ef
    if mode == "flat":
        out = jax.tree.map(lambda g: lax.psum(g, tuple(axes)), grads)
    else:
        # lax.psum of a Python constant folds to the static axis size.
        pad_mult = 1
        for ax in fast_axes:
            pad_mult *= int(lax.psum(1, ax))
        flat, spec = flatten_tree(grads, pad_mult)
        if ef is not None:
            # The residual's size defines the shard: compress_ef_zeros may
            # round it up (tile=QTILE keeps the fused quantiser pad-free),
            # so grow the flat buffer to match and fold the extra zeros
            # into the spec's pad for unflatten.
            want = int(ef.size) * pad_mult
            if flat.size > want:
                raise ValueError(
                    f"ef residual too small for this pytree: shard is "
                    f"{ef.size} elements but the padded flat buffer needs "
                    f"{flat.size // pad_mult} (see compress_ef_zeros)")
            if flat.size < want:
                extra = want - flat.size
                flat = jnp.pad(flat, (0, extra))
                treedef, shapes, dtypes, sizes, pad = spec
                spec = (treedef, shapes, dtypes, sizes, pad + extra)
        flat = multilevel_psum(
            flat, slow_axis, fast_axes,
            compress_slow=(mode == "multilevel_compress"), ef=ef,
        )
        if ef is not None:
            flat, new_ef = flat
        out = unflatten_tree(flat, spec)
    if mean_over:
        out = jax.tree.map(lambda g: g / mean_over, out)
    return out if ef is None else (out, new_ef)


def bucketed_psum_tree(
    grads: Any,
    slow_axis: str | None,
    fast_axes: Sequence[str],
    *,
    bucket_bytes: float,
    mode: str = "multilevel",
    mean_over: int | None = None,
) -> Any:
    """All-reduce a gradient pytree as SIZE-TARGETED BUCKETS instead of one
    monolithic flat buffer.

    Leaves are walked in REVERSE flatten order — the order backward
    produces them — and greedily grouped into buckets of at least
    ``bucket_bytes`` (f32 wire bytes; the final bucket may be smaller).
    Each bucket syncs as its own fused flat buffer, so the lowered HLO
    carries one collective per bucket: XLA's latency-hiding scheduler can
    overlap bucket k's all-reduce with the backward computation of the
    layers below it, and the simulation plane prices exactly this program
    through :func:`repro.core.engine.overlapped_step_times`.

    mode: ``"flat"`` | ``"multilevel"`` — numerics identical to
    :func:`multilevel_psum_tree` (same f32 accumulation), only the
    collective granularity changes.  The compressed mode is refused: its
    error-feedback residual is shaped by the exchange, and re-bucketing
    would silently re-shard it.
    """
    if mode not in ("flat", "multilevel"):
        raise ValueError(f"bucketed sync supports modes 'flat'/'multilevel',"
                         f" got {mode!r}")
    from repro.core.engine import partition_buckets

    leaves, treedef = jax.tree.flatten(grads)
    buckets = partition_buckets([4.0 * l.size for l in leaves],
                                float(bucket_bytes))
    pad_mult = 1
    if mode == "multilevel":
        for ax in fast_axes:
            pad_mult *= int(lax.psum(1, ax))
    out: list[Any] = [None] * len(leaves)
    for idx in buckets:
        flat, spec = flatten_tree([leaves[i] for i in idx], pad_mult)
        if mode == "flat":
            axes = ([slow_axis] if slow_axis else []) + list(fast_axes)
            flat = lax.psum(flat, tuple(axes))
        else:
            flat = multilevel_psum(flat, slow_axis, fast_axes)
        for i, leaf in zip(idx, unflatten_tree(flat, spec)):
            out[i] = leaf
    res = jax.tree.unflatten(treedef, out)
    if mean_over:
        res = jax.tree.map(lambda g: g / mean_over, res)
    return res
