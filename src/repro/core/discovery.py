"""Automatic topology discovery: probe, cluster, and fit multilevel
topologies at runtime.

The paper's trees are "constructed automatically during execution" — but
only *given* topology information the runtime supplies (MPICH-G2 read it
from RSL "depths" the user wrote by hand).  This module closes the loop the
way Estefanel & Mounié (cs/0408033) proposed: infer the logical homogeneous
clusters from measured point-to-point performance, then cache the decision
("Fast Tuning", cs/0408034) so a fleet is measured once, not per job.

Three probe sources feed one pipeline::

    probes ──> cluster_probes ──> fit_levels ──> Topology
    (ProbeSet)  (agglomerative +   (least-squares    (canonical coords
                 dendrogram gap     Level per         + link classes)
                 cut → strata)      stratum)

1. :func:`simulated_probes` — all-pairs postal-model timings sampled from a
   hidden ground-truth :class:`Topology` with configurable multiplicative
   noise.  This is the validation plane: recovery accuracy vs. noise is a
   measurable quantity (``benchmarks/bench_discovery.py``).
2. :func:`environment_topology` — coordinates straight from
   ``jax.devices()`` metadata (slice_index, process_index): the modern
   analogue of RSL-supplied topology depths.  No timing needed.
3. :func:`device_probes` — timed round-trip ``ppermute`` exchanges at two
   message sizes on a real mesh, fitting per-pair latency and bandwidth.

The clusterer makes NO layer-count assumption: strata fall out of the
measurements (cost-gap plateaus in the dendrogram), which is the paper's
core thesis — as many levels as the network actually has.

Front doors: :func:`discover` (source dispatch + persistence) and
:meth:`repro.core.Communicator.from_probes`.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Sequence

import numpy as np

from .topology import Level, Topology, level_matrix

__all__ = [
    "ProbeSet",
    "TargetedProbes",
    "DEFAULT_PROBE_SIZES",
    "DEFAULT_GAP_FACTOR",
    "simulated_probes",
    "environment_topology",
    "device_probes",
    "cluster_probes",
    "fit_levels",
    "fit_topology",
    "discover",
    "representative_pairs",
    "targeted_probes",
    "synthetic_probes",
    "refit_levels",
    "measure_drift",
]


# Two sizes bracket the latency- and bandwidth-dominated regimes; the
# per-pair affine model t = latency + nbytes/bandwidth is then exactly
# identified (slope → bandwidth, intercept → latency).
DEFAULT_PROBE_SIZES = (1024.0, float(1 << 20))

# A dendrogram merge height more than this factor above its predecessor
# starts a new stratum.  Within one homogeneous link class, ±10%
# multiplicative probe noise bounds consecutive-height ratios near 1.2;
# adjacent real link classes in every topology we model differ by ≥ 2×.
DEFAULT_GAP_FACTOR = 1.5


# ---------------------------------------------------------------------- #
# Probe container
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ProbeSet:
    """All-pairs point-to-point measurements at two message sizes.

    sizes  : the two probe payloads, bytes, ascending.
    times  : (P, P, 2) one-way delivery seconds; ``times[p, q, k]`` is a
             lone message p→q of ``sizes[k]`` (diagonal is zero/ignored).
    inject : optional (P, P) per-message *sender occupancy* at ``sizes[0]``,
             from a back-to-back injection-rate probe.  Separates postal
             overhead from latency; without it discovered overhead is 0.
    """

    sizes: tuple[float, float]
    times: np.ndarray
    inject: np.ndarray | None = None

    def __post_init__(self):
        t = np.asarray(self.times, dtype=float)
        if t.ndim != 3 or t.shape[0] != t.shape[1] or t.shape[2] != 2:
            raise ValueError(f"times must be (P, P, 2), got {t.shape}")
        if self.sizes[0] >= self.sizes[1]:
            raise ValueError("probe sizes must be ascending")
        object.__setattr__(self, "times", t)
        if self.inject is not None:
            inj = np.asarray(self.inject, dtype=float)
            if inj.shape != t.shape[:2]:
                raise ValueError(
                    f"inject must be (P, P), got {inj.shape}")
            object.__setattr__(self, "inject", inj)

    @property
    def nprocs(self) -> int:
        return self.times.shape[0]

    def dissimilarity(self) -> np.ndarray:
        """Symmetric (P, P) clustering metric: summed probe time over both
        sizes, so strata that differ in *either* latency or bandwidth
        separate; direction noise is averaged out."""
        d = self.times.sum(axis=2)
        return (d + d.T) / 2.0


# ---------------------------------------------------------------------- #
# Source 1: simulated probes from a hidden ground truth
# ---------------------------------------------------------------------- #

def simulated_probes(topo: Topology, *, noise: float = 0.0, seed: int = 0,
                     sizes: Sequence[float] = DEFAULT_PROBE_SIZES,
                     ) -> ProbeSet:
    """Sample all-pairs probes from ``topo`` under the postal model.

    Per pair and size the one-way time is
    :func:`repro.core.simulator.probe_time` — ``overhead + latency +
    nbytes/bandwidth`` — scaled by independent multiplicative noise drawn
    uniformly from ``[1-noise, 1+noise]``.  Also emits the injection-rate
    probe (``overhead + nbytes/bandwidth``) so the fit can separate
    overhead from latency and recover the ground truth exactly at zero
    noise.
    """
    if not 0.0 <= noise < 1.0:
        raise ValueError(f"noise must be in [0, 1), got {noise}")
    s1, s2 = float(sizes[0]), float(sizes[1])
    rng = np.random.default_rng(seed)
    lm = topo.comm_level_matrix()
    lat = np.array([l.latency for l in topo.levels])[lm]
    bw = np.array([l.bandwidth for l in topo.levels])[lm]
    ovh = np.array([l.overhead for l in topo.levels])[lm]

    def jitter(shape):
        return 1.0 + noise * rng.uniform(-1.0, 1.0, shape) if noise else 1.0

    times = np.stack([(ovh + lat + s / bw) * jitter(lm.shape)
                      for s in (s1, s2)], axis=2)
    inject = (ovh + s1 / bw) * jitter(lm.shape)
    eye = np.eye(topo.nprocs, dtype=bool)
    times[eye] = 0.0
    inject[eye] = 0.0
    return ProbeSet(sizes=(s1, s2), times=times, inject=inject)


# ---------------------------------------------------------------------- #
# Source 2: environment metadata (the RSL-depths analogue)
# ---------------------------------------------------------------------- #

# Default link classes per platform, coarsest first; the fitted topology
# keeps the innermost ``S + 1`` of them for ``S`` discovered strata.
_ENV_LEVELS = {
    "tpu": None,  # filled below from topology's canned TPU constants
    "generic": (
        Level("dcn", latency=10e-6, bandwidth=6.25e9, overhead=2e-6),
        Level("host", latency=5e-6, bandwidth=12.5e9, overhead=2e-6),
        Level("local", latency=1e-6, bandwidth=100e9, overhead=0.5e-6),
    ),
}


def environment_topology(devices: Sequence | None = None) -> Topology:
    """Derive a topology from device metadata alone — no timing.

    Strata candidates, coarsest first: ``slice_index`` (pod / ICI domain)
    and ``process_index`` (host).  Columns that do not discriminate (all
    devices agree) are dropped, so a single-host run yields a flat
    single-class topology — the number of levels follows the environment,
    never a fixed template.  Rank order is the ``jax.devices()`` order,
    matching the flat mesh axis used by the device backends.
    """
    if devices is None:
        import jax

        devices = jax.devices()
    devices = list(devices)
    if not devices:
        raise ValueError("no devices to derive a topology from")

    def attr(d, name):
        v = getattr(d, name, None)
        return int(v) if v is not None else 0

    cols = [
        [attr(d, "slice_index") for d in devices],
        [attr(d, "process_index") for d in devices],
    ]
    cols = [c for c in cols if len(set(c)) > 1]
    coords = (np.stack(cols, axis=1) if cols
              else np.zeros((len(devices), 0), dtype=np.int64))

    platform = getattr(devices[0], "platform", "cpu")
    if platform == "tpu":
        from .topology import DCN, ICI, ICI_FAR

        classes = (DCN, ICI_FAR, ICI)
    else:
        classes = _ENV_LEVELS["generic"]
    need = coords.shape[1] + 1
    levels = list(classes[-need:])
    while len(levels) < need:  # more strata than canned classes: pad coarse
        levels.insert(0, classes[0])
    return Topology(coords, levels)


# ---------------------------------------------------------------------- #
# Source 3: timed device probes (round-trip ppermute)
# ---------------------------------------------------------------------- #

def device_probes(*, sizes: Sequence[float] = DEFAULT_PROBE_SIZES,
                  repeats: int = 3, roundtrips: int = 4,
                  devices: Sequence | None = None) -> ProbeSet:
    """Measure per-pair one-way time on a real mesh via ``ppermute``.

    For every pair (i, j) a jitted program bounces a payload i→j→i
    ``roundtrips`` times; the best of ``repeats`` timed runs divided by
    ``2 * roundtrips`` estimates the one-way time.  Two payload sizes give
    the affine fit its two points.  Cost is O(P²) compilations — this is
    the *once-per-fleet* measurement the persistence cache
    (:func:`discover` ``path=``) exists to amortise.
    """
    import time

    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.compat import shard_map

    devices = list(devices if devices is not None else jax.devices())
    P = len(devices)
    if P < 2:
        raise ValueError(f"device probes need >= 2 devices, got {P}")
    mesh = jax.sharding.Mesh(np.array(devices), ("probe",))
    spec = jax.sharding.PartitionSpec("probe")
    s1, s2 = float(sizes[0]), float(sizes[1])
    times = np.zeros((P, P, 2))

    for si, s in enumerate((s1, s2)):
        n = max(int(s) // 4, 1)  # float32 payload of ~s bytes per device
        x = jnp.zeros((P, n), jnp.float32)
        for i in range(P):
            for j in range(P):
                if i == j:
                    continue

                def bounce(v, fwd=((i, j),), bwd=((j, i),)):
                    def body(_, u):
                        u = lax.ppermute(u, "probe", fwd)
                        return lax.ppermute(u, "probe", bwd)
                    return lax.fori_loop(0, roundtrips, body, v)

                f = jax.jit(shard_map(bounce, mesh=mesh, in_specs=spec,
                                      out_specs=spec))
                jax.block_until_ready(f(x))  # compile + warm
                best = math.inf
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(f(x))
                    best = min(best, time.perf_counter() - t0)
                times[i, j, si] = best / (2 * roundtrips)
    return ProbeSet(sizes=(s1, s2), times=times, inject=None)


# ---------------------------------------------------------------------- #
# The pipeline: cluster → cut → fit
# ---------------------------------------------------------------------- #

def _average_linkage(D: np.ndarray) -> list[tuple[int, int, float]]:
    """UPGMA agglomerative clustering on a symmetric dissimilarity matrix.

    Returns the merge sequence ``(i, j, height)`` — representatives are
    original point indices; heights are non-decreasing (average linkage is
    reducible, so the dendrogram has no inversions).  Lance-Williams row
    updates keep each of the P-1 merges at one vectorised argmin + O(P)
    update, comfortably fast at P = 512.
    """
    P = D.shape[0]
    Dm = D.astype(float).copy()
    np.fill_diagonal(Dm, np.inf)
    sizes = np.ones(P)
    merges: list[tuple[int, int, float]] = []
    for _ in range(P - 1):
        flat = np.argmin(Dm)
        i, j = divmod(int(flat), P)
        if i > j:
            i, j = j, i
        h = float(Dm[i, j])
        ni, nj = sizes[i], sizes[j]
        row = (ni * Dm[i] + nj * Dm[j]) / (ni + nj)
        Dm[i, :] = row
        Dm[:, i] = row
        Dm[i, i] = np.inf
        Dm[j, :] = np.inf
        Dm[:, j] = np.inf
        sizes[i] = ni + nj
        merges.append((i, j, h))
    return merges


def _labels_at(P: int, merges: Sequence[tuple[int, int, float]],
               threshold: float) -> np.ndarray:
    """Cluster labels after applying every merge with height < threshold."""
    parent = np.arange(P)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i, j, h in merges:
        if h < threshold:
            parent[find(j)] = find(i)
    return np.array([find(r) for r in range(P)])


def cluster_probes(probes: ProbeSet, *,
                   gap_factor: float = DEFAULT_GAP_FACTOR) -> np.ndarray:
    """Infer per-process stratum coordinates from a probe matrix.

    Agglomerative clustering orders all merges by cost; plateaus separated
    by gaps (consecutive merge heights with ratio > ``gap_factor``) are the
    link classes.  Each gap becomes one dendrogram cut = one stratum; cuts
    are applied coarsest first so column 0 of the result is the slowest
    stratum, matching :class:`Topology`'s convention.  Zero gaps (a
    homogeneous network) yield a (P, 0) coordinate array — a single link
    class, no strata.
    """
    P = probes.nprocs
    if P < 2:
        return np.zeros((P, 0), dtype=np.int64)
    merges = _average_linkage(probes.dissimilarity())
    heights = sorted(h for _, _, h in merges)
    cuts = []
    for a, b in zip(heights, heights[1:]):
        if b > gap_factor * max(a, 1e-15):
            cuts.append(math.sqrt(max(a, 1e-15) * b))
    if not cuts:
        return np.zeros((P, 0), dtype=np.int64)
    cols = [_labels_at(P, merges, c) for c in sorted(cuts, reverse=True)]
    return np.stack(cols, axis=1)


def fit_levels(probes: ProbeSet, coords: np.ndarray) -> list[Level]:
    """Least-squares :class:`Level` per link class given the strata.

    For class ``l`` the samples are every ordered pair at that level, both
    probe sizes; the affine fit ``t = a + s·b`` gives ``bandwidth = 1/b``
    and intercept ``a = latency + overhead``.  When the injection-rate
    probe is present, per-message occupancy minus the bandwidth term
    separates ``overhead`` out of the intercept — at zero noise the ground
    truth is recovered exactly.  A class with no pairs (e.g. singleton leaf
    groups) inherits its nearest coarser fitted class.
    """
    P = probes.nprocs
    nstrata = coords.shape[1]
    s1, s2 = probes.sizes
    lm = level_matrix(coords)
    off = ~np.eye(P, dtype=bool)

    levels: list[Level] = []
    for l in range(nstrata + 1):
        mask = (lm == l) & off
        if not mask.any():
            if not levels:
                raise ValueError("cannot fit any link class from "
                                 f"{P} process(es)")
            prev = levels[-1]
            levels.append(Level(f"d{l}", prev.latency, prev.bandwidth,
                                prev.overhead))
            continue
        t1 = float(probes.times[..., 0][mask].mean())
        t2 = float(probes.times[..., 1][mask].mean())
        slope = max((t2 - t1) / (s2 - s1), 1e-30)
        bandwidth = 1.0 / slope
        intercept = ((t1 - s1 * slope) + (t2 - s2 * slope)) / 2.0
        overhead = 0.0
        if probes.inject is not None:
            overhead = max(
                float(probes.inject[mask].mean()) - s1 * slope, 0.0)
        latency = max(intercept - overhead, 0.0)
        levels.append(Level(f"d{l}", latency, bandwidth, overhead))
    return levels


def fit_topology(probes: ProbeSet, *,
                 gap_factor: float = DEFAULT_GAP_FACTOR) -> Topology:
    """The full pipeline: probes → strata → fitted levels → Topology."""
    coords = cluster_probes(probes, gap_factor=gap_factor)
    return Topology(coords, fit_levels(probes, coords))


# ---------------------------------------------------------------------- #
# Targeted drift re-probing: O(strata · group-count) instead of O(P²).
#
# Full discovery measures every pair because it must *find* the strata.
# Once a topology is known, checking whether its link classes still match
# the network only needs a handful of representative pairs — one per
# adjacent sibling-group pair per stratum, one inside each leaf group.
# This is the cheap refresh Estefanel & Mounié's Fast-Tuning loop calls
# for: re-measure in O(strata · group-count), refit levels, re-select.
# ---------------------------------------------------------------------- #

def representative_pairs(topo: Topology,
                         members: Sequence[int] | None = None,
                         ) -> list[tuple[int, int, int]]:
    """Sample pairs ``(p, q, level)`` covering every link class of ``topo``.

    For stratum ``l`` the groups under each common parent path are chained
    in member order and one representative pair is emitted per adjacent
    group pair — enough to refit that class, without the quadratic
    all-pairs sweep.  The finest class gets one intra-leaf-group pair per
    (non-singleton) leaf group.  Total count is at most
    ``(nstrata + 1) · (number of leaf groups)``.
    """
    members = (list(range(topo.nprocs)) if members is None
               else list(members))
    pairs: list[tuple[int, int, int]] = []
    for l in range(topo.nstrata):
        by_parent: dict[tuple, dict[int, int]] = {}
        for m in members:
            path = tuple(topo.coords[m, :l])
            gid = int(topo.coords[m, l])
            by_parent.setdefault(path, {}).setdefault(gid, m)
        for reps in by_parent.values():
            chain = list(reps.values())
            pairs.extend((a, b, l) for a, b in zip(chain, chain[1:]))
    leaf: dict[tuple, list[int]] = {}
    for m in members:
        leaf.setdefault(tuple(topo.coords[m]), []).append(m)
    pairs.extend((g[0], g[1], topo.nstrata)
                 for g in leaf.values() if len(g) >= 2)
    return pairs


@dataclasses.dataclass(frozen=True)
class TargetedProbes:
    """Point-to-point measurements at selected pairs only.

    pairs  : ``(p, q, level)`` triples — ``level`` is the link class the
             *model* topology assigns the pair (what the refit groups by).
    sizes  : the two probe payloads, bytes, ascending.
    times  : (n, 2) one-way delivery seconds per pair and size.
    inject : optional (n,) sender occupancy at ``sizes[0]`` (separates
             overhead from latency, as in :class:`ProbeSet`).
    """

    pairs: tuple[tuple[int, int, int], ...]
    sizes: tuple[float, float]
    times: np.ndarray
    inject: np.ndarray | None = None

    def __post_init__(self):
        t = np.asarray(self.times, dtype=float)
        if t.shape != (len(self.pairs), 2):
            raise ValueError(
                f"times must be ({len(self.pairs)}, 2), got {t.shape}")
        if self.sizes[0] >= self.sizes[1]:
            raise ValueError("probe sizes must be ascending")
        object.__setattr__(self, "times", t)
        object.__setattr__(self, "pairs", tuple(map(tuple, self.pairs)))
        if self.inject is not None:
            inj = np.asarray(self.inject, dtype=float)
            if inj.shape != (len(self.pairs),):
                raise ValueError(
                    f"inject must be ({len(self.pairs)},), got {inj.shape}")
            object.__setattr__(self, "inject", inj)


def targeted_probes(truth: Topology,
                    pairs: Sequence[tuple[int, int, int]], *,
                    noise: float = 0.0, seed: int = 0,
                    sizes: Sequence[float] = DEFAULT_PROBE_SIZES,
                    ) -> TargetedProbes:
    """Sample the postal model of ``truth`` at ``pairs`` only.

    The simulation analogue of pinging just the representative pairs: each
    sample is ``overhead + latency + nbytes/bandwidth`` on the TRUE link
    class of (p, q), under multiplicative noise — the pair's *model* level
    tag rides along untouched so :func:`refit_levels` can group by it.
    """
    if not 0.0 <= noise < 1.0:
        raise ValueError(f"noise must be in [0, 1), got {noise}")
    s1, s2 = float(sizes[0]), float(sizes[1])
    rng = np.random.default_rng(seed)
    n = len(pairs)
    lvls = [truth.level_of_edge(p, q) for p, q, _ in pairs]
    lat = np.array([l.latency for l in lvls])
    bw = np.array([l.bandwidth for l in lvls])
    ovh = np.array([l.overhead for l in lvls])

    def jitter():
        return 1.0 + noise * rng.uniform(-1.0, 1.0, n) if noise else 1.0

    times = np.stack([(ovh + lat + s / bw) * jitter() for s in (s1, s2)],
                     axis=1)
    inject = (ovh + s1 / bw) * jitter()
    return TargetedProbes(tuple(pairs), (s1, s2), times, inject)


def synthetic_probes(topo: Topology,
                     fits: "dict[int, tuple[float, float, float]]", *,
                     sizes: Sequence[float] = DEFAULT_PROBE_SIZES,
                     ) -> TargetedProbes:
    """Render per-level postal fits back into a :class:`TargetedProbes`.

    ``fits`` maps link-class index -> ``(latency, bandwidth, overhead)``
    as estimated elsewhere (e.g. :func:`repro.core.costmodel.link_affine_fit`
    over traced transfer durations).  Each fitted level gets one synthetic
    pair whose two probe times and injection sample are the postal model
    evaluated AT the fit, so feeding the result to :func:`refit_levels`
    reproduces the fitted parameters exactly.

    This keeps refitting single-pathed: measured feedback
    (:mod:`repro.obs.feedback`) does not mutate :class:`Level` objects
    itself — it speaks the same probe language as targeted re-probing, and
    :func:`refit_levels` stays the only writer of level parameters.
    Levels absent from ``fits`` get no pair and keep their parameters.
    """
    if not fits:
        raise ValueError("synthetic_probes needs at least one fitted level")
    bad = [l for l in fits if not 0 <= l < len(topo.levels)]
    if bad:
        raise ValueError(f"fitted level(s) {bad} not in topology "
                         f"(has {len(topo.levels)} classes)")
    s1, s2 = float(sizes[0]), float(sizes[1])
    pairs, t1, t2, inj = [], [], [], []
    for l in sorted(fits):
        lat, bw, ovh = fits[l]
        if bw <= 0:
            raise ValueError(f"level {l}: bandwidth must be positive")
        # the pair endpoints are carriers for the level tag (refit groups
        # by the tag alone); (0, 1) is as good as any real pair
        pairs.append((0, 1, l))
        t1.append(ovh + lat + s1 / bw)
        t2.append(ovh + lat + s2 / bw)
        inj.append(ovh + s1 / bw)
    return TargetedProbes(tuple(pairs), (s1, s2),
                          np.stack([t1, t2], axis=1), np.asarray(inj))


def refit_levels(topo: Topology, probes: TargetedProbes) -> Topology:
    """Refit ``topo``'s link classes from a targeted probe set.

    Coordinates (membership, grouping) are untouched — only the per-class
    postal parameters move, via the same two-point affine fit as
    :func:`fit_levels`.  A class with no sample pairs keeps its previous
    parameters.  Returns a new :class:`Topology`.
    """
    s1, s2 = probes.sizes
    levels = []
    for l, old in enumerate(topo.levels):
        idx = [i for i, (_, _, pl) in enumerate(probes.pairs) if pl == l]
        if not idx:
            levels.append(old)
            continue
        t1 = float(probes.times[idx, 0].mean())
        t2 = float(probes.times[idx, 1].mean())
        slope = max((t2 - t1) / (s2 - s1), 1e-30)
        intercept = ((t1 - s1 * slope) + (t2 - s2 * slope)) / 2.0
        overhead = old.overhead
        if probes.inject is not None:
            overhead = max(
                float(probes.inject[idx].mean()) - s1 * slope, 0.0)
        levels.append(Level(old.name, max(intercept - overhead, 0.0),
                            1.0 / slope, overhead))
    return Topology(topo.coords, levels)


def measure_drift(topo: Topology, probes: TargetedProbes) -> dict[int, float]:
    """Per link class: the measured / modeled one-way time ratio that
    deviates most from 1.0 across BOTH probe sizes — the small probe is
    latency-dominated and the large one bandwidth-dominated, so either
    parameter drifting alone is visible (latency drift on a fat link
    barely moves the large-probe ratio).  1.0 means the class still
    matches the model; the deviation is what
    :meth:`repro.core.Communicator.refresh` thresholds."""
    out: dict[int, float] = {}
    for l, lvl in enumerate(topo.levels):
        idx = [i for i, (_, _, pl) in enumerate(probes.pairs) if pl == l]
        if not idx:
            continue
        ratios = [float(probes.times[idx, k].mean())
                  / (lvl.overhead + lvl.latency + s / lvl.bandwidth)
                  for k, s in enumerate(probes.sizes)]
        out[l] = max(ratios, key=lambda r: abs(r - 1.0))
    return out


# ---------------------------------------------------------------------- #
# Front door
# ---------------------------------------------------------------------- #

def discover(source: str = "sim", *, topo: Topology | None = None,
             noise: float = 0.0, seed: int = 0,
             sizes: Sequence[float] = DEFAULT_PROBE_SIZES,
             gap_factor: float = DEFAULT_GAP_FACTOR,
             devices: Sequence | None = None,
             path: str | None = None, refresh: bool = False,
             **device_kw) -> Topology:
    """Discover a topology from one of the three probe sources.

    source : "sim" (requires ``topo=`` as hidden ground truth; ``noise``,
        ``seed`` control the probe sampling), "env" (``jax.devices()``
        metadata), or "device" (timed ppermute probes; extra kwargs are
        forwarded to :func:`device_probes`).
    path : Fast-Tuning cache.  When the file exists (and ``refresh`` is
        false) it is loaded and NO probing happens; otherwise discovery
        runs once and persists its result there.
    """
    if path and not refresh and os.path.exists(path):
        return Topology.load(path)
    if source == "sim":
        if topo is None:
            raise ValueError("source='sim' needs topo= as ground truth")
        t = fit_topology(simulated_probes(topo, noise=noise, seed=seed,
                                          sizes=sizes),
                         gap_factor=gap_factor)
    elif source == "env":
        t = environment_topology(devices)
    elif source == "device":
        t = fit_topology(device_probes(sizes=sizes, devices=devices,
                                       **device_kw),
                         gap_factor=gap_factor)
    else:
        raise ValueError(f"unknown probe source {source!r}; "
                         "choose from 'sim', 'env', 'device'")
    if path:
        t.save(path)
    return t
