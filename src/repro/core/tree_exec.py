"""Execute the paper's explicit multilevel trees on devices with
``lax.ppermute`` rounds — the faithful §3.2 port.

ENGINE MODULE: these are the primitives behind the ``backend="ppermute"``
path of :class:`repro.core.communicator.Communicator`, which is the public
entry point (``Communicator(topo, backend="ppermute", axis=...)``) and also
caches the round schedules (``Plan.rounds``) across calls.

MPICH-G2 §3.2: every process independently constructs the identical tree and
executes it with point-to-point sends.  On TPU the point-to-point primitive
is ``collective_permute``; one tree "round" (a set of disjoint (src,dst)
edges) is exactly one collective-permute.  We schedule a tree as rounds:
round r carries every tree edge whose parent received in some round < r and
which is the parent's r'-th injection — computed statically at trace time
from the Tree structure, so the device program is a fixed sequence of
ppermutes + masked selects.

Used for the root-ful operations of the serving/checkpoint planes (bcast of
updated params, gather of metrics/logits to a coordinator) where XLA has no
axis-decomposed shortcut, and as the *demonstration* that the paper's exact
trees run on a TPU mesh.

All functions run INSIDE shard_map over a 1-D logical axis (the flattened
device order); the multilevel structure comes from the Tree built against a
Topology whose coordinates mirror the mesh (pod, board) hierarchy.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .trees import Tree

__all__ = ["tree_rounds", "tree_bcast", "tree_reduce", "tree_gather_flat"]


def tree_rounds(tree: Tree) -> list[list[tuple[int, int]]]:
    """Static round schedule: list of rounds, each a list of (src, dst) tree
    edges; a parent injects one message per round (postal sequential sends),
    children become senders the round after they receive."""
    recv_round = {tree.root: -1}
    pending = {p: list(cs) for p, cs in tree.children.items()}
    rounds: list[list[tuple[int, int]]] = []
    r = 0
    injections: dict[int, int] = {}
    while any(pending.values()):
        this: list[tuple[int, int]] = []
        for p in list(pending):
            if p not in recv_round or recv_round[p] >= r:
                continue
            sent = injections.get(p, 0)
            # parent may inject its (r - recv_round[p] - 1)-th message now
            if pending[p] and sent <= r - recv_round[p] - 1:
                c = pending[p].pop(0)
                this.append((p, c))
                injections[p] = sent + 1
                recv_round[c] = r
        if not this:  # safety: should not happen on a valid tree
            raise RuntimeError("tree schedule stalled")
        rounds.append(this)
        r += 1
    return rounds


def tree_bcast(x: jax.Array, tree: Tree, axis: str) -> jax.Array:
    """Broadcast the root's shard value to every device along ``axis`` using
    the tree's rounds.  Non-root inputs are ignored (replaced)."""
    idx = lax.axis_index(axis)
    have = idx == tree.root
    for rnd in tree_rounds(tree):
        recv = lax.ppermute(x, axis, rnd)
        dsts = jnp.array([d for _, d in rnd])
        is_dst = jnp.any(idx == dsts)
        x = jnp.where(is_dst & ~have, recv, x)
        have = have | is_dst
    return x


def tree_reduce(x: jax.Array, tree: Tree, axis: str) -> jax.Array:
    """Sum-reduce to the tree root (other devices return garbage partials —
    callers select on root).  Children send up in reversed round order."""
    for rnd in reversed(tree_rounds(tree)):
        up = [(d, s) for s, d in rnd]  # reverse each edge
        recv = lax.ppermute(x, axis, up)
        dsts = jnp.array([d for _, d in up])
        idx = lax.axis_index(axis)
        is_dst = jnp.any(idx == dsts)
        x = jnp.where(is_dst, x + recv, x)
    return x


def tree_gather_flat(x: jax.Array, tree: Tree, axis: str, axis_size: int) -> jax.Array:
    """Gather shards to the root as [axis_size, ...] via up-edges.

    Implemented as a masked all-gather substitute: each round ships the
    partial gather buffer up one tree edge.  Buffer cost is the same as an
    all-gather but traffic follows the multilevel tree (slow links crossed
    once)."""
    idx = lax.axis_index(axis)
    buf = jnp.zeros((axis_size,) + x.shape, x.dtype)
    buf = buf.at[idx].set(x)
    for rnd in reversed(tree_rounds(tree)):
        up = [(d, s) for s, d in rnd]
        recv = lax.ppermute(buf, axis, up)
        dsts = jnp.array([d for _, d in up])
        is_dst = jnp.any(idx == dsts)
        buf = jnp.where(is_dst, buf + recv, buf)
    return buf
