"""Execute the paper's explicit multilevel trees on devices with
``lax.ppermute`` rounds — the faithful §3.2 port.

ENGINE MODULE: these are the primitives behind the ``backend="ppermute"``
path of :class:`repro.core.communicator.Communicator`, which is the public
entry point (``Communicator(topo, backend="ppermute", axis=...)``) and also
caches the round schedules (``Plan.rounds``) across calls.

MPICH-G2 §3.2: every process independently constructs the identical tree and
executes it with point-to-point sends.  On TPU the point-to-point primitive
is ``collective_permute``; one tree "round" (a set of disjoint (src,dst)
edges) is exactly one collective-permute.  We schedule a tree as rounds:
round r carries every tree edge whose parent received in some round < r and
which is the parent's r'-th injection — computed statically at trace time
from the Tree structure, so the device program is a fixed sequence of
ppermutes + masked selects.

Used for the root-ful operations of the serving/checkpoint planes (bcast of
updated params, gather of metrics/logits to a coordinator) where XLA has no
axis-decomposed shortcut, and as the *demonstration* that the paper's exact
trees run on a TPU mesh.

All functions run INSIDE shard_map over a 1-D logical axis (the flattened
device order); the multilevel structure comes from the Tree built against a
Topology whose coordinates mirror the mesh (pod, board) hierarchy.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .trees import Tree

__all__ = ["tree_rounds", "tree_bcast", "tree_reduce", "tree_gather_flat",
           "run_lowered"]


def tree_rounds(tree: Tree) -> list[list[tuple[int, int]]]:
    """Static round schedule: list of rounds, each a list of (src, dst) tree
    edges; a parent injects one message per round (postal sequential sends),
    children become senders the round after they receive."""
    recv_round = {tree.root: -1}
    pending = {p: list(cs) for p, cs in tree.children.items()}
    rounds: list[list[tuple[int, int]]] = []
    r = 0
    injections: dict[int, int] = {}
    while any(pending.values()):
        this: list[tuple[int, int]] = []
        for p in list(pending):
            if p not in recv_round or recv_round[p] >= r:
                continue
            sent = injections.get(p, 0)
            # parent may inject its (r - recv_round[p] - 1)-th message now
            if pending[p] and sent <= r - recv_round[p] - 1:
                c = pending[p].pop(0)
                this.append((p, c))
                injections[p] = sent + 1
                recv_round[c] = r
        if not this:  # safety: should not happen on a valid tree
            raise RuntimeError("tree schedule stalled")
        rounds.append(this)
        r += 1
    return rounds


def run_lowered(x: jax.Array, lowered, axis: str,
                axis_size: int) -> jax.Array:
    """Execute a lowered rounds-IR program (:class:`repro.core.rounds.Lowered`)
    on devices: one ``lax.ppermute`` per device round.

    The payload is reshaped into ``lowered.nchunks`` contiguous chunks (the
    IR's data units — padding as needed); each round every participating
    rank ships one chunk to one peer and folds (``reduce``) or overwrites
    (``copy``) on receipt.  Chunk routing is static — per-round constant
    tables indexed by ``axis_index`` — so the traced program is a fixed
    sequence of ppermutes + dynamic chunk updates.  Works for any lowering
    whose chunk ids are 0..nchunks-1 (tree bcast/allreduce, sag, rsag).
    """
    import numpy as np

    C = max(1, lowered.nchunks)
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % C
    if pad:
        flat = jnp.pad(flat, (0, pad))
    buf = flat.reshape(C, -1)
    idx = lax.axis_index(axis)
    for rnd in lowered.device_rounds():
        src_chunk = np.zeros(axis_size, np.int32)
        dst_chunk = np.zeros(axis_size, np.int32)
        is_dst = np.zeros(axis_size, bool)
        is_red = np.zeros(axis_size, bool)
        perm = []
        for s, d, c, kind in rnd:
            src_chunk[s] = c
            dst_chunk[d] = c
            is_dst[d] = True
            is_red[d] = kind == "reduce"
            perm.append((s, d))
        carried = lax.dynamic_index_in_dim(
            buf, jnp.asarray(src_chunk)[idx], axis=0, keepdims=False)
        recv = lax.ppermute(carried, axis, perm)
        di = jnp.asarray(dst_chunk)[idx]
        cur = lax.dynamic_index_in_dim(buf, di, axis=0, keepdims=False)
        new = jnp.where(jnp.asarray(is_red)[idx], cur + recv, recv)
        new = jnp.where(jnp.asarray(is_dst)[idx], new, cur)
        buf = lax.dynamic_update_index_in_dim(buf, new, di, axis=0)
    out = buf.reshape(-1)
    if pad:
        out = out[:out.size - pad]
    return out.reshape(shape)


def tree_bcast(x: jax.Array, tree: Tree, axis: str) -> jax.Array:
    """Broadcast the root's shard value to every device along ``axis`` using
    the tree's rounds.  Non-root inputs are ignored (replaced)."""
    idx = lax.axis_index(axis)
    have = idx == tree.root
    for rnd in tree_rounds(tree):
        recv = lax.ppermute(x, axis, rnd)
        dsts = jnp.array([d for _, d in rnd])
        is_dst = jnp.any(idx == dsts)
        x = jnp.where(is_dst & ~have, recv, x)
        have = have | is_dst
    return x


def tree_reduce(x: jax.Array, tree: Tree, axis: str) -> jax.Array:
    """Sum-reduce to the tree root (other devices return garbage partials —
    callers select on root).  Children send up in reversed round order."""
    for rnd in reversed(tree_rounds(tree)):
        up = [(d, s) for s, d in rnd]  # reverse each edge
        recv = lax.ppermute(x, axis, up)
        dsts = jnp.array([d for _, d in up])
        idx = lax.axis_index(axis)
        is_dst = jnp.any(idx == dsts)
        x = jnp.where(is_dst, x + recv, x)
    return x


def tree_gather_flat(x: jax.Array, tree: Tree, axis: str, axis_size: int) -> jax.Array:
    """Gather shards to the root as [axis_size, ...] via up-edges.

    Implemented as a masked all-gather substitute: each round ships the
    partial gather buffer up one tree edge.  Buffer cost is the same as an
    all-gather but traffic follows the multilevel tree (slow links crossed
    once)."""
    idx = lax.axis_index(axis)
    buf = jnp.zeros((axis_size,) + x.shape, x.dtype)
    buf = buf.at[idx].set(x)
    for rnd in reversed(tree_rounds(tree)):
        up = [(d, s) for s, d in rnd]
        recv = lax.ppermute(buf, axis, up)
        dsts = jnp.array([d for _, d in up])
        is_dst = jnp.any(idx == dsts)
        buf = jnp.where(is_dst, buf + recv, buf)
    return buf
