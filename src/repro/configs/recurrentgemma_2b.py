"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (GQA kv=1, MQA) d_ff=7680
vocab=256000.  RG-LRU + local attention, 1 attn per 2 recurrent (Griffin).
[arXiv:2402.19427; hf-verified]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    pattern = (("rglru", "rglru", "local") * 9)[:26]
    return ModelConfig(
        name="recurrentgemma-2b", n_layers=26, d_model=2560, n_heads=10,
        n_kv_heads=1, head_dim=256, d_ff=7680, vocab=256000,
        pattern=pattern, window=2048, activation="geglu", tie_embeddings=True,
        d_rnn=2560, family="hybrid",
    )

def smoke_config() -> ModelConfig:
    return shrink(config(), n_layers=3)  # one rglru,rglru,local period
