"""olmoe-1b-7b [moe]: 16L d=2048 16H (kv=16) d_ff_expert=1024 vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf-verified]"""
from ._base import ModelConfig, MoECfg, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50304,
        pattern=("attn",) * 16, activation="swiglu", tie_embeddings=True,
        moe=MoECfg(n_experts=64, top_k=8, d_ff_expert=1024),
        family="moe",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
