"""Shared helpers for arch config modules."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, MoECfg, EncDecCfg, pattern_repeat

__all__ = ["ModelConfig", "MoECfg", "EncDecCfg", "pattern_repeat", "shrink"]


def shrink(cfg: ModelConfig, n_layers: int = 4) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny widths, few
    layers (pattern prefix preserved), tiny vocab / expert count."""
    hd = 16
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads * n_heads // max(cfg.n_heads, 1), n_heads))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32)
    enc_dec = None
    if cfg.enc_dec is not None:
        enc_dec = EncDecCfg(n_enc_layers=2, n_dec_layers=n_layers)
    pattern = pattern_repeat(cfg.pattern, max(len(cfg.pattern), n_layers))[:n_layers]
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=128,
        vocab=512,
        pattern=pattern,
        window=min(cfg.window, 8),
        moe=moe,
        enc_dec=enc_dec,
        d_rnn=64 if cfg.d_rnn else None,
        rwkv_head_dim=16,
    )
