"""Architecture registry: ``get_config(arch_id)`` + shape registry.

Each assigned architecture lives in its own module exposing ``config()``
(exact published configuration) and ``smoke_config()`` (reduced same-family
config for CPU tests).
"""
from __future__ import annotations

import importlib

from .shapes import SHAPES, ShapeSpec, input_specs  # noqa: F401

ARCHS = [
    "qwen3_4b",
    "gemma3_12b",
    "phi4_mini_3p8b",
    "tinyllama_1p1b",
    "llama4_scout_17b_a16e",
    "olmoe_1b_7b",
    "pixtral_12b",
    "recurrentgemma_2b",
    "seamless_m4t_medium",
    "rwkv6_1p6b",
    "gpt_100m",  # e2e training example model (paper-scale driver)
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "qwen3-4b": "qwen3_4b",
    "gemma3-12b": "gemma3_12b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "pixtral-12b": "pixtral_12b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "gpt-100m": "gpt_100m",
})


def get_config(arch: str, smoke: bool = False):
    mod_name = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.config()


def list_archs() -> list[str]:
    return list(ARCHS)
