"""Assigned input shapes + ShapeDtypeStruct stand-ins for the dry-run.

``input_specs(cfg, shape)`` returns the exact pytree of ShapeDtypeStructs the
corresponding step function takes — weak-type-correct, shardable, and with
NO device allocation (decode caches come from ``jax.eval_shape``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as T

__all__ = ["ShapeSpec", "SHAPES", "input_specs", "applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Modality stubs: how many leading positions come from the frontend.
VISION_PATCHES = 1024
AUDIO_SRC_FRACTION = 0.5  # enc-dec: half the budget is encoder frames


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is (arch x shape) a runnable cell?  Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 512k decode is quadratic (skip per brief)"
    return True, ""


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model inputs for one step, as ShapeDtypeStructs (global shapes)."""
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model

    if cfg.enc_dec:  # audio enc-dec: split budget between encoder and decoder
        s_src = int(S * AUDIO_SRC_FRACTION)
        s_tgt = S - s_src
        if shape.kind == "train":
            return {"src_embeds": _tok((B, s_src, D), jnp.bfloat16),
                    "tokens": _tok((B, s_tgt)), "labels": _tok((B, s_tgt))}
        if shape.kind == "prefill":
            return {"src_embeds": _tok((B, s_src, D), jnp.bfloat16),
                    "tokens": _tok((B, s_tgt))}
        # decode: one new target token against an S-long cache
        return {"tokens": _tok((B, 1)),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    if cfg.frontend == "vision":
        n_img = min(VISION_PATCHES, S // 4)
        if shape.kind == "train":
            return {"embeds": _tok((B, n_img, D), jnp.bfloat16),
                    "tokens": _tok((B, S - n_img)),
                    "labels": _tok((B, S - n_img))}
        if shape.kind == "prefill":
            return {"embeds": _tok((B, n_img, D), jnp.bfloat16),
                    "tokens": _tok((B, S - n_img))}
        return {"tokens": _tok((B, 1)), "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    if shape.kind == "train":
        return {"tokens": _tok((B, S)), "labels": _tok((B, S))}
    if shape.kind == "prefill":
        return {"tokens": _tok((B, S))}
    return {"tokens": _tok((B, 1)), "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> list:
    """ShapeDtypeStructs for the decode cache (global shapes)."""
    B, S = shape.global_batch, shape.seq_len
    src_len = int(S * AUDIO_SRC_FRACTION) if cfg.enc_dec else 0
    return jax.eval_shape(lambda: T.init_cache(cfg, B, S, src_len))
