"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from ._base import ModelConfig, MoECfg, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
        pattern=("attn",) * 48, activation="swiglu", tie_embeddings=True,
        moe=MoECfg(n_experts=16, top_k=1, d_ff_expert=8192, shared_expert=True),
        family="moe",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
