"""gpt-100m: ~100M-param dense LM used by the end-to-end training example
(examples/train_e2e.py) — small enough to train a few hundred steps on CPU
in the CI budget while exercising the full distributed stack."""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="gpt-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        head_dim=64, d_ff=3072, vocab=32000, pattern=("attn",) * 12,
        activation="gelu", tie_embeddings=True, family="dense",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
