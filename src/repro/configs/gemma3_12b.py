"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local:global attention, 128k context.  [hf:google/gemma-3 family]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        head_dim=256, d_ff=15360, vocab=262144,
        pattern=(("local",) * 5 + ("attn",)) * 8, window=1024,
        qk_norm=True, rope_theta=1e6, activation="geglu", tie_embeddings=True,
        family="dense",
    )

def smoke_config() -> ModelConfig:
    return shrink(config(), n_layers=6)  # one full 5:1 period
