"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Pixtral-ViT frontend is a STUB (input_specs supplies patch embeddings);
backbone = mistral-nemo-style decoder.  [hf:mistralai/Pixtral-12B-2409]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
        pattern=("attn",) * 40, activation="swiglu", tie_embeddings=True,
        family="vlm", frontend="vision",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
