"""phi4-mini-3.8b [dense]: 32L d=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
RoPE + SwiGLU + GQA.  [arXiv:2412.08905; hf-verified]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab=200064,
        pattern=("attn",) * 32, activation="swiglu", tie_embeddings=True,
        family="dense",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
