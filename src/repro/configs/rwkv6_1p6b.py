"""rwkv6-1.6b [ssm]: 24L d=2048 (attention-free) d_ff=7168 vocab=65536.
Finch: data-dependent per-channel decay.  [arXiv:2404.05892]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", n_layers=24, d_model=2048, n_heads=32,
        n_kv_heads=32, head_dim=64, d_ff=7168, vocab=65536,
        pattern=("rwkv6",) * 24, activation="gelu", tie_embeddings=True,
        rwkv_head_dim=64, family="ssm",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
