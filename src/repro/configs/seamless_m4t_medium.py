"""seamless-m4t-medium [audio]: enc-dec, 12+12L d=1024 16H d_ff=4096
vocab=256206.  Audio frontend is a STUB (input_specs supplies precomputed
frame embeddings).  [arXiv:2308.11596; hf-verified]"""
from ._base import ModelConfig, EncDecCfg, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", n_layers=12, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=4096, vocab=256206,
        pattern=("attn",) * 12, activation="gelu", tie_embeddings=True,
        enc_dec=EncDecCfg(n_enc_layers=12, n_dec_layers=12),
        family="audio", frontend="audio",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
