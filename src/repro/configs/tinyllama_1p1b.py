"""tinyllama-1.1b [dense]: 22L d=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
llama2-arch small.  [arXiv:2401.02385; hf-verified]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
        n_kv_heads=4, head_dim=64, d_ff=5632, vocab=32000,
        pattern=("attn",) * 22, activation="swiglu", tie_embeddings=False,
        family="dense",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
