"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.
qk_norm + GQA.  [hf:Qwen/Qwen3-8B family; hf-verified tier]"""
from ._base import ModelConfig, shrink

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=9728, vocab=151936, pattern=("attn",) * 36,
        qk_norm=True, rope_theta=1e6, activation="swiglu", tie_embeddings=True,
        family="dense",
    )

def smoke_config() -> ModelConfig:
    return shrink(config())
