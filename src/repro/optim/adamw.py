"""AdamW with f32 master weights, global-norm clipping, cosine schedule —
with per-leaf multilevel gradient sync and an optional ZeRO-1 mode that
rides the multilevel collective for free.

ZeRO-1 x multilevel synergy (beyond-paper, recorded in EXPERIMENTS §Perf):
the multilevel all-reduce's first stage is a reduce-scatter over the fast
intra-pod `data` axis.  In ZeRO-1 we simply STOP after the slow-axis psum —
each data rank holds the fully-reduced 1/|data| gradient shard, updates its
shard of the optimizer state, and the trailing all-gather ships updated
*parameters* instead of gradients.  Same wire bytes as the multilevel
all-reduce, 1/|data| the optimizer memory and update FLOPs.

Everything here runs INSIDE a partial-manual shard_map: manual over the
data-parallel axes (`pod`, `data`), auto (GSPMD) over `model` — so every
per-leaf collective below composes with tensor-parallel sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compression

__all__ = ["OptConfig", "scatter_axes", "init_opt_state", "apply_updates",
           "lr_at", "opt_manual_specs"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    zero1: bool = True
    # gradient communication: flat | multilevel | multilevel_compress
    comm_mode: str = "multilevel"
    # size-targeted gradient buckets (wire bytes): sync one fused buffer per
    # bucket in reverse leaf order instead of per-leaf/monolithic, so the
    # device scheduler can overlap bucket k's collective with the backward
    # of the layers below it.  Dense modes only (flat | multilevel): ZeRO-1
    # scatters per leaf and the compressed mode's EF residual is shaped by
    # the exchange.
    bucket_bytes: float | None = None
    # Pallas quantiser toggle for the compressed slow hop: None -> auto
    # (fused kernel on TPU, jnp reference elsewhere); threaded through to
    # compression.compressed_psum(use_kernel=).
    quant_kernel: bool | None = None

    def __post_init__(self):
        if (self.quant_kernel is not None
                and self.comm_mode != "multilevel_compress"):
            raise ValueError("quant_kernel only applies to "
                             "comm_mode='multilevel_compress'")
        if self.bucket_bytes is not None:
            if self.bucket_bytes <= 0:
                raise ValueError("bucket_bytes must be positive")
            if self.comm_mode not in ("flat", "multilevel"):
                raise ValueError("bucketed gradient sync supports "
                                 "comm_mode 'flat'/'multilevel' only")
            if self.sharded_state:
                raise ValueError("bucketed gradient sync requires "
                                 "zero1=False (the ZeRO-1 path scatters "
                                 "per leaf)")

    @property
    def error_feedback(self) -> bool:
        """True when the opt state carries an EF residual: the int8 slow-hop
        exchange rounds every step, and without feeding the rounding error
        back into the next step's gradient the bias accumulates in the
        optimiser (the compressed path drifts from the exact trajectory)."""
        return self.comm_mode == "multilevel_compress"

    @property
    def sharded_state(self) -> bool:
        """True when the opt state lives as 1/|data| shards.  The flat
        (topology-unaware) baseline always runs the dense path in
        ``apply_updates``, so its state must be replicated too — sharding
        decisions and update math must agree on this one predicate."""
        return self.zero1 and self.comm_mode != "flat"


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


# ---------------------------------------------------------------------- #
# Per-leaf scatter planning
# ---------------------------------------------------------------------- #

def scatter_axes(params: Any, n: int, model_dims: Any | None = None) -> Any:
    """For each leaf: the dim to reduce-scatter over the `data` axis (size
    ``n``), or None if no dim divides.  Prefers the largest dim that is NOT
    already model-sharded so the two shardings never collide."""

    def pick(leaf, mdim):
        shape = leaf.shape
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for avoid_model in (True, False):
            for i in order:
                if shape[i] % n == 0 and (not avoid_model or i != mdim):
                    return i
        return None

    if model_dims is None:
        model_dims = jax.tree.map(lambda _: -1, params)
    return jax.tree.map(pick, params, model_dims)


def _adamw_math(m, v, g, master, cfg: OptConfig, lr, t, decay_mask=1.0):
    b1, b2 = cfg.betas
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * decay_mask * master
    return m, v, master - lr * upd


# ---------------------------------------------------------------------- #
# State
# ---------------------------------------------------------------------- #

def init_opt_state(params: Any, cfg: OptConfig, n_slow: int = 1) -> dict:
    """m/v/master as GLOBAL arrays mirroring params (f32).  Under ZeRO-1 the
    launcher device_puts them sharded over `data` along the scatter axis (see
    ``opt_manual_specs``); dense mode replicates them over dp.  The
    compressed comm mode adds an ``ef`` error-feedback residual per leaf:
    shape ``(n_slow,) + param.shape``, sharded over BOTH the slow axis
    (leading dim — every pod quantises its own partial sum, so residuals
    diverge per pod rank) and `data` along the scatter axis.  ``n_slow``
    is the slow-axis (pod) degree."""
    zeros = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params)
    # copy=True: an f32 param leaf must not alias its master (donation!)
    master = jax.tree.map(
        lambda l: jnp.array(l, dtype=jnp.float32, copy=True), params)
    state = {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
             "master": master, "step": jnp.zeros((), jnp.int32)}
    if cfg.error_feedback:
        state["ef"] = jax.tree.map(
            lambda l: jnp.zeros((max(n_slow, 1),) + l.shape, jnp.float32),
            params)
    return state


def opt_manual_specs(params: Any, cfg: OptConfig, data_size: int,
                     model_dims: Any | None = None,
                     slow_axis: str | None = None) -> dict:
    """Manual-axis PartitionSpecs for the opt state (the shard_map in/out
    specs for dp axes).  ZeRO-1: P('data' at scatter axis); dense: P().
    The EF residual (leading slow dim, see :func:`init_opt_state`) shards
    over ``slow_axis`` + 'data' even in dense mode: each (pod, data) rank
    owns the rounding error of the shard IT exchanged."""
    from jax.sharding import PartitionSpec as P

    axes = scatter_axes(params, data_size, model_dims)

    def to_spec(leaf, ax, lead=False):
        dims = [None] * leaf.ndim
        if ax is not None:
            dims[ax] = "data"
        if lead:
            dims = [slow_axis] + dims
        elif ax is None:
            return P()
        return P(*dims)

    scattered = jax.tree.map(to_spec, params, axes)
    spec = (scattered if cfg.sharded_state
            else jax.tree.map(lambda _: P(), params))
    out = {"m": spec, "v": spec,
           "master": jax.tree.map(lambda s: s, spec),
           "step": P()}
    if cfg.error_feedback:
        out["ef"] = jax.tree.map(lambda l, ax: to_spec(l, ax, lead=True),
                                 params, axes)
    return out


# ---------------------------------------------------------------------- #
# The update (INSIDE shard_map; manual dp axes, auto model axis)
# ---------------------------------------------------------------------- #

def _sync_shard(g, ax, slow_axis, cfg: OptConfig, ef=None):
    """Multilevel stage 1+2 for one leaf: reduce-scatter intra-pod, then the
    (optionally compressed) slow-axis exchange on the 1/|data| shard.

    ``ef`` is the leaf's error-feedback residual (local shard, same shape
    the scatter produces); when given the return is ``(g, new_ef)`` — the
    residual is folded into the compressed exchange and the fresh rounding
    error comes back to be carried into the next step."""
    if ax is not None:
        g = lax.psum_scatter(g.astype(jnp.float32), "data",
                             scatter_dimension=ax, tiled=True)
    else:
        g = lax.psum(g.astype(jnp.float32), "data")
    new_ef = ef
    if slow_axis is not None:
        if cfg.comm_mode == "multilevel_compress":
            shp = g.shape
            if ef is not None:
                g, new_ef = compression.compressed_psum(
                    g.reshape(-1), slow_axis, ef=ef.reshape(-1),
                    use_kernel=cfg.quant_kernel)
                g, new_ef = g.reshape(shp), new_ef.reshape(shp)
            else:
                g = compression.compressed_psum(
                    g.reshape(-1), slow_axis,
                    use_kernel=cfg.quant_kernel).reshape(shp)
        else:
            g = lax.psum(g, slow_axis)
    return g if ef is None else (g, new_ef)


def apply_updates(
    params: Any,
    grads: Any,
    opt: dict,
    cfg: OptConfig,
    slow_axis: str | None,
    data_size: int,
    dp_degree: int,
    model_dims: Any | None = None,
    model_axis: str | None = None,
) -> tuple[Any, dict]:
    """Gradient sync (flat | multilevel | multilevel_compress) + AdamW.
    ZeRO-1: opt-state leaves enter as their 1/|data| shards.  When the model
    axis is manual (``model_axis``), grad-norm reductions include it."""
    t = opt["step"] + 1
    lr = lr_at(cfg, opt["step"])
    axes = scatter_axes(params, data_size, model_dims)
    norm_axes = ("data",) + ((model_axis,) if model_axis else ())

    is_pair = lambda x: isinstance(x, tuple)

    if not cfg.sharded_state:
        # Baseline (topology-unaware) or dense mode: full grads everywhere.
        dp = tuple(a for a in (slow_axis, "data") if a)
        new_ef = opt.get("ef")
        if cfg.bucket_bytes is not None:
            # size-targeted buckets in reverse leaf order: one fused
            # collective per bucket, overlappable with backward
            from repro.core.collectives import bucketed_psum_tree
            grads = bucketed_psum_tree(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads),
                slow_axis, ("data",), bucket_bytes=cfg.bucket_bytes,
                mode=cfg.comm_mode, mean_over=dp_degree)
        elif cfg.comm_mode == "flat":
            grads = jax.tree.map(
                lambda g: lax.psum(g.astype(jnp.float32), dp) / dp_degree, grads)
        elif cfg.error_feedback:
            # dense compressed: the EF residual lives on each rank's shard
            # (ef leaves carry a leading slow-axis dim, locally size 1)
            def ml_ef(g, ax, e):
                gs, ne = _sync_shard(g, ax, slow_axis, cfg, e[0])
                gs = gs / dp_degree
                if ax is not None:
                    gs = lax.all_gather(gs, "data", axis=ax, tiled=True)
                return gs, ne[None]
            pairs = jax.tree.map(ml_ef, grads, axes, opt["ef"])
            grads = jax.tree.map(lambda r: r[0], pairs, is_leaf=is_pair)
            new_ef = jax.tree.map(lambda r: r[1], pairs, is_leaf=is_pair)
        else:  # multilevel but dense state: scatter + slow + gather per leaf
            def ml(g, ax):
                gs = _sync_shard(g, ax, slow_axis, cfg) / dp_degree
                if ax is not None:
                    gs = lax.all_gather(gs, "data", axis=ax, tiled=True)
                return gs
            grads = jax.tree.map(ml, grads, axes)
        gn2 = sum(jnp.vdot(g, g).real for g in jax.tree.leaves(grads))
        if model_axis:  # leaves are manual model shards here
            gn2 = lax.psum(gn2, model_axis)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(jnp.sqrt(gn2), 1e-12))
        res = jax.tree.map(
            lambda m, v, g, w: _adamw_math(m, v, g * scale, w, cfg, lr, t),
            opt["m"], opt["v"], grads, opt["master"])
        new_m = jax.tree.map(lambda r: r[0], res, is_leaf=is_pair)
        new_v = jax.tree.map(lambda r: r[1], res, is_leaf=is_pair)
        new_w = jax.tree.map(lambda r: r[2], res, is_leaf=is_pair)
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), new_w, params)
        out = dict(opt, m=new_m, v=new_v, master=new_w, step=t)
        if new_ef is not None:
            out["ef"] = new_ef
        return new_params, out

    # ---------------- ZeRO-1 multilevel path ---------------- #
    new_ef = None
    if cfg.error_feedback:
        # ef leaves carry a leading slow-axis dim (locally size 1)
        pairs = jax.tree.map(
            lambda g, ax, e: _sync_shard(g, ax, slow_axis, cfg, e[0]),
            grads, axes, opt["ef"])
        shards = jax.tree.map(lambda r: r[0] / dp_degree, pairs,
                              is_leaf=is_pair)
        new_ef = jax.tree.map(lambda r: r[1][None], pairs, is_leaf=is_pair)
    else:
        shards = jax.tree.map(
            lambda g, ax: _sync_shard(g, ax, slow_axis, cfg) / dp_degree,
            grads, axes)
    # global grad norm from the shards (they tile the full gradient exactly;
    # leaves that could not scatter are replicated -> divide their sq once)
    def sq(g, ax):
        s = jnp.vdot(g, g).real
        return s if ax is not None else s / data_size
    gn2 = sum(jax.tree.leaves(jax.tree.map(sq, shards, axes)))
    gn2 = lax.psum(gn2, norm_axes)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(jnp.sqrt(gn2), 1e-12))

    res = jax.tree.map(
        lambda m, v, g, w: _adamw_math(m, v, g * scale, w, cfg, lr, t),
        opt["m"], opt["v"], shards, opt["master"])
    new_m = jax.tree.map(lambda r: r[0], res, is_leaf=is_pair)
    new_v = jax.tree.map(lambda r: r[1], res, is_leaf=is_pair)
    new_w = jax.tree.map(lambda r: r[2], res, is_leaf=is_pair)

    # stage 3: all-gather updated PARAMS across the fast axis.  Cast to the
    # compute dtype BEFORE the gather: halves the wire bytes and kills the
    # f32 stacked-param buffers the gather would otherwise materialise.
    def gather(w, ax, p):
        wc = w.astype(p.dtype)
        return wc if ax is None else lax.all_gather(wc, "data", axis=ax,
                                                    tiled=True)
    new_params = jax.tree.map(gather, new_w, axes, params)
    out = dict(opt, m=new_m, v=new_v, master=new_w, step=t)
    if new_ef is not None:
        out["ef"] = new_ef
    return new_params, out
