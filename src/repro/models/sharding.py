"""Parameter / activation sharding rules (GSPMD side).

Topology-aware placement, per the paper's principle: the tensor-parallel
(`model`) axis — whose collectives run every layer — is always mapped to the
innermost, fastest mesh dimension and NEVER crosses a pod boundary; the
data-parallel axes (`pod`, `data`) carry only one gradient collective per
step, which `repro.core.collectives` decomposes multileveled.

Rules are name-based over the param pytree; any dimension that does not
divide the model-axis size falls back to replicated and GSPMD propagation
fills the gap (e.g. 24-head attention on a 16-way model axis).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_pspecs", "param_shardings", "batch_pspec", "dp_axes"]

# leaf name -> which logical dim to shard over "model"
#   "col": shard the LAST dim (output features)
#   "row": shard the SECOND-TO-LAST dim (input features)
#   "expert": shard the expert dim (ndim-3 with run stacking)
#   None: replicate
_RULES: dict[str, str | None] = {
    "embed": "vocab", "lm_head": "col",
    "wq": "col", "wk": "col", "wv": "col", "wo": "row",
    "wi": "col", "wg": "col",
    "router": None,
    "w_in": "expert", "w_out": "expert",
    "w_x": "col", "w_a": "col", "w_i": "col",
    "conv": None, "lam": None,
    "w_r": "col", "w_k": "col", "w_v": "col", "w_g": "col", "w_w": "col",
    "w_o": "row", "u": None, "mix": None,
    "cm_k": "col", "cm_v": "row", "cm_r": "col", "cm_mix": None,
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _spec_for(name: str, shape: tuple[int, ...], model_size: int) -> P:
    if model_size <= 1:
        return P()
    rule = _RULES.get(name)
    # MoE gate weight shares the "w_gate" name with RG-LRU's input gate:
    # disambiguate on rank (expert tensors are 4-D once run-stacked).
    if name == "w_gate":
        rule = "expert" if len(shape) >= 4 else "col"
    if rule is None:
        return P()
    dims: list[Any] = [None] * len(shape)
    if rule == "vocab":
        axis = 0
    elif rule == "col":
        axis = len(shape) - 1
    elif rule == "row":
        axis = len(shape) - 2
    elif rule == "expert":
        axis = len(shape) - 3
    else:
        return P()
    if shape[axis] % model_size != 0:
        # fall back: try the other matmul dim, else replicate
        alt = len(shape) - 1 if rule in ("row", "expert") else len(shape) - 2
        if 0 <= alt < len(shape) and shape[alt] % model_size == 0 and alt != axis:
            axis = alt
        else:
            return P()
    dims[axis] = "model"
    return P(*dims)


def param_pspecs(params: Any, model_size: int) -> Any:
    """PartitionSpec pytree mirroring ``params`` (model axis only)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_leaf_name(path), leaf.shape, model_size),
        params,
    )


def param_shardings(params: Any, mesh: Mesh) -> Any:
    model_size = mesh.shape.get("model", 1)
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(params, model_size),
        is_leaf=lambda x: isinstance(x, P),
    )


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel axes present in the mesh, slowest first."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pspec(mesh: Mesh) -> P:
    """Batch dim sharded over every data-parallel axis."""
    return P(dp_axes(mesh))
