"""Model assembly: init / train forward / prefill / decode, for every
assigned architecture family (dense, MoE, VLM, hybrid, audio enc-dec, SSM).

The layer stack is executed as *run-grouped scans*: maximal runs of identical
block kinds are stacked (leading run dim) and driven by ``lax.scan`` with
``jax.checkpoint`` on the body — keeps the lowered HLO size O(#runs), not
O(#layers), which is what makes 512-device dry-run compiles tractable, and
gives the standard remat memory/compute trade.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig

# ---------------------------------------------------------------------- #
# Init
# ---------------------------------------------------------------------- #

def _init_layer(key, cfg: ModelConfig, kind: str, cross: bool, causal: bool):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: dict[str, Any] = {"norm1": jnp.zeros((D,), jnp.float32),
                         "norm2": jnp.zeros((D,), jnp.float32)}
    if kind in ("attn", "local"):
        p["attn"] = L.init_attention(ks[0], cfg)
    elif kind == "rglru":
        p["rec"] = L.init_rglru(ks[0], cfg)
    elif kind == "rwkv6":
        p["rwkv"] = L.init_rwkv6(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "rwkv6":
        p["mlp"] = L.init_moe(ks[1], cfg) if cfg.moe else L.init_mlp(ks[1], cfg)
    if cross:
        p["norm_x"] = jnp.zeros((D,), jnp.float32)
        p["xattn"] = L.init_attention(ks[2], cfg, cross=True)
    return p


def _init_run(key, cfg: ModelConfig, kind: str, n: int, cross: bool, causal: bool):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, kind, cross, causal))(keys)


def init_model(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (V, D)) / math.sqrt(D)).astype(jnp.bfloat16),
        "final_norm": jnp.zeros((D,), jnp.float32),
    }
    cross = cfg.enc_dec is not None
    params["runs"] = [
        _init_run(jax.random.fold_in(ks[1], i), cfg, kind, n, cross, True)
        for i, (kind, n) in enumerate(cfg.runs())
    ]
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[2], D, V)
    if cfg.enc_dec:
        params["enc"] = {
            "runs": [_init_run(jax.random.fold_in(ks[3], i), cfg, "attn",
                               cfg.enc_dec.n_enc_layers, False, False)
                     for i in range(1)],
            "final_norm": jnp.zeros((D,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------- #
# Train / full-sequence forward
# ---------------------------------------------------------------------- #

def _layer_fwd(p, cfg: ModelConfig, kind: str, x, enc_out, causal: bool):
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        if cfg.parallel_block and enc_out is None and not cfg.moe:
            # parallel residual: both sublayer outputs are partial-sums over
            # the model axis; adding BEFORE the (GSPMD) psum merges two
            # all-reduces into one per direction.
            xn = L.rmsnorm(x, p["norm1"])
            return (x + L.attention_fwd(p["attn"], cfg, xn, causal=causal,
                                        window=window)
                    + L.mlp_fwd(p["mlp"], cfg, L.rmsnorm(x, p["norm2"])))
        x = x + L.attention_fwd(p["attn"], cfg, L.rmsnorm(x, p["norm1"]),
                                causal=causal, window=window)
    elif kind == "rglru":
        y, _ = L.rglru_fwd(p["rec"], cfg, L.rmsnorm(x, p["norm1"]))
        x = x + y
    elif kind == "rwkv6":
        x = x + L.rwkv6_fwd(p["rwkv"], cfg, L.rmsnorm(x, p["norm1"]))
        return x + L.rwkv6_channel_mix(p["rwkv"], cfg, L.rmsnorm(x, p["norm2"]))
    if enc_out is not None:
        x = x + L.attention_fwd(p["xattn"], cfg, L.rmsnorm(x, p["norm_x"]),
                                kv_src=enc_out)
    sub = L.moe_fwd if cfg.moe else L.mlp_fwd
    return x + sub(p["mlp"], cfg, L.rmsnorm(x, p["norm2"]))


def _run_fwd(stacked, cfg: ModelConfig, kind: str, x, enc_out, causal: bool):
    body = jax.checkpoint(
        lambda x, p: _layer_fwd(p, cfg, kind, x, enc_out, causal),
        prevent_cse=False)

    def step(x, p):
        return body(x, p), None

    x, _ = lax.scan(step, x, stacked)
    return x


def embed_inputs(params, cfg: ModelConfig, inputs: dict) -> jax.Array:
    """tokens (+ optional modality embeds prefix) -> (B, S, D)."""
    x = params["embed"][inputs["tokens"]] * math.sqrt(cfg.d_model)
    if "embeds" in inputs:  # vision/audio stub: precomputed patch embeds
        x = jnp.concatenate([inputs["embeds"].astype(x.dtype), x], axis=1)
    return x


def encoder_fwd(params, cfg: ModelConfig, src_embeds) -> jax.Array:
    x = src_embeds.astype(jnp.bfloat16)
    for stacked in params["enc"]["runs"]:
        x = _run_fwd(stacked, cfg, "attn", x, None, causal=False)
    return L.rmsnorm(x, params["enc"]["final_norm"])


def trunk_fwd(params, cfg: ModelConfig, inputs: dict) -> jax.Array:
    """Embeddings + layer stack + final norm -> hidden states (B, S, D)."""
    enc_out = None
    if cfg.enc_dec:
        enc_out = encoder_fwd(params, cfg, inputs["src_embeds"])
    x = embed_inputs(params, cfg, inputs)
    for stacked, (kind, _) in zip(params["runs"], cfg.runs()):
        x = _run_fwd(stacked, cfg, kind, x, enc_out, causal=True)
    return L.rmsnorm(x, params["final_norm"])


def _head(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def model_fwd(params, cfg: ModelConfig, inputs: dict) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V)."""
    x = trunk_fwd(params, cfg, inputs)
    head = _head(params, cfg)
    return (x @ head.astype(x.dtype)).astype(jnp.float32)


def _ce_chunk(x, labels, head):
    """Cross-entropy partial sums for one sequence chunk."""
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return ((lse - gold) * mask).sum(), mask.sum()


def loss_fn(params, cfg: ModelConfig, batch: dict,
            ce_chunk: int = 512) -> jax.Array:
    """Mean next-token cross-entropy over the *local* batch shard.

    The unembedding + softmax is scanned over sequence chunks with remat so
    the (B, S, V) logits tensor is never materialised — at vocab 256k and
    S=4k that buffer alone would exceed HBM."""
    x = trunk_fwd(params, cfg, batch)
    labels = batch["labels"]
    if "embeds" in batch:  # loss only over the token positions
        x = x[:, batch["embeds"].shape[1]:]
    head = _head(params, cfg)
    B, S, D = x.shape
    if S % ce_chunk or S <= ce_chunk:
        nll, cnt = _ce_chunk(x, labels, head)
        return nll / jnp.maximum(cnt, 1.0)
    n = S // ce_chunk
    xc = x.reshape(B, n, ce_chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n, ce_chunk).swapaxes(0, 1)
    body = jax.checkpoint(_ce_chunk, prevent_cse=False)

    def step(carry, xl):
        nll, cnt = carry
        dn, dc = body(xl[0], xl[1], head)
        return (nll + dn, cnt + dc), None

    (nll, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                             (xc, lc))
    return nll / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------- #
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, B: int, s_max: int, src_len: int = 0) -> list:
    """One cache entry per run, stacked on the run dim."""
    cache = []
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    R = cfg.d_rnn or cfg.d_model
    H6 = cfg.d_model // cfg.rwkv_head_dim
    for kind, n in cfg.runs():
        if kind in ("attn", "local"):
            windowed = kind == "local"
            s_c = min(cfg.window, s_max) if windowed else s_max
            ent = {"k": jnp.zeros((n, B, s_c, Hkv, hd), jnp.bfloat16),
                   "v": jnp.zeros((n, B, s_c, Hkv, hd), jnp.bfloat16)}
            if cfg.enc_dec:
                ent["xk"] = jnp.zeros((n, B, src_len, Hkv, hd), jnp.bfloat16)
                ent["xv"] = jnp.zeros((n, B, src_len, Hkv, hd), jnp.bfloat16)
            cache.append(ent)
        elif kind == "rglru":
            cache.append({"h": jnp.zeros((n, B, R), jnp.float32),
                          "conv": jnp.zeros((n, B, 3, R), jnp.bfloat16)})
        elif kind == "rwkv6":
            hd6 = cfg.rwkv_head_dim
            cache.append({"S": jnp.zeros((n, B, H6, hd6, hd6), jnp.float32),
                          "x_tm": jnp.zeros((n, B, cfg.d_model), jnp.bfloat16),
                          "x_cm": jnp.zeros((n, B, cfg.d_model), jnp.bfloat16)})
    return cache


def _layer_prefill(p, cfg, kind, x, enc_out, keep_full=False):
    """Returns (x_out, cache_entry) for one layer."""
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        y, ck, cv = L.attention_prefill(p["attn"], cfg,
                                        L.rmsnorm(x, p["norm1"]), window=window,
                                        keep_full=keep_full)
        x = x + y
        ent = {"k": ck, "v": cv}
        if enc_out is not None:
            ent["xk"], ent["xv"] = L.cross_kv(p["xattn"], cfg, enc_out)
            x = x + L.attention_fwd(p["xattn"], cfg, L.rmsnorm(x, p["norm_x"]),
                                    kv_src=enc_out)
        sub = L.moe_fwd if cfg.moe else L.mlp_fwd
        x = x + sub(p["mlp"], cfg, L.rmsnorm(x, p["norm2"]))
        return x, ent
    if kind == "rglru":
        y, h, conv = L.rglru_prefill(p["rec"], cfg, L.rmsnorm(x, p["norm1"]))
        x = x + y
        sub = L.moe_fwd if cfg.moe else L.mlp_fwd
        x = x + sub(p["mlp"], cfg, L.rmsnorm(x, p["norm2"]))
        return x, {"h": h, "conv": conv.astype(jnp.bfloat16)}
    if kind == "rwkv6":
        xn = L.rmsnorm(x, p["norm1"])
        y, st = L.rwkv6_fwd(p["rwkv"], cfg, xn, return_state=True)
        x = x + y
        xn2 = L.rmsnorm(x, p["norm2"])
        x = x + L.rwkv6_channel_mix(p["rwkv"], cfg, xn2)
        return x, {"S": st["S"], "x_tm": xn[:, -1].astype(jnp.bfloat16),
                   "x_cm": xn2[:, -1].astype(jnp.bfloat16)}
    raise ValueError(kind)


def prefill(params, cfg: ModelConfig, inputs: dict, s_max: int, *,
            last_pos=None, full_local_cache: bool = False):
    """Process the prompt; return (last-token logits, cache, pos).

    ``last_pos`` ((B,) int32) selects each row's last *real* token for the
    logits instead of column -1 — right-padded variable-length prompts are
    then safe: causality keeps pad tokens out of the real positions' scores,
    and decode overwrites/masks the pad cache entries.  ``full_local_cache``
    keeps windowed layers' caches unwrapped at full length (paged serving
    stores them that way and masks at read time)."""
    enc_out = None
    src_len = 0
    if cfg.enc_dec:
        enc_out = encoder_fwd(params, cfg, inputs["src_embeds"])
        src_len = enc_out.shape[1]
    x = embed_inputs(params, cfg, inputs)
    S = x.shape[1]
    cache = []
    for stacked, (kind, n) in zip(params["runs"], cfg.runs()):
        body = jax.checkpoint(functools.partial(
            _layer_prefill, cfg=cfg, kind=kind, enc_out=enc_out,
            keep_full=full_local_cache),
        prevent_cse=False)

        def step(x, p, body=body):
            x, ent = body(p, x=x)
            return x, ent

        x, ents = lax.scan(step, x, stacked)
        # Pad attention caches out to s_max so decode can update in place.
        if kind in ("attn", "local"):
            s_c = ents["k"].shape[2]
            tgt = s_max if full_local_cache or kind != "local" \
                else min(cfg.window, s_max)
            if s_c < tgt:
                pad = [(0, 0), (0, 0), (0, tgt - s_c), (0, 0), (0, 0)]
                ents["k"] = jnp.pad(ents["k"], pad)
                ents["v"] = jnp.pad(ents["v"], pad)
        cache.append(ents)
    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if last_pos is None:
        xl = x[:, -1:]
    else:
        xl = x[jnp.arange(x.shape[0]), last_pos][:, None]
    logits = (xl @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, cache, S


def _layer_decode(p, cfg, kind, x, ent, pos):
    if kind in ("attn", "local"):
        y, ck, cv = L.attention_decode(p["attn"], cfg, L.rmsnorm(x, p["norm1"]),
                                       ent["k"], ent["v"], pos,
                                       windowed=(kind == "local"))
        x = x + y
        ent = dict(ent, k=ck, v=cv)
        if "xk" in ent:
            x = x + L.cross_attention_decode(p["xattn"], cfg,
                                             L.rmsnorm(x, p["norm_x"]),
                                             ent["xk"], ent["xv"])
        sub = L.moe_fwd if cfg.moe else L.mlp_fwd
        x = x + sub(p["mlp"], cfg, L.rmsnorm(x, p["norm2"]))
        return x, ent
    if kind == "rglru":
        y, h, conv = L.rglru_decode(p["rec"], cfg, L.rmsnorm(x, p["norm1"]),
                                    ent["h"], ent["conv"].astype(jnp.bfloat16))
        x = x + y
        sub = L.moe_fwd if cfg.moe else L.mlp_fwd
        x = x + sub(p["mlp"], cfg, L.rmsnorm(x, p["norm2"]))
        return x, {"h": h, "conv": conv.astype(jnp.bfloat16)}
    if kind == "rwkv6":
        xn = L.rmsnorm(x, p["norm1"])
        st = {"S": ent["S"], "x_tm": ent["x_tm"].astype(xn.dtype)}
        y, st = L.rwkv6_decode(p["rwkv"], cfg, xn, st)
        x = x + y
        xn2 = L.rmsnorm(x, p["norm2"])
        y2, x_cm = L.rwkv6_channel_mix_decode(p["rwkv"], cfg, xn2,
                                              ent["x_cm"].astype(xn2.dtype))
        x = x + y2
        return x, {"S": st["S"], "x_tm": st["x_tm"].astype(jnp.bfloat16),
                   "x_cm": x_cm.astype(jnp.bfloat16)}
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, cache: list, tokens, pos):
    """One-token serve step.  tokens: (B,1) int32; pos: scalar int32.
    Returns (logits (B,1,V), new_cache)."""
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    new_cache = []
    for stacked, ent, (kind, n) in zip(params["runs"], cache, cfg.runs()):
        def step(x, p_ent, kind=kind):
            p, e = p_ent
            x, e2 = _layer_decode(p, cfg, kind, x, e, pos)
            return x, e2

        x, ent2 = lax.scan(step, x, (stacked, ent))
        new_cache.append(ent2)
    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------- #
# Paged serving: block-pool cache / per-request-position decode
# ---------------------------------------------------------------------- #

def paged_arch_check(cfg: ModelConfig) -> None:
    """Paged serving covers pure-attention stacks (attn/local, no enc-dec).

    Recurrent kinds (rglru/rwkv6) carry positionless state that right-padded
    variable-length prefill would corrupt, and enc-dec needs per-request
    encoder outputs — neither fits the shared-pool layout."""
    bad = [k for k, _ in cfg.runs() if k not in ("attn", "local")]
    if bad or cfg.enc_dec:
        raise ValueError(
            f"paged serving supports attention-only decoder stacks; "
            f"got kinds {bad or ['enc_dec']}")


def init_paged_pools(cfg: ModelConfig, n_blocks: int, block_size: int) -> list:
    """One k/v pool pair per run: (run, n_blocks, block_size, Hkv, hd).

    Physical block 0 is reserved as the null block — allocators must never
    hand it to a request, so inactive batch slots (block table all-zero) can
    scatter into it without touching live data."""
    paged_arch_check(cfg)
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    pools = []
    for kind, n in cfg.runs():
        shape = (n, n_blocks, block_size, Hkv, hd)
        pools.append({"k": jnp.zeros(shape, jnp.bfloat16),
                      "v": jnp.zeros(shape, jnp.bfloat16)})
    return pools


def scatter_prefill_cache(pools: list, cache: list, blocks, block_size: int,
                          row: int = 0) -> list:
    """Copy one request's dense prefill cache (from ``prefill`` with
    ``full_local_cache=True``) into its allocated physical blocks.

    cache entries: (run, B, S_p, Hkv, hd) with S_p % block_size == 0;
    ``blocks``: the request's physical block ids, len == S_p // block_size.
    Returns the updated pools list."""
    blocks = jnp.asarray(blocks, jnp.int32)
    out = []
    for pool, ent in zip(pools, cache):
        n, _, S_p, Hkv, hd = ent["k"].shape
        if S_p % block_size:
            raise ValueError(f"prefill length {S_p} not a multiple of "
                             f"block_size {block_size}")
        nb = S_p // block_size
        if nb != len(blocks):
            raise ValueError(f"need {nb} blocks, got {len(blocks)}")
        kk = ent["k"][:, row].reshape(n, nb, block_size, Hkv, hd)
        vv = ent["v"][:, row].reshape(n, nb, block_size, Hkv, hd)
        out.append({"k": pool["k"].at[:, blocks].set(kk),
                    "v": pool["v"].at[:, blocks].set(vv)})
    return out


def _layer_decode_paged(p, cfg, kind, x, ent, block_tables, pos):
    window = cfg.window if kind == "local" else None
    y, pk, pv = L.paged_attention_decode(
        p["attn"], cfg, L.rmsnorm(x, p["norm1"]), ent["k"], ent["v"],
        block_tables, pos, window=window)
    x = x + y
    sub = L.moe_fwd if cfg.moe else L.mlp_fwd
    x = x + sub(p["mlp"], cfg, L.rmsnorm(x, p["norm2"]))
    return x, {"k": pk, "v": pv}


def decode_step_paged(params, cfg: ModelConfig, pools: list, block_tables,
                      tokens, pos):
    """One-token serve step over paged pools.  tokens: (B,1) int32;
    block_tables: (B, max_blocks) int32; pos: (B,) int32 per-slot.
    Returns (logits (B,1,V), new_pools)."""
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    new_pools = []
    for stacked, ent, (kind, n) in zip(params["runs"], pools, cfg.runs()):
        def step(x, p_ent, kind=kind):
            p, e = p_ent
            x, e2 = _layer_decode_paged(p, cfg, kind, x, e, block_tables, pos)
            return x, e2

        x, ent2 = lax.scan(step, x, (stacked, ent))
        new_pools.append(ent2)
    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, new_pools
