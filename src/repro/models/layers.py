"""Neural building blocks, pure JAX.

Every block has ``init_<block>(key, cfg) -> params`` and
``<block>_fwd(params, x, ...) -> y`` plus, where serving needs it, a
``<block>_decode`` single-token step against a cache/state.

Attention uses an online-softmax double-chunked formulation (flash-style) so
that the lowered HLO never materialises an S x S score matrix — this is what
keeps the 32k-prefill dry-run memory term sane; the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU-target version of the same
algorithm and is validated against ``naive_attention`` below.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

# ---------------------------------------------------------------------- #
# Small pieces
# ---------------------------------------------------------------------- #

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics but NO f32 materialisation of x.

    The obvious ``x.astype(f32)`` implementation makes XLA hoist an f32 copy
    of the entire saved-activation stack out of the backward scan (observed:
    +11.8 GB/device on tinyllama train_4k).  Computing the sum-of-squares via
    a dot with f32 accumulation keeps every x-sized tensor in bf16."""
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss / x.shape[-1] + eps)
    scale = inv[..., None].astype(x.dtype) * (1.0 + w).astype(x.dtype)
    return x * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (...,S,half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _uniform(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16):
    return _uniform(key, (d_in, d_out), 1.0 / math.sqrt(d_in)).astype(dtype)


# ---------------------------------------------------------------------- #
# Attention
# ---------------------------------------------------------------------- #

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    D, Q, KV, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], D, Q),
        "wk": dense_init(ks[1], D, KV),
        "wv": dense_init(ks[2], D, KV),
        "wo": dense_init(ks[3], Q, D),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def naive_attention(q, k, v, *, causal: bool, window: int | None,
                    q_pos, k_pos) -> jax.Array:
    """Reference O(S^2)-memory attention.  q:(B,Sq,H,hd) k/v:(B,Sk,Hkv,hd)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def _block_mask(q_pos, k_pos, causal, window):
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


def _flash_fwd_impl(q, k, v, causal, window, cq, ck, q_offset):
    """Online-softmax forward.  Returns (o, lse) with
    o: (B,Sq,H,hd); lse: (B,Hkv,G,Sq) f32."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nq, nk = Sq // cq, Sk // ck
    qc = q.reshape(B, nq, cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(hd)

    def q_step(_, qi_i):
        qi, i = qi_i
        q_pos = q_offset + i * cq + jnp.arange(cq)

        def kv_step(carry, kj_vj_j):
            kj, vj, j = kj_vj_j

            def compute(carry):
                m, l, acc = carry
                k_pos = j * ck + jnp.arange(ck)
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32),
                               kj.astype(jnp.float32)) * scale
                s = jnp.where(_block_mask(q_pos, k_pos, causal, window),
                              s, -1e30)
                m_new = jnp.maximum(m, s.max(-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                l = l * alpha + p.sum(-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
                return m_new, l, acc

            # Block skipping: off-band blocks (above the causal diagonal /
            # outside the sliding window) are genuine HLO conditionals —
            # halves attention FLOPs at 4k causal, 1/32 at 32k window-1k.
            needed = jnp.bool_(True)
            if causal:
                needed &= j * ck <= i * cq + cq - 1 + q_offset
            if window is not None:
                needed &= (q_offset + i * cq) - (j * ck + ck - 1) < window
            return lax.cond(needed, compute, lambda c: c, carry), None

        m0 = jnp.full((B, Hkv, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nk)))
        l = jnp.maximum(l, 1e-30)
        o = acc / l[..., None]
        lse = m + jnp.log(l)
        return None, (o.transpose(0, 3, 1, 2, 4), lse)

    _, (oc, lsec) = lax.scan(q_step, None, (qc, jnp.arange(nq)))
    o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd).astype(q.dtype)
    lse = lsec.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, Sq)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, cq, ck, q_offset):
    o, _ = _flash_fwd_impl(q, k, v, causal, window, cq, ck, q_offset)
    return o


def _flash_vjp_fwd(q, k, v, causal, window, cq, ck, q_offset):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, cq, ck, q_offset)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(causal, window, cq, ck, q_offset, res, do):
    """Flash backward: recompute scores blockwise; memory O(block^2), not
    O(S^2) — this is what keeps the train-shape remat footprint sane."""
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / math.sqrt(hd)
    f32 = jnp.float32
    qg = q.reshape(B, Sq, Hkv, G, hd)
    dog = do.reshape(B, Sq, Hkv, G, hd)
    og = o.reshape(B, Sq, Hkv, G, hd)
    # D_i = rowsum(do * o): (B,Hkv,G,Sq)
    Dd = jnp.einsum("bqhgd,bqhgd->bhgq", dog.astype(f32), og.astype(f32))
    qc = qg.reshape(B, nq, cq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)   # (nq,B,h,g,cq,hd)
    doc = dog.reshape(B, nq, cq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)
    lsec = lse.reshape(B, Hkv, G, nq, cq).transpose(3, 0, 1, 2, 4)       # (nq,B,h,g,cq)
    Dc = Dd.reshape(B, Hkv, G, nq, cq).transpose(3, 0, 1, 2, 4)
    kc = k.reshape(B, nk, ck, Hkv, hd).transpose(1, 0, 3, 2, 4)          # (nk,B,h,ck,hd)
    vc = v.reshape(B, nk, ck, Hkv, hd).transpose(1, 0, 3, 2, 4)

    def kv_step(dq, blk):
        kj, vj, j = blk
        k_pos = j * ck + jnp.arange(ck)

        def q_step(carry, qblk):
            qi, doi, lsei, Di, i = qblk

            def compute(carry):
                dkj, dvj = carry
                q_pos = q_offset + i * cq + jnp.arange(cq)
                s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(f32),
                               kj.astype(f32)) * scale
                s = jnp.where(_block_mask(q_pos, k_pos, causal, window),
                              s, -1e30)
                p = jnp.exp(s - lsei[..., None])             # (B,h,g,cq,ck)
                dvj = dvj + jnp.einsum("bhgqk,bhgqd->bhkd", p,
                                       doi.astype(f32))
                dp = jnp.einsum("bhgqd,bhkd->bhgqk", doi.astype(f32),
                                vj.astype(f32))
                ds = p * (dp - Di[..., None]) * scale
                dkj = dkj + jnp.einsum("bhgqk,bhgqd->bhkd", ds,
                                       qi.astype(f32))
                dqi = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kj.astype(f32))
                return (dkj, dvj), dqi

            needed = jnp.bool_(True)
            if causal:
                needed &= j * ck <= i * cq + cq - 1 + q_offset
            if window is not None:
                needed &= (q_offset + i * cq) - (j * ck + ck - 1) < window
            zero_dq = jnp.zeros((B, Hkv, G, cq, hd), f32)
            return lax.cond(needed, compute,
                            lambda c: (c, zero_dq), carry)

        z = jnp.zeros((B, Hkv, ck, hd), f32)
        (dkj, dvj), dqc = lax.scan(
            q_step, (z, z), (qc, doc, lsec, Dc, jnp.arange(nq)))
        return dq + dqc, (dkj, dvj)

    dq0 = jnp.zeros((nq, B, Hkv, G, cq, hd), f32)
    dq, (dk, dv) = lax.scan(kv_step, dq0,
                            (kc, vc, jnp.arange(nk)))
    dq = dq.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    dk = dk.transpose(1, 0, 3, 2, 4).reshape(B, Sk, Hkv, hd)
    dv = dv.transpose(1, 0, 3, 2, 4).reshape(B, Sk, Hkv, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                      chunk_q: int = 512, chunk_k: int = 512,
                      q_offset: int = 0, impl: str | None = None) -> jax.Array:
    """Flash attention (online softmax fwd, blockwise-recompute custom VJP),
    GQA-aware, never materialising an S x S buffer in fwd OR bwd.  Falls back
    to the naive oracle for ragged (test-sized) shapes.

    ``impl``: None -> auto ("pallas" on TPU, "jnp" elsewhere).  "pallas"
    dispatches to ``repro.kernels.flash_attention`` — fwd AND bwd are Pallas
    kernels behind a ``jax.custom_vjp``, so training steps no longer fall
    back to this module's jnp VJP on TPU.  "jnp" keeps the pure-jnp lowering
    below, which doubles as the kernels' oracle and the CPU dry-run path."""
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq % chunk_q or Sk % chunk_k:
        q_pos = q_offset + jnp.arange(Sq)
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_pos=q_pos, k_pos=jnp.arange(Sk))
    if impl is None:
        from repro.kernels.backend import on_tpu  # lazy: models stay light
        impl = "pallas" if on_tpu() else "jnp"
    if impl == "pallas":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal, window, chunk_q, chunk_k,
                                  q_offset, None)
    if impl != "jnp":
        raise ValueError(f"chunked_attention impl must be None, 'pallas' or "
                         f"'jnp', got {impl!r}")
    return _flash(q, k, v, causal, window, chunk_q, chunk_k, q_offset)


def attention_fwd(p, cfg: ModelConfig, x, *, causal=True, window=None,
                  kv_src=None, positions=None) -> jax.Array:
    """Full attention sublayer (projections + rope + attention + out proj).

    kv_src: source sequence for cross-attention (keys/values from encoder).
    """
    B, S, D = x.shape
    hd = cfg.head_dim
    src = x if kv_src is None else kv_src
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q, k = rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])
    if positions is None:
        positions = jnp.arange(S)
    if kv_src is None:  # rope only for self-attention
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, jnp.arange(src.shape[1]), cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=causal and kv_src is None,
                          window=window)
    return o.reshape(B, S, cfg.q_dim) @ p["wo"]


# -------------------------- decode (KV cache) ------------------------- #

@dataclasses.dataclass
class KVCache:
    """Per-run stacked cache.  k/v: (run, B, S_cache, Hkv, hd).  For sliding
    window layers S_cache == window and writes wrap modulo the window."""
    k: jax.Array
    v: jax.Array
    windowed: bool

    @staticmethod
    def init(run_len, B, s_max, cfg: ModelConfig, windowed: bool):
        s_cache = min(cfg.window, s_max) if windowed else s_max
        shape = (run_len, B, s_cache, cfg.n_kv_heads, cfg.head_dim)
        z = jnp.zeros(shape, jnp.bfloat16)
        return KVCache(z, z, windowed)


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v"], meta_fields=["windowed"])


def _cache_attend_sp(q, k_new, v_new, cache_k, cache_v, pos, windowed,
                     axis="model"):
    """Flash-decode partial attention INSIDE shard_map manual over ``axis``.

    The cache sequence dim is sharded over the model axis; each rank scores
    its slice, then a pmax/psum log-sum-exp combine merges the partials —
    three tiny (B,H)-sized collectives instead of GSPMD re-gathering the
    cache/score tensors every layer (measured 37.5 GB/chip/step on qwen3
    decode_32k multi-pod with the naive lowering).

    q: (B,Hkv,G,hd) replicated over model; k/v_new: (B,1,Hkv,hd);
    cache_k/v: (B,S_loc,Hkv,hd) = this rank's sequence slice."""
    nsh = int(lax.psum(1, axis))
    r = lax.axis_index(axis)
    B, S_loc, Hkv, hd = cache_k.shape
    S_tot = S_loc * nsh
    slot_g = jnp.where(windowed, pos % S_tot, jnp.minimum(pos, S_tot - 1))
    local = slot_g - r * S_loc
    in_range = (local >= 0) & (local < S_loc)
    lc = jnp.clip(local, 0, S_loc - 1)
    ck = jnp.where(in_range,
                   lax.dynamic_update_slice(
                       cache_k, k_new.astype(cache_k.dtype), (0, lc, 0, 0)),
                   cache_k)
    cv = jnp.where(in_range,
                   lax.dynamic_update_slice(
                       cache_v, v_new.astype(cache_v.dtype), (0, lc, 0, 0)),
                   cache_v)
    idx = r * S_loc + jnp.arange(S_loc)        # absolute cache indices
    if windowed:
        abs_pos = pos - ((pos - idx) % S_tot)
        valid = (abs_pos >= 0) & (abs_pos >= pos - S_tot + 1) & (abs_pos <= pos)
    else:
        valid = idx <= pos
    sc = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                    ck.astype(jnp.float32)) / math.sqrt(hd)
    sc = jnp.where(valid[None, None, None, :], sc, -1e30)
    m = lax.pmax(sc.max(-1), axis)             # (B,Hkv,G)
    pr = jnp.exp(sc - m[..., None])
    l = lax.psum(pr.sum(-1), axis)
    o = lax.psum(jnp.einsum("bhgk,bkhd->bhgd", pr, cv.astype(jnp.float32)),
                 axis)
    return o / jnp.maximum(l, 1e-30)[..., None], ck, cv


def _sp_decode_ctx(s_cache: int, batch: int):
    """(use_sp, auto_dp) when a model axis exists and divides the cache."""
    import jax.sharding as jsh
    from repro.compat import get_abstract_mesh
    am = get_abstract_mesh()
    if am is None or "model" not in (am.axis_names or ()):
        return False, ()
    msize = am.shape["model"]
    if msize <= 1 or s_cache % msize:
        return False, ()
    auto_dp = tuple(n for n, t in zip(am.axis_names, am.axis_types)
                    if n in ("pod", "data") and "Auto" in str(t))
    dp_deg = 1
    for a in auto_dp:
        dp_deg *= am.shape[a]
    if batch % dp_deg:
        auto_dp = ()
    return True, auto_dp


def attention_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                     windowed: bool):
    """One-token decode.  x: (B,1,D); cache_k/v: (B,S_cache,Hkv,hd);
    pos: scalar int32 — number of tokens already in the cache.

    With a model axis present, the cache attention runs as an explicit
    flash-decode shard_map (sequence-sharded cache + LSE combine)."""
    import jax.sharding as jsh
    from repro.compat import shard_map

    B = x.shape[0]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q, k = rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])
    q = rope(q, pos[None], cfg.rope_theta)
    k = rope(k, pos[None], cfg.rope_theta)

    use_sp, auto_dp = _sp_decode_ctx(cache_k.shape[1], B)
    if use_sp:
        G = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(B, cfg.n_kv_heads, G, hd)
        P = jsh.PartitionSpec
        bdp = auto_dp if auto_dp else None
        rep4 = P(bdp, None, None, None)
        cache_spec = P(bdp, "model", None, None)
        sp = shard_map(
            lambda qq, kn, vn, ckk, cvv, pp: _cache_attend_sp(
                qq, kn, vn, ckk, cvv, pp, windowed),
            in_specs=(rep4, rep4, rep4, cache_spec, cache_spec, P()),
            out_specs=(rep4, cache_spec, cache_spec),
            axis_names={"model", *(auto_dp or ())}, check_vma=False)
        o, cache_k, cache_v = sp(qg, k, v, cache_k, cache_v, pos)
        o = o.reshape(B, 1, cfg.q_dim).astype(x.dtype)
        return o @ p["wo"], cache_k, cache_v

    s_cache = cache_k.shape[1]
    slot = jnp.where(windowed, pos % s_cache, jnp.minimum(pos, s_cache - 1))
    cache_k = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                       (0, slot, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                       (0, slot, 0, 0))
    # positions of cache entries for masking
    idx = jnp.arange(s_cache)
    if windowed:
        # entry i holds absolute position: the latest p' <= pos with p'%W == i
        abs_pos = pos - ((pos - idx) % s_cache)
        valid = (abs_pos >= 0) & (abs_pos >= pos - s_cache + 1) & (abs_pos <= pos)
    else:
        abs_pos = idx
        valid = idx <= pos
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, G, hd)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                    cache_k.astype(jnp.float32)) / math.sqrt(hd)
    sc = jnp.where(valid[None, None, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pr, cache_v.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.q_dim).astype(x.dtype)
    return o @ p["wo"], cache_k, cache_v


def paged_attention_decode(p, cfg: ModelConfig, x, pool_k, pool_v,
                           block_tables, pos, *, window: int | None = None):
    """One-token decode against a paged KV pool (vLLM-style block table).

    x: (B,1,D); pool_k/v: (n_blocks, block_size, Hkv, hd) — one shared
    physical pool per layer; block_tables: (B, max_blocks) int32 mapping each
    slot's logical block i to a physical block (0 = the reserved null block,
    never owned by a live request, so idle slots write there harmlessly);
    pos: (B,) int32 per-slot token count — unlike the dense path the write
    pointer is per request, which is what lets continuous batching mix
    requests at different depths in one step.

    The gather `pool[table]` reconstructs each slot's cache in logical token
    order, so with max_blocks*block_size == s_max the score/softmax math is
    term-for-term identical to :func:`attention_decode`'s dense full-
    attention path — bit-identical logits (asserted in tests).  Windowed
    layers store the full sequence and mask `pos - idx >= window` instead of
    wrapping; numerics match the wrapped dense path exactly when no wrap has
    occurred (window >= s_max) and to float tolerance otherwise (the softmax
    sums the same terms in a different order).
    """
    B = x.shape[0]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q, k = rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    BS = pool_k.shape[1]
    bidx = block_tables[jnp.arange(B), pos // BS]       # (B,) physical block
    off = pos % BS
    pool_k = pool_k.at[bidx, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[bidx, off].set(v[:, 0].astype(pool_v.dtype))

    MB = block_tables.shape[1]
    S = MB * BS
    gk = pool_k[block_tables].reshape(B, S, *pool_k.shape[2:])
    gv = pool_v[block_tables].reshape(B, S, *pool_v.shape[2:])
    idx = jnp.arange(S)
    valid = idx[None, :] <= pos[:, None]
    if window is not None:
        valid &= pos[:, None] - idx[None, :] < window
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, G, hd)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                    gk.astype(jnp.float32)) / math.sqrt(hd)
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pr, gv.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.q_dim).astype(x.dtype)
    return o @ p["wo"], pool_k, pool_v


def attention_prefill(p, cfg: ModelConfig, x, *, window=None,
                      keep_full: bool = False):
    """Like attention_fwd (self, causal) but also returns the KV cache slice.

    For windowed layers the cache keeps the last ``window`` keys; prefill
    length must be a multiple of the window so modular slots line up with
    ``attention_decode``'s write pointer.  ``keep_full`` returns the whole
    sequence instead (the paged pool stores windowed layers unwrapped and
    masks at read time), which also lifts the S %% window constraint.
    """
    B, S, D = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q, k = rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])
    pos = jnp.arange(S)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True, window=window)
    y = o.reshape(B, S, cfg.q_dim) @ p["wo"]
    if window is not None and S >= window and not keep_full:
        if S % window != 0:
            raise ValueError(
                f"windowed prefill needs S % window == 0, got "
                f"S={S} window={window}")
        ck, cv = k[:, S - window:], v[:, S - window:]
    else:
        ck, cv = k, v
    return y, ck.astype(jnp.bfloat16), cv.astype(jnp.bfloat16)


def cross_kv(p, cfg: ModelConfig, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, Sk, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim)
    return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)


def cross_attention_decode(p, cfg: ModelConfig, x, ck, cv):
    """One-token cross-attention against precomputed encoder K/V."""
    B = x.shape[0]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, G, hd)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                    ck.astype(jnp.float32)) / math.sqrt(hd)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pr, cv.astype(jnp.float32))
    return o.reshape(B, 1, cfg.q_dim).astype(x.dtype) @ p["wo"]


# ---------------------------------------------------------------------- #
# MLP / MoE
# ---------------------------------------------------------------------- #

def init_mlp(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    p = {"wi": dense_init(ks[0], D, F), "wo": dense_init(ks[1], F, D)}
    if cfg.activation in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[2], D, F)
    return p


def mlp_fwd(p, cfg: ModelConfig, x) -> jax.Array:
    h = x @ p["wi"]
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"]


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    D, Fe, E = cfg.d_model, m.d_ff_expert, m.n_experts
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": dense_init(ks[0], D, E).astype(jnp.float32),
        "w_in": _uniform(ks[1], (E, D, Fe), scale).astype(jnp.bfloat16),
        "w_gate": _uniform(ks[2], (E, D, Fe), scale).astype(jnp.bfloat16),
        "w_out": _uniform(ks[3], (E, Fe, D), 1.0 / math.sqrt(Fe)).astype(jnp.bfloat16),
    }
    if m.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg)
    return p


MOE_CHUNK = 8192  # token-block size for the scanned dispatch


def _moe_block(p, m, xt):
    """Route + dispatch + expert compute for one block of tokens (T, D)."""
    T, D = xt.shape
    logits = xt.astype(jnp.float32) @ p["router"]
    gates, eidx = lax.top_k(jax.nn.softmax(logits, axis=-1), m.top_k)  # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    flat_e = eidx.reshape(-1)                      # (T*k,)
    order = jnp.argsort(flat_e)                    # stable sort by expert
    tok_for = order // m.top_k                     # token index per slot
    xs = xt[tok_for]                               # (T*k, D) sorted by expert
    group_sizes = jnp.bincount(flat_e, length=m.n_experts)
    h = lax.ragged_dot(xs, p["w_in"], group_sizes)
    g = lax.ragged_dot(xs, p["w_gate"], group_sizes)
    h = jax.nn.silu(g) * h
    yo = lax.ragged_dot(h, p["w_out"], group_sizes)  # (T*k, D)
    yo = yo[jnp.argsort(order)].reshape(T, m.top_k, D)
    return jnp.einsum("tk,tkd->td", gates.astype(yo.dtype), yo)


def _moe_block_ep(p, m, xt, axis: str):
    """Expert-parallel MoE block INSIDE a shard_map manual over ``axis``.

    Each rank owns E_local = E/|axis| experts (w_* enter as local slices).
    Tokens are replicated across the model axis (as GSPMD already keeps the
    residual stream), so dispatch is a LOCAL capacity-bounded gather — no
    all-to-all, and crucially no per-block all-gather of expert weights
    (GSPMD cannot partition ragged_dot and was gathering all experts every
    chunk: measured 9.3 TB/chip on llama4 prefill_32k).  Combine = one psum.
    """
    T, D = xt.shape
    nshards = int(lax.psum(1, axis))
    rank = lax.axis_index(axis)
    E_local = p["w_in"].shape[0]          # local expert slice
    e0 = rank * E_local

    logits = xt.astype(jnp.float32) @ p["router"]   # router is replicated
    gates, eidx = lax.top_k(jax.nn.softmax(logits, axis=-1), m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                       # (T*k,) global expert ids
    local = flat_e - e0
    mine = (local >= 0) & (local < E_local)
    # capacity per rank: fair share + slack for imbalance
    C = int(T * m.top_k * m.capacity_factor) // nshards
    C = max(C - C % 8, 8)
    # sort my slots first (by local expert id), overflow + others last
    key = jnp.where(mine, local, E_local)
    order = jnp.argsort(key)[:C]                    # static-size selection
    sel_local = key[order]                          # E_local == padding
    valid = sel_local < E_local
    tok_for = order // m.top_k
    xs = jnp.where(valid[:, None], xt[tok_for], 0.0)
    group_sizes = jnp.bincount(jnp.where(valid, sel_local, E_local),
                               length=E_local + 1)[:E_local]
    h = lax.ragged_dot(xs, p["w_in"], group_sizes)
    g = lax.ragged_dot(xs, p["w_gate"], group_sizes)
    h = jax.nn.silu(g) * h
    yo = lax.ragged_dot(h, p["w_out"], group_sizes)  # (C, D)
    w = jnp.where(valid, gates.reshape(-1)[order], 0.0)
    out = jnp.zeros((T, D), jnp.float32).at[tok_for].add(
        yo.astype(jnp.float32) * w[:, None])
    return lax.psum(out, axis).astype(xt.dtype)


def moe_fwd(p, cfg: ModelConfig, x, chunk: int = MOE_CHUNK) -> jax.Array:
    """Top-k MoE via sort + lax.ragged_dot (MegaBlocks-style).

    Two data paths:
      * explicit expert parallelism (shard_map manual over `model`) when a
        model axis exists and divides n_experts — local capacity-bounded
        dispatch, one combine psum;
      * single-device ragged path otherwise (tests, no-TP meshes).
    Long sequences are scanned in token blocks with remat: dispatch buffers
    live only per block (8x working-set cut at olmoe prefill_32k)."""
    import jax.sharding as jsh
    from repro.compat import shard_map

    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)

    block = None
    from repro.compat import get_abstract_mesh
    am = get_abstract_mesh()
    if am is not None and "model" in (am.axis_names or ()):
        msize = am.shape["model"]
        if msize > 1 and m.n_experts % msize == 0:
            # dp axes still in AUTO state (e.g. the GSPMD serving path) must
            # become manual alongside `model`, with tokens sharded over them
            # — otherwise the P() token spec would force an all-gather of
            # the whole global batch onto every device.
            auto_dp = tuple(
                n for n, t in zip(am.axis_names, am.axis_types)
                if n in ("pod", "data") and "Auto" in str(t))
            manual = {"model", *auto_dp}
            tok_spec = (jsh.PartitionSpec(auto_dp, None) if auto_dp
                        else jsh.PartitionSpec())
            especs = {
                "router": jsh.PartitionSpec(),
                "w_in": jsh.PartitionSpec("model", None, None),
                "w_gate": jsh.PartitionSpec("model", None, None),
                "w_out": jsh.PartitionSpec("model", None, None),
            }
            if m.shared_expert:
                especs["shared"] = jax.tree.map(
                    lambda _: jsh.PartitionSpec(), p["shared"])
            ep = shard_map(
                lambda pp, xb: _moe_block_ep(pp, m, xb, "model"),
                in_specs=(especs, tok_spec),
                out_specs=tok_spec,
                axis_names=manual, check_vma=False)
            block = lambda xb: ep(p, xb)
    if block is None:
        block = lambda xb: _moe_block(p, m, xb)

    if T <= chunk or T % chunk:
        y = block(xt).reshape(B, S, D)
    else:
        blocks = xt.reshape(T // chunk, chunk, D)
        body = jax.checkpoint(block, prevent_cse=False)
        y = lax.scan(lambda c, xb: (c, body(xb)), None, blocks)[1]
        y = y.reshape(B, S, D)
    if m.shared_expert:
        y = y + mlp_fwd(p["shared"], cfg, x)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------- #
# RG-LRU (Griffin / recurrentgemma recurrent block)
# ---------------------------------------------------------------------- #

def init_rglru(key, cfg: ModelConfig) -> dict:
    R = cfg.d_rnn or cfg.d_model
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], D, R),
        "w_gate": dense_init(ks[1], D, R),
        "conv": _uniform(ks[2], (4, R), 0.5).astype(jnp.bfloat16),
        "w_a": dense_init(ks[3], R, R),
        "w_i": dense_init(ks[4], R, R),
        "lam": jnp.linspace(-4.3, -9.0, R).astype(jnp.float32),  # a in (.9,.999)
        "w_out": dense_init(ks[5], R, D),
    }


def _rglru_gates(p, u):
    """u: (..., R) conv output -> (a, gated_input) both f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r  # c=8 per Griffin
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * uf)
    return a, b


def rglru_fwd(p, cfg: ModelConfig, x, h0=None):
    """x: (B,S,D) -> (B,S,D).  Linear diagonal recurrence via associative
    scan: h_t = a_t h_{t-1} + b_t."""
    B, S, D = x.shape
    u = x @ p["w_x"]
    gate = x @ p["w_gate"]
    # causal depthwise conv, kernel 4
    upad = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
    u = sum(upad[:, i : i + S] * p["conv"][i] for i in range(4))
    a, b = _rglru_gates(p, u)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = lax.associative_scan(combine, (a, b), axis=1)
    y = (jax.nn.gelu(gate.astype(jnp.float32)) * h).astype(x.dtype)
    return y @ p["w_out"], h[:, -1]


def rglru_prefill(p, cfg: ModelConfig, x):
    """Forward + recurrent/conv state for decode continuation."""
    B, S, D = x.shape
    u_pre = x @ p["w_x"]                # pre-conv inputs
    y, h_last = rglru_fwd(p, cfg, x)
    if S >= 3:
        conv_state = u_pre[:, -3:]
    else:
        conv_state = jnp.pad(u_pre, ((0, 0), (3 - S, 0), (0, 0)))
    return y, h_last.astype(jnp.float32), conv_state


def rglru_decode(p, cfg: ModelConfig, x, h_prev, conv_state):
    """x: (B,1,D); h_prev: (B,R); conv_state: (B,3,R)."""
    u_new = (x @ p["w_x"])[:, 0]                      # (B,R)
    gate = (x @ p["w_gate"])[:, 0]
    window = jnp.concatenate([conv_state, u_new[:, None]], axis=1)  # (B,4,R)
    u = jnp.einsum("bkr,kr->br", window, p["conv"])
    a, b = _rglru_gates(p, u)
    h = a * h_prev + b
    y = (jax.nn.gelu(gate.astype(jnp.float32)) * h).astype(x.dtype)
    return (y @ p["w_out"])[:, None], h, window[:, 1:]


# ---------------------------------------------------------------------- #
# RWKV-6 ("Finch"): linear attention with data-dependent per-channel decay
# ---------------------------------------------------------------------- #

def init_rwkv6(key, cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H = D // cfg.rwkv_head_dim
    ks = jax.random.split(key, 9)
    return {
        # time-mix
        "w_r": dense_init(ks[0], D, D),
        "w_k": dense_init(ks[1], D, D),
        "w_v": dense_init(ks[2], D, D),
        "w_g": dense_init(ks[3], D, D),
        "w_w": dense_init(ks[4], D, D),     # decay projection
        "w_o": dense_init(ks[5], D, D),
        "u": _uniform(ks[6], (H, cfg.rwkv_head_dim), 0.5).astype(jnp.float32),
        "mix": _uniform(ks[7], (5, D), 0.5).astype(jnp.float32),  # r,k,v,g,w
        # channel-mix
        "cm_k": dense_init(ks[8], D, F),
        "cm_v": dense_init(jax.random.fold_in(key, 99), F, D),
        "cm_r": dense_init(jax.random.fold_in(key, 98), D, D),
        "cm_mix": _uniform(jax.random.fold_in(key, 97), (2, D), 0.5).astype(jnp.float32),
    }


def _token_shift(x, x_prev_last=None):
    """x_{t-1} with zero (or carried state) at t=0.  x: (B,S,D)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev_last is not None:
        shifted = shifted.at[:, 0].set(x_prev_last)
    return shifted


def _wkv_chunk_scan(r, k, v, w, u, chunk: int):
    """Chunked linear recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T with
    per-step output o_t = r_t S_{t-1} + (r_t . (u*k_t)) v_t.

    r,k,v,w: (B,S,H,hd) — w in (0,1); u: (H,hd).  Returns (o, S_final).
    """
    B, S, H, hd = r.shape
    C = chunk
    if S % C != 0:
        raise ValueError(f"linear-attention chunking needs S % chunk "
                         f"== 0, got S={S} chunk={C}")
    n = S // C
    rs = r.reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)  # (n,B,H,C,hd)
    ks_ = k.reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)
    ws = w.reshape(B, n, C, H, hd).transpose(1, 0, 3, 2, 4)

    def step(S_prev, x):
        rc, kc, vc, wc = x  # (B,H,C,hd)
        logw = jnp.log(jnp.maximum(wc, 1e-8))
        e = jnp.exp(jnp.cumsum(logw, axis=2))        # e_t = prod_{j<=t} w_j
        e_excl = e / jnp.maximum(wc, 1e-8)           # e_{t-1} relative
        # inter-chunk: o_t += (r_t * e_excl_t) @ S_prev
        o = jnp.einsum("bhtd,bhde->bhte", rc * e_excl, S_prev)
        # intra-chunk: scores_{t,j} = (r_t*e_excl_t) . (k_j/e_j), j < t
        kk = kc / jnp.maximum(e, 1e-30)
        sc = jnp.einsum("bhtd,bhjd->bhtj", rc * e_excl, kk)
        mask = jnp.tril(jnp.ones((C, C), bool), -1)
        sc = jnp.where(mask, sc, 0.0)
        o = o + jnp.einsum("bhtj,bhjd->bhtd", sc, vc)
        # diagonal bonus term
        bonus = jnp.einsum("bhtd,bhtd->bht", rc, u[None, :, None, :] * kc)
        o = o + bonus[..., None] * vc
        # state update
        S_new = e[:, :, -1][..., None] * S_prev + jnp.einsum(
            "bhtd,bhte->bhde", kk * e[:, :, -1][:, :, None], vc)
        return S_new, o

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S_fin, os_ = lax.scan(step, S0, (rs, ks_, vs, ws))
    o = os_.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return o, S_fin


def rwkv6_fwd(p, cfg: ModelConfig, x, chunk: int = 16, return_state: bool = False):
    """RWKV-6 time-mix sublayer (pre-norm handled by caller).  x: (B,S,D)."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    xp = _token_shift(x)
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mix[i] * (xp - x) for i in range(5))
    r = (xr @ p["w_r"]).reshape(B, S, H, hd).transpose(0, 1, 2, 3)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd)
    g = xg @ p["w_g"]
    # Decay clamp keeps the factored chunk recurrence in f32 range for
    # chunk<=16 (see _wkv_chunk_scan numerics note in DESIGN.md).
    w = jnp.exp(-jnp.exp(jnp.clip((xw @ p["w_w"]).astype(jnp.float32),
                                  -8, 0.5))).reshape(B, S, H, hd)
    # pad sequence to a chunk multiple (zero k contributes nothing; w=1 keeps
    # the state unchanged so S_fin stays exact)
    pad = (-S) % chunk
    if pad:
        zpad = lambda t, fill=0.0: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)),
                                           constant_values=fill)
        r, k, v = (zpad(t.astype(jnp.float32)) for t in (r, k, v))
        w = zpad(w, fill=1.0)
    o, S_fin = _wkv_chunk_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w, p["u"], chunk)
    if pad:
        o = o[:, :S]
    o = (o.reshape(B, S, D) * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    y = o @ p["w_o"]
    if return_state:
        return y, {"S": S_fin, "x_tm": x[:, -1], "x_cm": x[:, -1]}
    return y


def rwkv6_channel_mix(p, cfg: ModelConfig, x):
    xp = _token_shift(x)
    mix = p["cm_mix"].astype(x.dtype)
    xk = x + mix[0] * (xp - x)
    xr = x + mix[1] * (xp - x)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid((xr @ p["cm_r"]).astype(jnp.float32)).astype(x.dtype) * (kk @ p["cm_v"])


def rwkv6_channel_mix_decode(p, cfg: ModelConfig, x, x_cm_prev):
    """Single-token channel mix.  x: (B,1,D); x_cm_prev: (B,D)."""
    xt = x[:, 0]
    mix = p["cm_mix"].astype(x.dtype)
    xk = xt + mix[0] * (x_cm_prev - xt)
    xr = xt + mix[1] * (x_cm_prev - xt)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    y = jax.nn.sigmoid((xr @ p["cm_r"]).astype(jnp.float32)).astype(x.dtype) * (kk @ p["cm_v"])
    return y[:, None], xt


def rwkv6_decode(p, cfg: ModelConfig, x, state):
    """Single-token step.  state = {"S": (B,H,hd,hd), "x_tm": (B,D),
    "x_cm": (B,D)}."""
    B, _, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    xt = x[:, 0]
    mix = p["mix"].astype(x.dtype)
    xp = state["x_tm"]
    xr, xk, xv, xg, xw = (xt + mix[i] * (xp - xt) for i in range(5))
    r = (xr @ p["w_r"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, H, hd).astype(jnp.float32)
    g = xg @ p["w_g"]
    w = jnp.exp(-jnp.exp(jnp.clip((xw @ p["w_w"]).astype(jnp.float32), -8, 0.5)))
    w = w.reshape(B, H, hd)
    S = state["S"]
    o = jnp.einsum("bhd,bhde->bhe", r, S) + \
        jnp.einsum("bhd,bhd->bh", r, p["u"][None] * k)[..., None] * v
    S = w[..., None] * S + k[..., None] * v[:, :, None, :]
    o = (o.reshape(B, D) * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    y = (o @ p["w_o"])[:, None]
    # channel mix on (y + x)? caller handles residuals; here only state keep
    new_state = dict(state, S=S, x_tm=xt)
    return y, new_state
