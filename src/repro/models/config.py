"""Model configuration: one dataclass covering every assigned architecture.

Layer stacks are described by a per-layer ``pattern`` of block kinds:
  "attn"    full causal self-attention
  "local"   sliding-window self-attention (window = cfg.window)
  "rglru"   RG-LRU recurrent block (Griffin / recurrentgemma)
  "rwkv6"   RWKV-6 "Finch" linear-attention block with data-dependent decay
Every block is followed by an MLP (or MoE) sublayer except "rwkv6", which
uses the RWKV channel-mix in place of the MLP.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = ["MoECfg", "EncDecCfg", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # llama4-style: a shared dense expert alongside the routed ones
    shared_expert: bool = False


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    n_enc_layers: int
    n_dec_layers: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...]            # len == n_layers (decoder side)
    window: int = 1024                  # sliding-window size for "local"
    qk_norm: bool = False
    rope_theta: float = 10000.0
    activation: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = True
    moe: MoECfg | None = None
    enc_dec: EncDecCfg | None = None
    d_rnn: int | None = None            # RG-LRU recurrence width
    rwkv_head_dim: int = 64
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # which family flag ("dense"|"moe"|"vlm"|"hybrid"|"audio"|"ssm")
    family: str = "dense"
    # PaLM/GPT-J-style parallel residual block: y = x + attn(n(x)) + mlp(n(x))
    # — halves the per-layer tensor-parallel all-reduces (perf variant; the
    # paper-faithful configs keep sequential blocks)
    parallel_block: bool = False
    # modality frontend stub: number of non-token embedding positions
    frontend: str | None = None         # None | "vision" | "audio"

    # ------------------------------------------------------------------ #
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def subquadratic(self) -> bool:
        """True iff a 500k-token decode is feasible (no full-attention layer)."""
        return all(k in ("rglru", "rwkv6", "local") for k in self.pattern)

    def runs(self) -> list[tuple[str, int]]:
        """Maximal runs of identical block kinds (scan groups)."""
        out: list[tuple[str, int]] = []
        for k in self.pattern:
            if out and out[-1][0] == k:
                out[-1] = (k, out[-1][1] + 1)
            else:
                out.append((k, 1))
        return out

    def param_count(self) -> int:
        """Total parameters (embedding + blocks); MoE counts all experts."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb + D  # final norm
        n_dec = self.enc_dec.n_dec_layers if self.enc_dec else self.n_layers
        for kind in self.pattern:
            total += self._block_params(kind, cross=False)
        if self.enc_dec:
            for _ in range(self.enc_dec.n_enc_layers):
                total += self._block_params("attn", cross=False)
            # decoder cross-attention on top of the pattern blocks
            total += n_dec * self._attn_params()
        return total

    def _attn_params(self) -> int:
        D = self.d_model
        return D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D

    def _mlp_params(self) -> int:
        D, F = self.d_model, self.d_ff
        if self.moe is not None:
            E, Fe = self.moe.n_experts, self.moe.d_ff_expert
            routed = E * (3 if self.activation == "swiglu" else 2) * self.d_model * Fe
            shared = (3 * D * F) if self.moe.shared_expert else 0
            return routed + shared + D * E  # + router
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * D * F

    def _block_params(self, kind: str, cross: bool) -> int:
        D = self.d_model
        if kind in ("attn", "local"):
            return self._attn_params() + self._mlp_params() + 2 * D
        if kind == "rglru":
            R = self.d_rnn or D
            return (2 * D * R + 2 * R * R + 4 * R + R * D
                    + self._mlp_params() + 2 * D)
        if kind == "rwkv6":
            # time-mix (r,k,v,g,w proj + out) + channel-mix (k,v,r)
            return 7 * D * D + 2 * D * self.d_ff + 2 * D
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        E, k = self.moe.n_experts, self.moe.top_k
        routed_all = self.moe.n_experts * 3 * self.d_model * self.moe.d_ff_expert
        routed_active = k * 3 * self.d_model * self.moe.d_ff_expert
        return self.param_count() - self.n_layers * (routed_all - routed_active)


def pattern_repeat(base: Sequence[str], n_layers: int) -> tuple[str, ...]:
    out: list[str] = []
    while len(out) < n_layers:
        out.extend(base)
    return tuple(out[:n_layers])
