"""Repo lint: AST rules for this codebase's recurring bug classes.

Generic linters don't know which of our modules must be deterministic or
device-free; these rules encode that repo-specific knowledge:

``RA001`` bare ``assert`` in library code.  ``python -O`` strips asserts —
          the tier-1 CI matrix runs ``-O`` precisely because a load-bearing
          assert once shipped (the PR 4 bug class).  Library invariants
          raise real exceptions; ``assert`` belongs in tests.
``RA002`` ``jax``/``jnp`` in a deterministic hot path.  The simulator,
          engine, rounds IR, and scheduler core are pure-Python by design
          (they must run identically with no accelerator present); a device
          op there is a silent 1000x slowdown and an import-time jax
          dependency.  Backend classes that legitimately touch devices are
          allow-listed per module.
``RA003`` wall-clock / nondeterminism in a deterministic component:
          ``time.time``-family, ``datetime.now``-family, the global
          ``random`` module, legacy ``np.random.*`` (seeded
          ``default_rng`` is fine), ``os.urandom``, ``uuid.uuid4``.  The
          simulation plane must be bit-reproducible; measurement modules
          (discovery, obs) are outside the deterministic set on purpose.
``RA004`` mutable default argument (``def f(x=[])``) — anywhere.

Suppress a true-but-intended finding by putting ``# lint: allow`` on the
flagged line.  :func:`lint_tree` walks ``src/repro`` (skipping nothing —
the repo ships lint-clean and CI keeps it that way).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable

__all__ = ["LintFinding", "lint_source", "lint_file", "lint_tree",
           "DETERMINISTIC_MODULES"]

_SUPPRESS = "lint: allow"

# Modules that must stay deterministic and device-free, keyed by path
# relative to the package root (``src/repro``).  The value is the set of
# class/function names INSIDE which jax/device use is allowed (the
# explicitly-device-facing backends living in an otherwise pure module).
DETERMINISTIC_MODULES: dict[str, tuple[str, ...]] = {
    "core/rounds.py": (),
    "core/trees.py": (),
    "core/schedule.py": (),
    "core/simulator.py": (),
    "core/engine.py": (),
    "core/topology.py": (),
    "core/costmodel.py": (),
    "core/communicator.py": ("PpermuteBackend", "JaxBackend"),
    "serving/scheduler.py": ("JaxExecutor",),
    "serving/kv_cache.py": (),
}

_DEVICE_ROOTS = ("jax", "jnp")

# dotted-call patterns that read clocks or unseeded entropy
_WALLCLOCK = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.sleep", "time.process_time",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
    "os.urandom", "uuid.uuid1", "uuid.uuid4",
}
_RANDOM_ROOTS = ("random",)          # the stdlib global-state module
_NP_RANDOM_OK = ("default_rng",)     # np.random.default_rng(seed) is seeded


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One lint violation at ``path:line``."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chain as a string, or None for anything fancier."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, relmod: str | None,
                 suppressed: set[int]):
        self.path = path
        self.suppressed = suppressed
        self.findings: list[LintFinding] = []
        # RA002/RA003 apply only inside the deterministic set
        self.det = relmod in DETERMINISTIC_MODULES
        self.allowed_scopes = (DETERMINISTIC_MODULES.get(relmod or "", ())
                               if self.det else ())
        self.scope: list[str] = []

    # -- helpers --------------------------------------------------------- #
    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if line in self.suppressed:
            return
        self.findings.append(LintFinding(rule, self.path, line, message))

    def _in_allowed_scope(self) -> bool:
        return any(s in self.allowed_scopes for s in self.scope)

    def _scoped(self, node) -> None:
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._scoped(node)

    # -- RA001: bare assert ---------------------------------------------- #
    def visit_Assert(self, node: ast.Assert) -> None:
        self._emit(
            "RA001", node,
            "bare assert in library code — stripped under python -O; "
            "raise a real exception (or add '# lint: allow')")
        self.generic_visit(node)

    # -- RA002: device ops in deterministic modules ---------------------- #
    def visit_Import(self, node: ast.Import) -> None:
        if self.det and not self._in_allowed_scope():
            for alias in node.names:
                root = alias.name.split(".")[0]
                if root in _DEVICE_ROOTS:
                    self._emit(
                        "RA002", node,
                        f"import of {alias.name!r} in a deterministic "
                        f"module — device code belongs in an allow-listed "
                        f"backend class")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if self.det and not self._in_allowed_scope() and node.module:
            if node.module.split(".")[0] in _DEVICE_ROOTS:
                self._emit(
                    "RA002", node,
                    f"import from {node.module!r} in a deterministic "
                    f"module — device code belongs in an allow-listed "
                    f"backend class")
        self.generic_visit(node)

    # -- RA003: wall clock / entropy in deterministic modules ------------ #
    def visit_Call(self, node: ast.Call) -> None:
        if self.det and not self._in_allowed_scope():
            name = _dotted(node.func)
            if name is not None:
                root = name.split(".")[0]
                if name in _WALLCLOCK:
                    self._emit(
                        "RA003", node,
                        f"{name}() in a deterministic module — the "
                        f"simulation plane must be reproducible; take "
                        f"time as a parameter")
                elif root in _RANDOM_ROOTS and "." in name:
                    self._emit(
                        "RA003", node,
                        f"{name}() uses global random state — use a "
                        f"seeded np.random.default_rng / random.Random")
                elif root in ("np", "numpy") and ".random." in f".{name}.":
                    leaf = name.split(".")[-1]
                    if leaf not in _NP_RANDOM_OK and name.split(".")[1] \
                            == "random" and len(name.split(".")) > 2:
                        self._emit(
                            "RA003", node,
                            f"legacy {name}() draws from global numpy "
                            f"state — use np.random.default_rng(seed)")
        self.generic_visit(node)

    # -- RA004: mutable default args (everywhere) ------------------------ #
    def _check_defaults(self, node) -> None:
        args = node.args
        for d in list(args.defaults) + [d for d in args.kw_defaults if d]:
            if isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(d, ast.Call)
                    and _dotted(d.func) in ("list", "dict", "set")):
                self._emit(
                    "RA004", d,
                    "mutable default argument — evaluated once at def "
                    "time and shared across calls; default to None")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_defaults(node)
        self._scoped(node)

    def visit_AsyncFunctionDef(self, node) -> None:
        self._check_defaults(node)
        self._scoped(node)


def _suppressed_lines(source: str) -> set[int]:
    return {i for i, line in enumerate(source.splitlines(), start=1)
            if _SUPPRESS in line}


def lint_source(source: str, path: str = "<string>",
                relmod: str | None = None) -> list[LintFinding]:
    """Lint one module's source.  ``relmod`` is its path relative to the
    package root (selects the deterministic-module rules); None applies
    only the everywhere-rules (RA001, RA004)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:  # a broken file IS a finding, not a crash
        return [LintFinding("RA000", path, e.lineno or 0,
                            f"syntax error: {e.msg}")]
    v = _Visitor(path, relmod, _suppressed_lines(source))
    v.visit(tree)
    return sorted(v.findings, key=lambda f: (f.path, f.line, f.rule))


def lint_file(path: str, root: str | None = None) -> list[LintFinding]:
    """Lint one file.  ``root`` is the package root used to derive the
    deterministic-module key (defaults to the enclosing ``repro`` dir if
    the path contains one)."""
    relmod = _relmod(path, root)
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path, relmod)


def _relmod(path: str, root: str | None) -> str | None:
    p = os.path.abspath(path).replace(os.sep, "/")
    if root is not None:
        r = os.path.abspath(root).replace(os.sep, "/")
        return p[len(r):].lstrip("/") if p.startswith(r) else None
    marker = "/repro/"
    i = p.rfind(marker)
    return p[i + len(marker):] if i >= 0 else None


def lint_tree(root: str) -> list[LintFinding]:
    """Lint every ``.py`` file under ``root`` (the package root, e.g.
    ``src/repro``)."""
    findings: list[LintFinding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                findings.extend(lint_file(os.path.join(dirpath, fn), root))
    return findings


def format_findings(findings: Iterable[LintFinding]) -> str:
    return "\n".join(str(f) for f in findings)
