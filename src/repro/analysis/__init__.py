"""Static analysis for the collective stack: plan verification, engine
hazard detection, and repo lint.

Three passes, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.verify` — machine-check any lowered collective
  program (semantics, byte conservation, DAG/FIFO feasibility, member
  closure).  Wired into :meth:`Communicator.verify_plans
  <repro.core.Communicator.verify_plans>` and the simulator's
  ``sanitize=True`` mode.
* :mod:`repro.analysis.hazards` — static wait-for analysis of an Engine's
  pending batch (deadlock cycles, foreign/dangling deps, interleaving
  races, starvation risk).  Wired into ``Engine(check=True)``.
* :mod:`repro.analysis.lint` — AST rules for this repo's recurring bug
  classes (bare asserts, device ops / wall-clock in deterministic modules,
  mutable defaults).  The CI gate runs ``python -m repro.analysis --all``.
"""
from .hazards import (Hazard, HazardError, HazardWarning, analyze_engine,
                      check_hazards)
from .lint import LintFinding, lint_file, lint_source, lint_tree
from .verify import (Finding, VerificationError, check_lowered, quick_check,
                     verify_lowered)

__all__ = [
    "Finding", "VerificationError", "verify_lowered", "check_lowered",
    "quick_check",
    "Hazard", "HazardError", "HazardWarning", "analyze_engine",
    "check_hazards",
    "LintFinding", "lint_source", "lint_file", "lint_tree",
]
