"""``python -m repro.analysis`` — the repo's static-analysis gate.

``--verify``   lower every registered op × algorithm × segmentation over the
               paper's fig8 grid and the 512-chip pod and machine-check each
               program; then warm a plan cache, kill ranks, and re-verify
               every spliced plan (``Communicator.verify_plans``).
``--hazards``  run the hazard analyzer over canned engine scenarios: the
               legitimate ones (bucketed gradient stream, ordered cross-set
               traffic, aged priority serving) must be hazard-free, and the
               seeded defects (an ``after=`` cycle, a foreign handle, an
               unaged priority pile-up) must each be caught.
``--lint``     lint ``src/repro`` with the repo rules (RA001-RA004).
``--all``      all three.  Exit status 1 on any finding — this is the CI
               contract.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import warnings

from ..core import rounds as R
from ..core.communicator import Communicator
from ..core.engine import Engine
from ..core.topology import paper_fig8_topology, tpu_v5e_multipod
from ..core.trees import PAPER_POLICY, build_multilevel_tree
from .hazards import HazardWarning, analyze_engine
from .lint import lint_tree
from .verify import verify_lowered

ALL_OPS = ("bcast", "reduce", "allreduce", "barrier",
           "gather", "scatter", "allgather")


def _matrix(topo, label: str, sizes) -> tuple[int, list[str]]:
    """Verify every lowering the planner can emit on ``topo``; returns
    (programs checked, failure messages)."""
    members = tuple(range(topo.nprocs))
    tree = build_multilevel_tree(topo, 0, members, PAPER_POLICY)
    checked, failures = 0, []

    def run(desc: str, fn):
        nonlocal checked
        try:
            low = fn()
        except ValueError:
            return  # algorithm rejects this shape (e.g. rsag non-uniform)
        findings = verify_lowered(low)
        checked += 1
        for f in findings:
            failures.append(f"{label} {desc}: {f}")

    for nbytes in sizes:
        for seg in (None, "bdp"):
            for op in ALL_OPS:
                run(f"{op}/tree nb={nbytes:g} seg={seg}",
                    lambda op=op, nb=nbytes, s=seg:
                    R.lower_tree(op, tree, topo, nb, s))
            run(f"bcast/sag nb={nbytes:g} seg={seg}",
                lambda nb=nbytes, s=seg:
                R.lower_sag_bcast(topo, 0, members, nb, s))
            run(f"allreduce/rsag nb={nbytes:g} seg={seg}",
                lambda nb=nbytes, s=seg:
                R.lower_rsag_allreduce(topo, members, nb, s))
    return checked, failures


def _post_repair(topo, label: str, failed, sizes) -> tuple[int, list[str]]:
    """Warm a plan cache, splice ranks out, and verify every surviving
    plan at every size it ever lowered."""
    comm = Communicator(topo, policy="auto")
    for op in ALL_OPS:
        for nb in sizes:
            comm.plan(op, nbytes=nb).lower(nb)
    try:
        comm.repair(failed)  # repair re-verifies automatically...
        n = comm.verify_plans()  # ...and the explicit call re-proves it
    except ValueError as e:
        return 0, [f"{label} post-repair: {e}"]
    return n, []


def cmd_verify() -> int:
    t0 = time.perf_counter()
    total, failures = 0, []
    fig8 = paper_fig8_topology()
    big = tpu_v5e_multipod()
    for topo, label, sizes, failed in (
            (fig8, "fig8", (float(1 << 20), float(1 << 24)), [3, 17, 40]),
            (big, "512-chip", (float(1 << 20),), [7, 100, 300, 511])):
        n, f = _matrix(topo, label, sizes)
        total += n
        failures += f
        n, f = _post_repair(topo, label, failed, sizes)
        total += n
        failures += f
    dt = time.perf_counter() - t0
    for msg in failures:
        print(f"VERIFY FAIL {msg}")
    print(f"# verify: {total} lowered programs checked, "
          f"{len(failures)} finding(s), {dt:.1f}s")
    return 1 if failures else 0


def _clean_scenarios(comm) -> list[str]:
    """Legitimate engine programs must analyze hazard-free."""
    failures = []
    # bucketed gradient stream: same member set -> implicit FIFO orders it
    eng = Engine(comm)
    hs = [eng.issue("allreduce", 1e6) for _ in range(6)]
    # cross-set traffic explicitly ordered behind the stream
    eng.issue("bcast", 1e5, members=comm.members[:8], after=[hs[-1]])
    for h in analyze_engine(eng):
        failures.append(f"clean bucketed stream flagged: {h}")
    eng.wait_all()
    # serve-like: aged priority, fat bcast under small gathers -> the
    # age_rate escape hatch means no starvation hazard
    eng = Engine(comm, policy="priority", age_rate=1e6)
    eng.issue("bcast", 1e8)
    for _ in range(5):
        eng.issue("gather", 1e4, after=[eng.issue("barrier")])
    for h in analyze_engine(eng):
        if h.severity == "error":
            failures.append(f"clean serving scenario flagged: {h}")
    eng.wait_all()
    return failures


def _seeded_scenarios(comm) -> list[str]:
    """Seeded defects the analyzer MUST catch."""
    failures = []
    # after= cycle (only constructible by post-issue mutation)
    eng = Engine(comm)
    a = eng.issue("bcast", 1e6, members=comm.members[:4])
    b = eng.issue("reduce", 1e6, members=comm.members[4:8], after=[a])
    a.after = (b,)
    hz = analyze_engine(eng)
    if not any(h.kind == "deadlock-cycle" for h in hz):
        failures.append("seeded after= cycle not flagged")
    eng._pending.clear()  # never execute the poisoned batch
    # unaged strict priority: a fat full-set bcast under a stream of small
    # high-priority subset ops sharing its links
    eng = Engine(comm, policy="priority")
    eng.issue("bcast", 1e8)
    for _ in range(4):
        eng.issue("barrier", members=comm.members[:8])
    if not any(h.kind == "starvation" for h in analyze_engine(eng)):
        failures.append("seeded starvation risk not flagged")
    eng.wait_all()
    return failures


def cmd_hazards() -> int:
    comm = Communicator(paper_fig8_topology(), policy="auto")
    failures = _clean_scenarios(comm) + _seeded_scenarios(comm)
    for msg in failures:
        print(f"HAZARDS FAIL {msg}")
    print(f"# hazards: {len(failures)} failure(s)")
    return 1 if failures else 0


def cmd_lint() -> int:
    # repro is a namespace package (no __init__.py): locate it by path
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings = lint_tree(root)
    for f in findings:
        print(f"LINT {f}")
    print(f"# lint: {len(findings)} finding(s) over {root}")
    return 1 if findings else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis gate: plan verifier, engine hazard "
                    "analyzer, repo lint")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--hazards", action="store_true")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)
    if args.all:
        args.verify = args.hazards = args.lint = True
    if not (args.verify or args.hazards or args.lint):
        ap.error("nothing to do: pass --verify, --hazards, --lint or --all")
    rc = 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", HazardWarning)
        if args.verify:
            rc |= cmd_verify()
        if args.hazards:
            rc |= cmd_hazards()
        if args.lint:
            rc |= cmd_lint()
    return rc


if __name__ == "__main__":
    sys.exit(main())
