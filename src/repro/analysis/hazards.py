"""The hazard analyzer: static wait-for analysis of an Engine's pending batch.

An :class:`~repro.core.engine.Engine` batch is a little concurrent program:
explicit ``after=`` edges, the implicit same-member-set FIFO rule (the MPI
same-communicator ordering), and link sharing between any two programs whose
member sets overlap.  The engine's simulator *executes* that program — and a
malformed batch surfaces there as a cryptic late error ("programs ... never
completed") or, on a real backend, as a hang.  This module analyzes the
batch BEFORE execution and reports precisely what is wrong:

``deadlock-cycle``    the wait-for graph (explicit ``after=`` + implicit
                      same-member-set FIFO) contains a cycle: the batch can
                      never complete anywhere (error)
``cross-engine-dep``  a handle's ``after=`` chain reaches a handle owned by
                      a different engine — ``issue()`` rejects these up
                      front, so one can only appear via post-issue mutation;
                      the foreign engine's clock is meaningless here (error)
``dangling-dep``      an ``after=`` dep that is neither resolved nor in this
                      engine's pending set — it can never flush, so the
                      waiter waits forever (error)
``interleaving-race`` two pending programs whose member sets OVERLAP but are
                      UNEQUAL, with no ordering path between them: the fluid
                      simulator resolves the contention deterministically,
                      but a real backend interleaves their sends
                      nondeterministically on the shared ranks' NICs
                      (warning)
``starvation``        strict ``priority`` policy with ``age_rate == 0`` and
                      a sustained stream of higher-priority work overlapping
                      a fat transfer's links: the fat transfer has no aging
                      escape and starves for the stream's lifetime (warning)

:func:`check_hazards` raises :class:`HazardError` on errors and emits
:class:`HazardWarning` for warnings; the engine runs it from ``issue()`` /
``wait_all()`` when constructed with ``check=True`` (errors at issue time,
the full analysis at flush time), and the test-suite always runs it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..core.engine import Engine

__all__ = ["Hazard", "HazardError", "HazardWarning",
           "analyze_engine", "check_hazards"]

# How many strictly-higher-priority overlapping handles constitute a
# "persistent stream" for the starvation heuristic.
_STARVE_STREAM = 3


@dataclasses.dataclass(frozen=True)
class Hazard:
    """One finding: ``kind`` (see module docstring), ``severity`` is
    ``"error"`` (cannot complete / meaningless schedule) or ``"warning"``
    (legal but nondeterministic or unfair), ``handles`` names the involved
    handle ids."""

    kind: str
    severity: str
    message: str
    handles: tuple[int, ...] = ()

    def __str__(self) -> str:
        hs = ",".join(f"#{h}" for h in self.handles)
        return f"[{self.kind}] ({self.severity}) {hs}: {self.message}"


class HazardError(RuntimeError):
    """The pending batch contains error-severity hazards (it would deadlock
    or reference a foreign/dangling handle).  ``hazards`` carries all of
    them."""

    def __init__(self, hazards):
        self.hazards = tuple(hazards)
        super().__init__(
            f"{len(self.hazards)} engine hazard(s): "
            + "; ".join(str(h) for h in self.hazards))


class HazardWarning(UserWarning):
    """Warning-severity hazard (nondeterministic interleaving, starvation
    risk) emitted by :func:`check_hazards`."""


def analyze_engine(engine: "Engine") -> list[Hazard]:
    """Analyze ``engine``'s pending handles; returns ALL hazards found.

    Pure read-only: nothing is flushed, no simulation runs.  Cost is
    O(pending² ) in the worst case (reachability for the race check), which
    is trivial at real batch sizes (tens of handles).
    """
    pending = list(engine._pending)
    out: list[Hazard] = []
    index = {h: i for i, h in enumerate(pending)}

    # --- wait-for edges: i waits on each of adj[i] ---------------------- #
    adj: list[list[int]] = [[] for _ in pending]
    for i, h in enumerate(pending):
        for d in h.after:
            if d.engine is not engine:
                out.append(Hazard(
                    "cross-engine-dep", "error",
                    f"handle #{h.hid} waits on #{d.hid} owned by a "
                    f"different engine — its clock and flush cycle are "
                    f"unrelated to this one", (h.hid, d.hid)))
            elif d.done:
                continue  # resolved: a release-time bound, not an edge
            elif d in index:
                adj[i].append(index[d])
            else:
                out.append(Hazard(
                    "dangling-dep", "error",
                    f"handle #{h.hid} waits on #{d.hid} which is neither "
                    f"resolved nor pending on this engine — it can never "
                    f"flush", (h.hid, d.hid)))
    # implicit same-member-set FIFO: each handle waits on its set's
    # predecessor (exactly what Engine._flush enforces via last_in_batch)
    last_of_set: dict[tuple[int, ...], int] = {}
    for i, h in enumerate(pending):
        prev = last_of_set.get(h.members)
        if prev is not None:
            adj[i].append(prev)
        last_of_set[h.members] = i

    cyc = _find_cycle(adj)
    if cyc is not None:
        hids = tuple(pending[i].hid for i in cyc)
        out.append(Hazard(
            "deadlock-cycle", "error",
            "wait-for cycle " + " -> ".join(f"#{h}" for h in hids)
            + " -> #" + str(hids[0]) + " over after= deps and same-member-"
            "set FIFO order — this batch can never complete",
            hids))
        return out  # reachability below is meaningless with a cycle

    # --- reachability (for the race check): ordered[i][j] = i,j ordered - #
    n = len(pending)
    reach = [set(a) for a in adj]
    for i in _topo_order(adj):
        for j in adj[i]:
            reach[i] |= reach[j]

    # --- interleaving races: overlapping unequal sets, no ordering ------ #
    for i in range(n):
        for j in range(i + 1, n):
            a, b = pending[i], pending[j]
            if a.members == b.members:
                continue  # implicit FIFO orders them
            if not set(a.members) & set(b.members):
                continue  # disjoint: no shared NIC, nothing to race on
            if j in reach[i] or i in reach[j]:
                continue  # explicitly ordered (possibly transitively)
            out.append(Hazard(
                "interleaving-race", "warning",
                f"#{a.hid} ({a.op}, {len(a.members)} ranks) and #{b.hid} "
                f"({b.op}, {len(b.members)} ranks) overlap on "
                f"{len(set(a.members) & set(b.members))} rank(s) with no "
                f"ordering edge — a real backend interleaves them "
                f"nondeterministically; add after= if order matters",
                (a.hid, b.hid)))

    # --- starvation: strict priority, no aging, persistent stream ------- #
    if engine.policy == "priority" and engine.age_rate == 0 and n > 1:
        prios = [h.priority if h.priority is not None else -h.nbytes
                 for h in pending]
        for i, h in enumerate(pending):
            ahead = [pending[j].hid for j in range(n)
                     if j != i and prios[j] > prios[i]
                     and set(pending[j].members) & set(h.members)]
            if len(ahead) >= _STARVE_STREAM:
                out.append(Hazard(
                    "starvation", "warning",
                    f"#{h.hid} ({h.op}, {h.nbytes:.0f}B, priority "
                    f"{prios[i]:.4g}) is outranked by {len(ahead)} "
                    f"overlapping higher-priority handles under strict "
                    f"priority with age_rate=0 — it has no aging escape; "
                    f"set age_rate > 0 to bound its wait",
                    (h.hid, *ahead[:4])))
    return out


def check_hazards(engine: "Engine", *, errors_only: bool = False) -> None:
    """Raise :class:`HazardError` if the pending batch has error-severity
    hazards; emit :class:`HazardWarning` for the rest unless
    ``errors_only`` (the cheap gate ``issue()`` uses — warnings about a
    half-built batch would be noise, the flush-time check sees the whole
    batch)."""
    hazards = analyze_engine(engine)
    errors = [h for h in hazards if h.severity == "error"]
    if errors:
        raise HazardError(errors)
    if not errors_only:
        for h in hazards:
            warnings.warn(str(h), HazardWarning, stacklevel=3)


# ---------------------------------------------------------------------- #
# Small graph helpers (duplicated from verify to keep the modules
# independently importable; both are ~20 lines).
# ---------------------------------------------------------------------- #

def _find_cycle(adj: list[list[int]]) -> list[int] | None:
    WHITE, GREY, BLACK = 0, 1, 2
    color = [WHITE] * len(adj)
    parent: dict[int, int] = {}
    for start in range(len(adj)):
        if color[start] != WHITE:
            continue
        stack = [(start, 0)]
        color[start] = GREY
        while stack:
            node, ptr = stack[-1]
            if ptr < len(adj[node]):
                stack[-1] = (node, ptr + 1)
                nxt = adj[node][ptr]
                if color[nxt] == GREY:
                    cycle = [nxt, node]
                    cur = node
                    while cur != nxt:
                        cur = parent[cur]
                        if cur != nxt:
                            cycle.append(cur)
                    cycle.reverse()
                    return cycle
                if color[nxt] == WHITE:
                    color[nxt] = GREY
                    parent[nxt] = node
                    stack.append((nxt, 0))
            else:
                color[node] = BLACK
                stack.pop()
    return None


def _topo_order(adj: list[list[int]]) -> list[int]:
    """Topological order of an ACYCLIC adjacency list such that every node
    appears after all nodes it points to (post-order DFS)."""
    seen = [False] * len(adj)
    order: list[int] = []
    for start in range(len(adj)):
        if seen[start]:
            continue
        stack = [(start, 0)]
        seen[start] = True
        while stack:
            node, ptr = stack[-1]
            if ptr < len(adj[node]):
                stack[-1] = (node, ptr + 1)
                nxt = adj[node][ptr]
                if not seen[nxt]:
                    seen[nxt] = True
                    stack.append((nxt, 0))
            else:
                order.append(node)
                stack.pop()
    return order
