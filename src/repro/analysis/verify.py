"""The plan verifier: machine-check any :class:`~repro.core.rounds.Lowered`
program before (or after) it touches the network.

The paper's trees are constructed *automatically at runtime*, and since the
elastic PRs this repo goes further: ``repair_tree`` splices cached plans in
place, ``refresh`` refits their costs, and the engine composes them into
concurrent programs.  ``rounds.check_semantics`` proves the op's final-state
contract for a lowering the tests happen to run — this module promotes that
interpreter into a full static checker that any plan, including a mutated or
composed one, must pass:

``no-self-send``      a rank never sends to itself
``segment-range``     seg/chunk ids and byte counts are in range
``member-closure``    no send touches a rank outside ``lowered.members``
``injection-order``   data deps point strictly backward — the contract the
                      linear-pass executor's single sweep relies on
``dependency-cycle``  the wait-for graph (data deps + per-rank FIFO NIC
                      order) is acyclic — a cycle is a guaranteed hang
``byte-conservation`` every send carries exactly its segment's bytes and
                      every receiver's wire bytes equal the distinct payload
                      cells it is owed (sum of seg bytes == nbytes)
``semantics``         exactly-once delivery, fold-once, and the op's
                      final-holdings contract (:func:`rounds.check_semantics`,
                      which also checks the personalised chunk-routing paths)

Each pass returns :class:`Finding`\\ s instead of raising, so callers can
collect everything wrong with a program in one sweep; :func:`check_lowered`
raises :class:`VerificationError` carrying the full list.  :func:`quick_check`
is the cheap structural subset (no symbolic interpretation) behind the
simulator's ``sanitize=True`` runtime mode.

:meth:`Communicator.verify_plans <repro.core.Communicator.verify_plans>`
runs :func:`check_lowered` over every cached plan and is invoked
automatically after ``repair()`` / ``refresh()`` — every in-place splice is
re-proven before it can serve traffic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from ..core import rounds as R

__all__ = [
    "Finding",
    "VerificationError",
    "verify_lowered",
    "check_lowered",
    "quick_check",
    "structural_findings",
    "member_findings",
    "dag_findings",
    "conservation_findings",
    "semantic_findings",
]

_REL_TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier violation: which ``rule`` fired, ``where`` in the
    program (send index / rank / cell), and a human-readable message."""

    rule: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.where}: {self.message}"


class VerificationError(ValueError):
    """A lowered program failed verification.  ``findings`` carries every
    violation the passes collected (not just the first)."""

    def __init__(self, findings: Iterable[Finding], context: str = ""):
        self.findings = tuple(findings)
        self.context = context
        head = f"{context}: " if context else ""
        body = "; ".join(str(f) for f in self.findings[:8])
        more = (f" (+{len(self.findings) - 8} more)"
                if len(self.findings) > 8 else "")
        super().__init__(
            f"{head}{len(self.findings)} verification finding(s): "
            f"{body}{more}")


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL_TOL, abs_tol=1e-12)


# ---------------------------------------------------------------------- #
# Pass 1: per-send structure.
# ---------------------------------------------------------------------- #

def structural_findings(low: R.Lowered) -> list[Finding]:
    """Per-send invariants: no self-sends, legal kinds, seg ids in range,
    non-negative byte counts that match the segment contract (a seg=k send
    carries ``chunk_bytes / nsegs``, a seg=None send the whole chunk)."""
    out: list[Finding] = []
    piece = low.chunk_bytes / low.nsegs
    for i, snd in enumerate(low.sends):
        where = f"send #{i} {snd.src}->{snd.dst}"
        if snd.src == snd.dst:
            out.append(Finding("no-self-send", where,
                               "a rank must not send to itself"))
        if snd.kind not in ("copy", "reduce"):
            out.append(Finding("segment-range", where,
                               f"unknown send kind {snd.kind!r}"))
        if snd.seg is not None and not 0 <= snd.seg < low.nsegs:
            out.append(Finding(
                "segment-range", where,
                f"seg {snd.seg} outside [0, {low.nsegs})"))
        if snd.nbytes < 0:
            out.append(Finding("byte-conservation", where,
                               f"negative byte count {snd.nbytes}"))
        elif low.chunk_bytes > 0:
            want = low.chunk_bytes if snd.seg is None else piece
            if not _close(snd.nbytes, want):
                out.append(Finding(
                    "byte-conservation", where,
                    f"carries {snd.nbytes:.6g} B, segment contract says "
                    f"{want:.6g} B (chunk {low.chunk_bytes:.6g} / "
                    f"{'whole' if snd.seg is None else low.nsegs})"))
    return out


# ---------------------------------------------------------------------- #
# Pass 2: member closure.
# ---------------------------------------------------------------------- #

def member_findings(low: R.Lowered) -> list[Finding]:
    """No send may touch a rank outside ``lowered.members`` (the defect a
    splice-to-dead-rank bug injects), the root must be a member, and chunk
    ids must be legal — member ranks for personalised ops, ``[0, nchunks)``
    contiguous blocks otherwise."""
    members = set(low.members)
    personalised = low.op in ("gather", "scatter", "allgather")
    out: list[Finding] = []
    if low.root not in members:
        out.append(Finding("member-closure", f"root {low.root}",
                           "root is not a member of the program"))
    for i, snd in enumerate(low.sends):
        where = f"send #{i} {snd.src}->{snd.dst}"
        for role, r in (("src", snd.src), ("dst", snd.dst)):
            if r not in members:
                out.append(Finding(
                    "member-closure", where,
                    f"{role} rank {r} is not a member "
                    f"(|members|={len(members)})"))
        if personalised:
            if snd.chunk not in members:
                out.append(Finding(
                    "member-closure", where,
                    f"chunk {snd.chunk} is not a member rank "
                    f"(personalised op {low.op})"))
        elif not 0 <= snd.chunk < low.nchunks:
            out.append(Finding(
                "segment-range", where,
                f"chunk {snd.chunk} outside [0, {low.nchunks})"))
    return out


# ---------------------------------------------------------------------- #
# Pass 3: dependency DAG + per-rank FIFO injection feasibility.
# ---------------------------------------------------------------------- #

def dag_findings(low: R.Lowered) -> list[Finding]:
    """Two related guarantees:

    * ``injection-order`` — every data dep points strictly backward in the
      program.  The linear-pass executor resolves ``delivered[d]`` in one
      sweep, so a forward dep is unexecutable there even when the general
      graph is acyclic.
    * ``dependency-cycle`` — the full wait-for graph (data deps plus the
      implicit per-rank FIFO NIC edges between a rank's consecutive sends)
      is acyclic.  A cycle deadlocks *any* executor.
    """
    n = len(low.sends)
    out: list[Finding] = []
    for i, snd in enumerate(low.sends):
        for d in snd.deps:
            if not 0 <= d < n:
                out.append(Finding(
                    "dependency-cycle", f"send #{i}",
                    f"dep index {d} outside the program [0, {n})"))
            elif d >= i:
                out.append(Finding(
                    "injection-order", f"send #{i}",
                    f"depends on send #{d} which is emitted later — the "
                    f"linear injection pass cannot execute this"))
    # wait-for graph: i -> its data deps, plus i -> rank's previous send
    waits: list[list[int]] = []
    last_of_src: dict[int, int] = {}
    for i, snd in enumerate(low.sends):
        ws = [d for d in snd.deps if 0 <= d < n]
        prev = last_of_src.get(snd.src)
        if prev is not None:
            ws.append(prev)
        last_of_src[snd.src] = i
        waits.append(ws)
    cyc = _find_cycle(waits)
    if cyc is not None:
        out.append(Finding(
            "dependency-cycle",
            " -> ".join(f"#{k}" for k in cyc),
            "wait-for cycle over data deps + per-rank FIFO order — this "
            "program can never complete"))
    return out


def _find_cycle(adj: list[list[int]]) -> list[int] | None:
    """Iterative DFS cycle detection; returns one cycle's node list."""
    WHITE, GREY, BLACK = 0, 1, 2
    color = [WHITE] * len(adj)
    parent: dict[int, int] = {}
    for start in range(len(adj)):
        if color[start] != WHITE:
            continue
        stack: list[tuple[int, int]] = [(start, 0)]
        color[start] = GREY
        while stack:
            node, ptr = stack[-1]
            if ptr < len(adj[node]):
                stack[-1] = (node, ptr + 1)
                nxt = adj[node][ptr]
                if color[nxt] == GREY:  # back edge: walk the cycle out
                    cycle = [nxt, node]
                    cur = node
                    while cur != nxt:
                        cur = parent[cur]
                        if cur != nxt:
                            cycle.append(cur)
                    cycle.reverse()
                    return cycle
                if color[nxt] == WHITE:
                    color[nxt] = GREY
                    parent[nxt] = node
                    stack.append((nxt, 0))
            else:
                color[node] = BLACK
                stack.pop()
    return None


# ---------------------------------------------------------------------- #
# Pass 4: byte conservation per receiver.
# ---------------------------------------------------------------------- #

def conservation_findings(low: R.Lowered) -> list[Finding]:
    """Every (receiver, chunk, seg) payload cell that copy-sends target must
    accumulate EXACTLY its piece of the payload — ``sum(seg bytes) ==
    chunk_bytes`` per delivered chunk, so a half-sized or double-counted
    wire message cannot hide behind a symbolically correct delivery (the
    interpreter tracks *which* contributions move, not how many bytes)."""
    if low.chunk_bytes <= 0:
        return []  # barrier-class programs ship no payload
    piece = low.chunk_bytes / low.nsegs
    got: dict[tuple[int, int, int], float] = {}
    for snd in low.sends:
        if snd.kind != "copy":
            continue
        segs = range(low.nsegs) if snd.seg is None else (snd.seg,)
        per_seg = (snd.nbytes / low.nsegs if snd.seg is None
                   else snd.nbytes)
        for k in segs:
            if snd.seg is not None and not 0 <= k < low.nsegs:
                continue  # structural pass already reported the range
            cell = (snd.dst, snd.chunk, k)
            got[cell] = got.get(cell, 0.0) + per_seg
    out: list[Finding] = []
    for (dst, chunk, k), nb in sorted(got.items()):
        if not _close(nb, piece):
            out.append(Finding(
                "byte-conservation",
                f"rank {dst} chunk {chunk} seg {k}",
                f"received {nb:.6g} B of a {piece:.6g} B segment "
                f"({'under' if nb < piece else 'over'}-delivered)"))
    return out


# ---------------------------------------------------------------------- #
# Pass 5: executable semantics.
# ---------------------------------------------------------------------- #

def semantic_findings(low: R.Lowered) -> list[Finding]:
    """Run the symbolic interpreter and the op's final-state contract
    (exactly-once delivery, fold-once, full holdings, personalised
    chunk-routing paths).  The interpreter raises on the FIRST violation,
    so one finding at most — but it is the deepest pass and catches what
    the structural ones cannot (a dropped send shows up only here)."""
    try:
        R.check_semantics(low)
    except (ValueError, KeyError) as e:
        return [Finding("semantics", f"{low.op}/{low.algorithm}", str(e))]
    return []


# ---------------------------------------------------------------------- #
# Entry points.
# ---------------------------------------------------------------------- #

def verify_lowered(low: R.Lowered) -> list[Finding]:
    """Run every pass over one lowered program; returns ALL findings.

    The structural passes always run; the symbolic pass is skipped when
    structure is already broken badly enough that interpretation would
    throw spurious errors (out-of-range deps / unknown kinds)."""
    out = structural_findings(low)
    out += member_findings(low)
    out += dag_findings(low)
    out += conservation_findings(low)
    blocking = {"dependency-cycle", "injection-order", "segment-range"}
    if not any(f.rule in blocking for f in out):
        out += semantic_findings(low)
    return out


def check_lowered(low: R.Lowered, context: str = "") -> None:
    """Raise :class:`VerificationError` (with all findings) unless ``low``
    verifies clean."""
    findings = verify_lowered(low)
    if findings:
        ctx = context or f"{low.op}/{low.algorithm} over " \
                         f"{len(low.members)} ranks"
        raise VerificationError(findings, ctx)


def quick_check(low: R.Lowered, context: str = "") -> None:
    """The cheap structural subset (no symbolic interpretation): per-send
    structure, member closure, dependency order.  This is the simulator's
    ``sanitize=True`` runtime gate — O(sends) with a small constant, and
    memoised per program object by the caller."""
    findings = structural_findings(low)
    findings += member_findings(low)
    findings += dag_findings(low)
    if findings:
        ctx = context or f"{low.op}/{low.algorithm} over " \
                         f"{len(low.members)} ranks"
        raise VerificationError(findings, ctx)
