"""Quickstart: the paper's multilevel topology-aware collectives in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import schedule as S
from repro.core.simulator import simulate
from repro.core.topology import paper_fig8_topology, magpie_site_view
from repro.core.trees import (binomial_tree, build_multilevel_tree,
                              PAPER_POLICY, adaptive_policy)

# 1. Describe the network as integer coordinate vectors (site, machine) —
#    here: the paper's own testbed, 16 procs on each of 3 machines, 2 sites.
topo = paper_fig8_topology()
print(topo)

# 2. Build broadcast trees rooted at rank 0 under different views.
oblivious = binomial_tree(0, range(topo.nprocs))          # MPICH default
two_level = build_multilevel_tree(magpie_site_view(topo), 0)   # MagPIe
multilevel = build_multilevel_tree(topo, 0, policy=PAPER_POLICY)  # the paper

# The multilevel tree crosses the WAN exactly once:
wan_edges = [(p, c) for p, cs in multilevel.children.items() for c in cs
             if topo.comm_level(p, c) == 0]
print(f"multilevel tree: {len(wan_edges)} WAN edge(s)  "
      f"(root's first child is across the WAN: {multilevel.children[0][0]})")

# 3. Simulate a 256 KB broadcast on the postal model.
for name, tree in [("mpich-binomial", oblivious),
                   ("magpie-site", two_level),
                   ("multilevel", multilevel)]:
    t = max(simulate(S.bcast(tree, 256e3), topo).values())
    print(f"{name:16s} bcast 256KB: {t*1e3:8.2f} ms")

# 4. Beyond the paper: per-level tree-shape selection (its §6 future work).
adaptive = build_multilevel_tree(topo, 0, policy=adaptive_policy(topo, 256e3))
t = max(simulate(S.bcast(adaptive, 256e3), topo).values())
print(f"{'adaptive':16s} bcast 256KB: {t*1e3:8.2f} ms")

# 5. All five paper collectives work over any tree:
for op in (S.reduce, S.gather, S.scatter):
    t = max(simulate(op(multilevel, 64e3), topo).values())
    print(f"{op.__name__:16s} 64KB multilevel: {t*1e3:8.2f} ms")
t = max(simulate(S.barrier(multilevel), topo).values())
print(f"{'barrier':16s} multilevel: {t*1e3:8.2f} ms")
