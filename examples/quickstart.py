"""Quickstart: the paper's multilevel topology-aware collectives behind the
one public entry point, :class:`repro.core.Communicator`.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Communicator, paper_fig8_topology
from repro.core.topology import magpie_site_view

# 1. Describe the network as integer coordinate vectors (site, machine) —
#    here: the paper's own testbed, 16 procs on each of 3 machines, 2 sites.
topo = paper_fig8_topology()
print(topo)

# 2. One communicator per tree-selection policy.  Baselines build their
#    trees against a reduced *view* of the network; the simulator still
#    charges true per-edge costs.
comms = {
    "mpich-binomial": Communicator(topo, policy="oblivious"),      # MPICH
    "magpie-site": Communicator(topo, policy="paper",
                                view=magpie_site_view(topo)),      # MagPIe
    "multilevel": Communicator(topo, policy="paper"),              # the paper
}

# The multilevel plan crosses the WAN exactly once (paper Fig. 4):
ml = comms["multilevel"]
print(f"multilevel bcast plan: {ml.slow_crossings('bcast', nbytes=256e3)} "
      f"WAN edge(s); root serves its WAN child first: "
      f"{ml.plan('bcast', root=0, nbytes=256e3).tree.children[0][0]}")

# 3. Simulate a 256 KB broadcast on the postal model.
for name, comm in comms.items():
    print(f"{name:16s} bcast 256KB: {comm.bcast(256e3, root=0).time*1e3:8.2f} ms")

# 4. Beyond the paper: per-level tree-shape selection (its §6 future work),
#    and the cost-model argmin over all candidates ("auto").
for policy in ("adaptive", "auto"):
    comm = Communicator(topo, policy=policy)
    print(f"{policy:16s} bcast 256KB: {comm.bcast(256e3, root=0).time*1e3:8.2f} ms")

# 5. All seven collectives go through the same object:
for op in ("reduce", "gather", "scatter", "allreduce", "allgather"):
    t = getattr(ml, op)(64e3, root=0).time if op in ("reduce", "gather", "scatter") \
        else getattr(ml, op)(64e3).time
    print(f"{op:16s} 64KB multilevel: {t*1e3:8.2f} ms")
print(f"{'barrier':16s} multilevel: {ml.barrier().time*1e3:8.2f} ms")

# 6. Plans are cached — the second identical call rebuilds nothing:
before = ml.cache_info()
ml.bcast(256e3, root=0)
after = ml.cache_info()
print(f"plan cache: +{after.hits - before.hits} hit, "
      f"tree builds unchanged: {after.tree_builds == before.tree_builds}")

# 7. Large messages: plans LOWER to a segmented rounds IR, and the "auto"
#    argmin also searches bandwidth-optimal algorithms (scatter-allgather
#    bcast, reduce-scatter+allgather allreduce) — pipelined chunks cross
#    the WAN on parallel pair links instead of one saturated edge.
auto = Communicator(topo, policy="auto")
N = 64 * 2**20  # 64 MiB
plan = auto.plan("bcast", root=0, nbytes=N)
low = plan.lower(N)
print(f"64 MiB bcast plan: algorithm={plan.algorithm}, "
      f"{low.nchunks} chunks x {low.nsegs} segments, "
      f"{len(low.sends)} sends")
print(f"  unsegmented multilevel: {ml.bcast(N, root=0).time:8.2f} s")
print(f"  segmented auto plan:    {auto.bcast(N, root=0).time:8.2f} s")

# 8. The async engine: nonblocking handles, contention-aware concurrent
#    scheduling, and the bucketed OVERLAPPED gradient sync — all-reduce of
#    layer k rides under the backward compute of the layers below it.
from repro.core import Engine
from repro.core.engine import overlapped_step_times

L = 12
layer_bytes = [N / L] * L
t_comm = auto.allreduce(N).time
ov = overlapped_step_times(auto, layer_bytes, [t_comm / L] * L,
                           bucket_bytes=8 * 2**20)
print(f"64 MiB gradient sync, {ov['n_buckets']} buckets: "
      f"serial {ov['serial_s']:.2f} s -> overlapped {ov['overlapped_s']:.2f} s "
      f"({ov['speedup']:.2f}x)")

eng = Engine(auto, policy="priority")
fat = eng.issue("bcast", N, root=0)              # fat weight broadcast...
ping = eng.issue("allreduce", 8e3,               # ...small op on site 0
                 members=tuple(range(16)))       #    jumps it (different
eng.wait_all()                                   #    member set: legal)
print(f"engine: small allreduce done at {ping.finished*1e3:.2f} ms while "
      f"the fat bcast runs until {fat.finished:.2f} s "
      f"(plans reused: {auto.stats().hits} cache hits)")

# 9. Serving: continuous batching on a paged KV cache, the engine pricing
#    each step's decode gathers against the periodic weight broadcast.
#    Open-loop Poisson arrivals; the "slo" policy admits by earliest TTFT
#    deadline and sheds requests whose deadline already passed.
from repro.serving import (Scheduler, SimExecutor, SLO, make_requests,
                           poisson_arrivals, default_compute_model)

arrivals = poisson_arrivals(rate=60.0, horizon_s=2.0, seed=0)
requests = make_requests(arrivals, vocab=512, prompt_len=(16, 48),
                         gen_len=(8, 24), slo=SLO(ttft_s=0.3, tpot_s=0.05))
sch = Scheduler(
    SimExecutor(block_size=16), n_blocks=1 + 8 * 16, block_size=16,
    max_slots=8, s_max=256, policy="slo", prefill_token_budget=256,
    compute_model=default_compute_model(1e9, flops_per_s=2e12),
    engine=Engine(auto, policy="priority", age_rate=N),
    replicas=[tuple(range(g * 16, (g + 1) * 16)) for g in range(3)],
    weight_bytes=N, gather_bytes=4096.0, bcast_every=64)
rep = sch.run(requests)
s = rep.summary()
print(f"serving: {s['n_done']}/{s['n_requests']} served "
      f"({s['n_shed']} shed) at {s['throughput_tok_s']:.0f} tok/s, "
      f"p99 TTFT {s['ttft_p99_s']*1e3:.0f} ms, "
      f"max {rep.max_concurrent} concurrent (paged KV, "
      f"{sch.alloc.capacity} blocks)")
