"""Batched serving example: prefill a batch of prompts, decode greedily with
a sharded KV cache (batch over `data`, cache sequence over `model`).

Run: XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
     PYTHONPATH=src python examples/serve_batch.py [--arch qwen3-4b]
(arch configs run in reduced/smoke form on CPU)
"""
import argparse

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh", default="1x2x2")
    args = ap.parse_args()
    out = serve(args.arch, args.requests, args.prompt_len, args.gen_len,
                mesh_spec=args.mesh)
    print(f"[serve] {args.arch}: {out['generated'].shape[0]} requests x "
          f"{out['generated'].shape[1]} tokens in {out['seconds']:.2f}s "
          f"({out['tokens_per_s']:.1f} tok/s)")
    for i, row in enumerate(out["generated"][:2]):
        print(f"  req{i}: {row[:12].tolist()} ...")


if __name__ == "__main__":
    main()
