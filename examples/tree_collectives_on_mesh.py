"""The paper's explicit trees executed ON DEVICES with lax.ppermute rounds.

Shows the faithful §3.2 port: every host deterministically constructs the
same multilevel tree from the mesh's coordinate table, then one
collective-permute per tree round moves the data — one DCN crossing total.

Run: XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
     PYTHONPATH=src python examples/tree_collectives_on_mesh.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import tree_exec
from repro.core.topology import tpu_v5e_multipod
from repro.core.trees import build_multilevel_tree

# A 2-pod, 2-board-per-pod, 2-chip-per-board fleet (8 devices emulated).
topo = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
tree = build_multilevel_tree(topo, root=3)
print("tree rounds (src,dst per collective-permute):")
for r, edges in enumerate(tree_exec.tree_rounds(tree)):
    lv = [topo.levels[topo.comm_level(s, d)].name for s, d in edges]
    print(f"  round {r}: {edges}  links={lv}")

mesh = jax.make_mesh((8,), ("all",))
x = jnp.arange(8.0)

bcast = jax.jit(shard_map(lambda v: tree_exec.tree_bcast(v, tree, "all"),
                          mesh=mesh, in_specs=P("all"), out_specs=P("all")))
print("bcast from rank 3:", np.asarray(bcast(x)))

def reduce_to_root(v):
    r = tree_exec.tree_reduce(v, tree, "all")
    return jnp.where(jax.lax.axis_index("all") == tree.root, r, 0.0)

red = jax.jit(shard_map(reduce_to_root, mesh=mesh,
                        in_specs=P("all"), out_specs=P("all")))
print("reduce to rank 3:", np.asarray(red(x)), "(expect 28 at index 3)")

# Count DCN crossings in the schedule — the paper's metric.
dcn = sum(1 for edges in tree_exec.tree_rounds(tree)
          for s, d in edges if topo.comm_level(s, d) == 0)
print(f"DCN crossings in the whole broadcast: {dcn} (binomial would use "
      f">= {int(np.ceil(np.log2(2)))} per pod pair, interleaved deep)")
