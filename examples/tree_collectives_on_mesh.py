"""The paper's explicit trees executed ON DEVICES through the
``backend="ppermute"`` Communicator: one ``lax.ppermute`` per tree round.

Shows the faithful §3.2 port: every host deterministically constructs the
same multilevel tree from the mesh's coordinate table, then one
collective-permute per tree round moves the data — one DCN crossing total.

Run: XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
     PYTHONPATH=src python examples/tree_collectives_on_mesh.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import Communicator
from repro.core.topology import tpu_v5e_multipod

# A 2-pod, 2-board-per-pod, 2-chip-per-board fleet (8 devices emulated).
topo = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
comm = Communicator(topo, policy="paper", backend="ppermute", axis="all")

plan = comm.plan("bcast", root=3)
print("tree rounds (src,dst per collective-permute):")
for r, edges in enumerate(plan.rounds):
    lv = [topo.levels[topo.comm_level(s, d)].name for s, d in edges]
    print(f"  round {r}: {edges}  links={lv}")

mesh = jax.make_mesh((8,), ("all",))
x = jnp.arange(8.0)

bcast = jax.jit(shard_map(lambda v: comm.bcast(v, root=3),
                          mesh=mesh, in_specs=P("all"), out_specs=P("all")))
print("bcast from rank 3:", np.asarray(bcast(x)))

red = jax.jit(shard_map(lambda v: comm.reduce(v, root=3), mesh=mesh,
                        in_specs=P("all"), out_specs=P("all")))
print("reduce to rank 3:", np.asarray(red(x)), "(expect 28 at index 3)")

allred = jax.jit(shard_map(lambda v: comm.allreduce(v), mesh=mesh,
                           in_specs=P("all"), out_specs=P("all")))
print("allreduce:", np.asarray(allred(x)), "(expect 28 everywhere)")

# Count DCN crossings in the schedule — the paper's metric.  The plan is
# cached: these reads re-run zero tree constructions.
dcn = sum(1 for edges in plan.rounds
          for s, d in edges if topo.comm_level(s, d) == 0)
print(f"DCN crossings in the whole broadcast: {dcn}")
print(f"plan cache: {comm.cache_info()}")
