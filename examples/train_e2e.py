"""End-to-end distributed training: ~100M-param LM, a few hundred steps,
multilevel gradient collectives + ZeRO-1 + checkpointing + a mid-run pod
failure with elastic recovery.

Run (CPU, 8 emulated devices, ~10 min):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--full]

``--full`` uses the real gpt-100m config (slower on CPU); the default uses
the reduced config so CI finishes quickly — the distributed machinery
exercised is identical.
"""
import argparse
import tempfile

import jax

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--comm", default="multilevel_compress",
                    choices=["flat", "multilevel", "multilevel_compress"])
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if n_dev < 8:
        print(f"[e2e] WARNING: only {n_dev} device(s); "
              "set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        mesh = "1x1x1" if n_dev == 1 else "1x2x2"
    else:
        mesh = "2x2x2"

    with tempfile.TemporaryDirectory() as ckpt:
        out = train(
            arch="gpt-100m",
            steps=args.steps,
            mesh_spec=mesh,
            seq=128,
            batch=8,
            comm=args.comm,
            zero1=True,
            ckpt_dir=ckpt,
            ckpt_every=50,
            # inject a pod failure at step 60% through: the driver shrinks
            # the mesh, restores the last checkpoint, raises accumulation
            fail_at={int(args.steps * 0.6): [1]} if mesh == "2x2x2" else None,
            smoke=not args.full,
            log_every=20,
        )
    first, last = out["losses"][0], out["final_loss"]
    print(f"\n[e2e] loss {first:.3f} -> {last:.3f} over {args.steps} steps, "
          f"{out['recoveries']} elastic recoveries, "
          f"{out['stragglers']} straggler drops")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
