"""Data pipeline, optimizer, checkpoint, fault-tolerance unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataPipeline
from repro.optim import adamw
from repro.runtime.fault_tolerance import (HeartbeatTracker, plan_recovery,
                                           StragglerMonitor)


# ------------------------------ data ---------------------------------- #

def test_pipeline_deterministic_and_sharded():
    cfg = get_config("gpt_100m", smoke=True)
    shape = ShapeSpec("t", "train", 32, 8)
    full = DataPipeline(cfg, shape).host_batch(5)
    again = DataPipeline(cfg, shape).host_batch(5)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    # two hosts partition the global batch exactly
    h0 = DataPipeline(cfg, shape, host_id=0, num_hosts=2).host_batch(5)
    h1 = DataPipeline(cfg, shape, host_id=1, num_hosts=2).host_batch(5)
    np.testing.assert_array_equal(np.concatenate([h0["tokens"], h1["tokens"]]),
                                  full["tokens"])
    assert (full["tokens"] != DataPipeline(cfg, shape).host_batch(6)["tokens"]).any()
    assert full["labels"].min() >= 0 and full["tokens"].max() < cfg.vocab


def test_pipeline_modalities():
    for arch in ["pixtral_12b", "seamless_m4t_medium"]:
        cfg = get_config(arch, smoke=True)
        b = DataPipeline(cfg, ShapeSpec("t", "train", 32, 4)).host_batch(0)
        key = "embeds" if cfg.frontend == "vision" else "src_embeds"
        assert b[key].ndim == 3 and np.isfinite(b[key]).all()
        assert b["tokens"].shape == b["labels"].shape


# ------------------------------ optim --------------------------------- #

@settings(deadline=None, max_examples=40)
@given(st.lists(st.tuples(st.integers(1, 64), st.integers(1, 64)),
                min_size=1, max_size=5),
       st.integers(2, 8))
def test_scatter_axes_property(shapes, n):
    """Picked axis always divides by n; None only when no axis divides."""
    leaves = {f"w{i}": jnp.zeros(s) for i, s in enumerate(shapes)}
    axes = adamw.scatter_axes(leaves, n)
    for name, leaf in leaves.items():
        ax = axes[name]
        if ax is None:
            assert all(d % n for d in leaf.shape)
        else:
            assert leaf.shape[ax] % n == 0


def test_adamw_math_matches_reference():
    cfg = adamw.OptConfig(lr=1e-2, betas=(0.9, 0.99), eps=1e-8,
                          weight_decay=0.0)
    m = jnp.zeros((4,)); v = jnp.zeros((4,))
    g = jnp.array([1.0, -2.0, 0.5, 0.0])
    w = jnp.ones((4,))
    m1, v1, w1 = adamw._adamw_math(m, v, g, w, cfg, jnp.float32(1e-2), 1)
    # step 1 closed form: mhat = g, vhat = g^2 -> update = sign(g)-ish
    expect = w - 1e-2 * g / (jnp.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_lr_schedule():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(adamw.lr_at(cfg, jnp.int32(0))) == pytest.approx(0.1)
    assert float(adamw.lr_at(cfg, jnp.int32(9))) == pytest.approx(1.0)
    assert float(adamw.lr_at(cfg, jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)


# ---------------------------- checkpoint ------------------------------ #

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"params": {"w": np.arange(6.0).reshape(2, 3)},
             "step": np.int32(7)}
    for s in [1, 2, 3]:
        mgr.save(s, state)
    assert mgr.list_steps() == [2, 3]  # gc keeps last 2
    out = mgr.restore(3, state)
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])


def test_checkpoint_atomic_commit(tmp_path):
    """A leftover .tmp dir (simulated crash) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = {"w": np.ones((3,))}
    mgr.save(1, state)
    os.makedirs(tmp_path / "step_000000002.tmp")  # crashed write
    assert mgr.latest_step() == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(5, {"w": np.zeros((10,))})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"w": np.ones((3,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(1, {"w": np.ones((4,))})


# --------------------------- fault tolerance -------------------------- #

def test_heartbeat_detector():
    clock = [0.0]
    hb = HeartbeatTracker(["h0", "h1"], timeout_s=10, clock=lambda: clock[0])
    clock[0] = 5.0
    hb.ping("h0")
    clock[0] = 12.0
    assert hb.dead_hosts() == ["h1"]


def test_plan_recovery_shrinks_pod_axis():
    plan = plan_recovery((4, 16, 16), ("pod", "data", "model"), [2])
    assert plan.new_shape == (3, 16, 16)
    assert plan.accum_factor == 1  # 4//3 -> 1 (batch mostly preserved)
    plan = plan_recovery((2, 16, 16), ("pod", "data", "model"), [0])
    assert plan.new_shape == (1, 16, 16)
    assert plan.accum_factor == 2  # halve dp -> double accumulation
    assert plan.changed


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(10):
        assert not mon.observe(i, 1.0)
    assert mon.observe(10, 10.0)
    assert mon.dropped_steps == [10]


# ----------------------- e2e fault tolerance (subprocess) ------------- #

def test_train_restart_with_failure_injection(subproc, tmp_path):
    subproc(f"""
from repro.launch.train import train
out = train("gpt-100m", steps=10, mesh_spec="2x2x1", seq=32, batch=4,
            comm="multilevel", zero1=True, ckpt_dir=r"{tmp_path}",
            ckpt_every=4, fail_at={{7: [1]}}, smoke=True, log_every=100)
assert out["recoveries"] == 1, out
assert out["final_loss"] is not None and out["final_loss"] < 8.0
import numpy as np
assert np.isfinite(out["losses"]).all()
print("OK recoveries:", out["recoveries"])
""", n_devices=4, timeout=1500)


def test_plan_expansion_inverse_of_recovery():
    from repro.runtime.fault_tolerance import plan_expansion, plan_recovery
    shrunk = plan_recovery((2, 16, 16), ("pod", "data", "model"), [1])
    assert shrunk.new_shape == (1, 16, 16) and shrunk.accum_factor == 2
    grown = plan_expansion(shrunk.new_shape, ("pod", "data", "model"), 2)
    assert grown.new_shape == (2, 16, 16)
    assert grown.accum_factor == 1 and grown.changed
