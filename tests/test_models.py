"""Per-architecture smoke tests + decode/prefill equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.enc_dec:
        return {"src_embeds": jax.random.normal(KEY, (B, 8, cfg.d_model),
                                                jnp.bfloat16),
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        return {"embeds": jax.random.normal(KEY, (B, 4, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jnp.ones((B, S - 4), jnp.int32),
                "labels": jnp.ones((B, S - 4), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one SGD-style grad step, no NaNs."""
    cfg = get_config(arch, smoke=True)
    params = T.init_model(KEY, cfg)
    batch = _batch(cfg)
    logits = T.model_fwd(params, cfg, batch)
    S_tok = batch["tokens"].shape[1]
    n_prefix = 0 if cfg.enc_dec else (4 if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, S_tok + n_prefix, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.abs(g.astype(jnp.float32)).sum())
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [
    "qwen3_4b",            # GQA + qk_norm
    "gemma3_12b",          # 5:1 local:global windows
    "olmoe_1b_7b",         # MoE top-8
    "recurrentgemma_2b",   # RG-LRU + local attn
    "rwkv6_1p6b",          # attention-free
    "seamless_m4t_medium", # enc-dec cross-attention
    "pixtral_12b",         # vision prefix
])
def test_prefill_decode_matches_full_forward(arch):
    """prefill(S) + decode(1) must equal the full forward over S+1 tokens."""
    cfg = get_config(arch, smoke=True)
    params = T.init_model(KEY, cfg)
    B, S = 2, 8
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    inp_full = {"tokens": toks}
    inp_pre = {"tokens": toks[:, :S]}
    prefix = 0
    if cfg.enc_dec:
        src = jax.random.normal(KEY, (B, 4, cfg.d_model), jnp.bfloat16)
        inp_full["src_embeds"] = inp_pre["src_embeds"] = src
    if cfg.frontend == "vision":
        emb = jax.random.normal(KEY, (B, 4, cfg.d_model), jnp.bfloat16)
        inp_full["embeds"] = inp_pre["embeds"] = emb
        prefix = 4
    full = T.model_fwd(params, cfg, inp_full)
    logits_p, cache, pos = T.prefill(params, cfg, inp_pre, s_max=S + prefix + 4)
    logits_d, _ = T.decode_step(params, cfg, cache, toks[:, S:S + 1],
                                jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, prefix + S - 1]),
                               atol=0.08, rtol=0.05)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full[:, prefix + S]),
                               atol=0.08, rtol=0.05)


def test_sliding_window_cache_wraps():
    """Decode past the window: entries must wrap and old keys be masked."""
    cfg = get_config("gemma3_12b", smoke=True)  # window=8 after shrink
    params = T.init_model(KEY, cfg)
    B, S = 1, 8
    toks = jax.random.randint(KEY, (B, S + 4), 0, cfg.vocab)
    full = T.model_fwd(params, cfg, {"tokens": toks})
    _, cache, pos = T.prefill(params, cfg, {"tokens": toks[:, :S]},
                              s_max=S + 8)
    p = jnp.int32(pos)
    for i in range(4):  # decode 4 tokens past the window boundary
        logits_d, cache = T.decode_step(params, cfg, cache,
                                        toks[:, S + i:S + i + 1], p + i)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   atol=0.1, rtol=0.05)


def test_param_count_sane():
    """Full-config param counts in the right ballpark for the known models."""
    expect = {"tinyllama_1p1b": (0.9e9, 1.4e9),
              "qwen3_4b": (3e9, 5e9),
              "gemma3_12b": (9e9, 14e9),
              "olmoe_1b_7b": (5e9, 8.5e9),
              "rwkv6_1p6b": (1.2e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_less_than_total():
    cfg = get_config("olmoe_1b_7b")
    assert cfg.active_param_count() < cfg.param_count() * 0.4
