"""Elastic collectives: failure-aware tree repair, selective plan-cache
surgery, targeted drift re-probing, and fault-injected simulation.

The acceptance bar (ISSUE 4): a pod failure mid-run is survived by
``Communicator.repair`` — orphans reparent without a full tree rebuild
(the ``tree_builds`` counter does not move), only affected PlanCache
entries are touched, post-repair plan regret stays within 10% of a
from-scratch rebuild on fig8 and the 512-chip topology, and the targeted
drift re-probe costs O(strata · group-count) measurements instead of the
O(P²) of full discovery.
"""
import math

import numpy as np
import pytest

from repro.core import Communicator
from repro.core import discovery as D
from repro.core.simulator import simulate_rounds
from repro.core.topology import (Level, Topology, paper_fig8_topology,
                                 tpu_v5e_multipod)
from repro.core.trees import (PAPER_POLICY, build_multilevel_tree,
                              repair_tree)
from repro.runtime.fault_tolerance import (HeartbeatTracker, has_quorum,
                                           pod_member_ranks)


@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


@pytest.fixture(scope="module")
def big():
    return tpu_v5e_multipod()  # 2 pods x 16 boards x 16 chips = 512


# ------------------------------------------------------------------ #
# repair_tree: splice invariants.
# ------------------------------------------------------------------ #

def test_repair_tree_removes_failed_and_stays_valid(fig8):
    tree = build_multilevel_tree(fig8, 0, policy=PAPER_POLICY)
    for dead in ([16], [16, 17, 18], list(range(16, 24)), [5, 33, 40],
                 list(range(16, 48))):
        rep = repair_tree(tree, fig8, dead, nbytes=64e3)
        rep.validate()
        assert sorted(rep.members()) == [m for m in range(48)
                                         if m not in set(dead)]
        # the original tree is untouched (repair is a copy-splice)
        assert 16 in tree.members()


def test_repair_tree_preserves_slow_link_count(fig8):
    """Killing one site coordinator must not multiply WAN crossings: the
    deputy takes over the slow edge, everything else rejoins locally."""
    tree = build_multilevel_tree(fig8, 0, policy=PAPER_POLICY)

    def wan_edges(t):
        return sum(1 for p, cs in t.children.items() for c in cs
                   if fig8.comm_level(p, c) == 0)

    rep = repair_tree(tree, fig8, [16], nbytes=64e3)
    assert wan_edges(rep) == wan_edges(tree) == 1


def test_repair_tree_dead_root_raises(fig8):
    tree = build_multilevel_tree(fig8, 0, policy=PAPER_POLICY)
    with pytest.raises(ValueError, match="root 0 failed"):
        repair_tree(tree, fig8, [0])


def test_repair_tree_noop_without_intersection(fig8):
    tree = build_multilevel_tree(fig8, 0, members=list(range(16)),
                                 policy=PAPER_POLICY)
    rep = repair_tree(tree, fig8, [40, 41])  # not members of this tree
    assert rep.children == tree.children


def test_repair_tree_chained_dead_ancestors(fig8):
    """A dead child of a dead parent still splices (preorder handles the
    chain), and its surviving subtree survives."""
    tree = build_multilevel_tree(fig8, 0, policy=PAPER_POLICY)
    # 16 is the site-1 coordinator; 17 sits inside 16's machine group
    rep = repair_tree(tree, fig8, [16, 17], nbytes=64e3)
    rep.validate()
    assert 16 not in rep.members() and 17 not in rep.members()
    assert sorted(rep.members()) == [m for m in range(48)
                                     if m not in (16, 17)]


# ------------------------------------------------------------------ #
# Communicator.repair: cache surgery + counters.
# ------------------------------------------------------------------ #

def test_repair_splices_without_tree_rebuilds(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    comm.bcast(64e3, root=0)
    comm.allreduce(64e3)
    comm.bcast(64e3, root=16)  # root about to die -> must be evicted
    tb = comm.cache_info().tree_builds
    rep = comm.repair(failed=[16])
    assert comm.cache_info().tree_builds == tb, "repair rebuilt trees"
    assert rep.failed == (16,)
    assert rep.repaired == 2 and rep.evicted == 1 and rep.kept == 0
    assert 16 not in comm.members and len(comm.members) == 47
    assert comm.repairs == 1
    # the repaired plans are served as cache HITS under the new membership
    before = comm.cache_info()
    res = comm.bcast(64e3, root=0)
    after = comm.cache_info()
    assert after.hits == before.hits + 1 and after.misses == before.misses
    assert after.tree_builds == tb
    assert math.isfinite(res.time) and res.time > 0
    # the evicted dead-root plan re-plans lazily for a surviving root
    comm.bcast(64e3, root=17)
    assert comm.cache_info().tree_builds > tb


def test_repair_evicts_only_affected_entries(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim",
                        members=list(range(16)))  # SDSC only
    comm.bcast(8e3, root=0)
    rep = comm.repair(failed=[40, 41])  # other site: no member intersects
    assert rep.failed == () and rep.kept == 1
    assert rep.repaired == rep.evicted == 0
    assert comm.repairs == 0 and len(comm.members) == 16
    info = comm.cache_info()
    comm.bcast(8e3, root=0)
    assert comm.cache_info().hits == info.hits + 1  # entry untouched


def test_repair_evicts_leaf_group_algorithm_plans(fig8):
    """sag/rsag lowerings are shaped by membership, not just the tree:
    repair drops them and the next call re-plans."""
    comm = Communicator(fig8, policy="paper", backend="sim",
                        algorithm="rsag")
    comm.allreduce(1e6)
    rep = comm.repair(failed=[17])
    assert rep.evicted == 1 and rep.repaired == 0
    with pytest.raises(ValueError, match="rsag"):
        comm.allreduce(1e6)  # 15/16/16 leaf groups are no longer uniform


def test_repair_all_members_dead_raises(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim",
                        members=[0, 1, 2])
    with pytest.raises(ValueError, match="no members"):
        comm.repair(failed=[0, 1, 2])


def test_has_quorum(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    assert comm.has_quorum(list(range(16)))        # 32/48 survive
    assert not comm.has_quorum(list(range(24)))    # exactly half
    assert has_quorum(4, 1) and not has_quorum(4, 2)
    assert pod_member_ranks((4, 2, 2), ("pod", "data", "model"),
                            [1, 3]) == [2, 3, 6, 7]
    assert pod_member_ranks((2, 2), ("pod", "data"), [5]) == []


# ------------------------------------------------------------------ #
# The acceptance bar: post-repair plan regret vs a from-scratch rebuild.
# ------------------------------------------------------------------ #

def _regret(topo, dead, op, nbytes, root=0):
    comm = Communicator(topo, policy="paper", backend="sim")
    run = (lambda c: c.allreduce(nbytes) if op == "allreduce"
           else getattr(c, op)(nbytes, root=root))
    run(comm)
    tb = comm.cache_info().tree_builds
    comm.repair(failed=dead)
    assert comm.cache_info().tree_builds == tb
    t_rep = run(comm).time
    survivors = [m for m in range(topo.nprocs) if m not in set(dead)]
    fresh = Communicator(topo, policy="paper", backend="sim",
                         members=survivors)
    return t_rep / run(fresh).time - 1.0


@pytest.mark.parametrize("op,nbytes", [("bcast", 64e3), ("allreduce", 64e3)])
def test_fig8_repair_regret_within_10pct(fig8, op, nbytes):
    for dead in ([16], list(range(16, 24)), [5, 17, 33, 40],
                 list(range(16, 32))):   # whole ANL-SP machine
        assert _regret(fig8, dead, op, nbytes) <= 0.10, (op, dead)


@pytest.mark.parametrize("op,nbytes", [("bcast", 1e6), ("allreduce", 1e6)])
def test_512_chip_repair_regret_within_10pct(big, op, nbytes):
    scenarios = [
        list(range(256, 512)),   # a whole pod dies
        list(range(16, 32)),     # one board (with its coordinator)
        [256],                   # the pod-1 coordinator alone
        [3, 100, 300, 499],      # scattered chips
    ]
    for dead in scenarios:
        assert _regret(big, dead, op, nbytes) <= 0.10, (op, dead)


def test_512_chip_worst_case_board_kill_bounded(big):
    """Hardest splice we know: the pod coordinator's entire board. Track
    the bound so repair-quality regressions surface (currently ~14%)."""
    assert _regret(big, list(range(256, 272)), "bcast", 1e6) <= 0.20


# ------------------------------------------------------------------ #
# Targeted drift re-probe: O(strata · group-count), refresh semantics.
# ------------------------------------------------------------------ #

def test_representative_pairs_cost_bound(fig8, big):
    for topo in (fig8, big):
        pairs = D.representative_pairs(topo)
        leaf_groups = len({tuple(c) for c in topo.coords})
        bound = (topo.nstrata + 1) * leaf_groups
        assert len(pairs) <= bound, (len(pairs), bound)
        assert len(pairs) < topo.nprocs ** 2 / 100  # nowhere near all-pairs
        # every link class is sampled
        assert {l for _, _, l in pairs} == set(range(topo.nstrata + 1))
        for p, q, l in pairs:
            assert topo.comm_level(p, q) == l


def test_representative_pairs_homogeneous():
    flat = Topology(np.zeros((6, 0), dtype=np.int64),
                    [Level("one", 1e-6, 1e9)])
    pairs = D.representative_pairs(flat)
    assert pairs == [(0, 1, 0)]


def test_targeted_probes_refit_recovers_drift(big):
    drifted = Topology(big.coords,
                       [Level("dcn", 30e-6, 2e9, 2e-6)] + list(big.levels[1:]))
    pairs = D.representative_pairs(big)
    probes = D.targeted_probes(drifted, pairs)
    drift = D.measure_drift(big, probes)
    assert drift[0] > 1.5          # DCN got slower
    assert abs(drift[1] - 1) < .01 and abs(drift[2] - 1) < .01
    refit = D.refit_levels(big, probes)
    assert np.array_equal(refit.coords, big.coords)  # grouping untouched
    assert refit.levels[0].latency == pytest.approx(30e-6, rel=1e-6)
    assert refit.levels[0].bandwidth == pytest.approx(2e9, rel=1e-6)
    assert refit.levels[2].latency == pytest.approx(
        big.levels[2].latency, rel=1e-6)


def test_refresh_ignores_non_member_pairs_and_rejects_views(fig8):
    """After a repair, a pair list built from the full topology still
    contains dead ranks: refresh must ignore those samples rather than
    probe (or average in) ghosts.  View-based communicators refuse — the
    view's copied levels cannot be refitted generically."""
    comm = Communicator(fig8, policy="auto", backend="sim")
    comm.repair(failed=list(range(16, 32)))
    drifted = Topology(fig8.coords, [Level("wan", 90e-3, 1.25e6 / 3, 50e-6)]
                       + list(fig8.levels[1:]))
    stale_pairs = D.representative_pairs(fig8)  # includes dead ranks
    assert any(p in range(16, 32) or q in range(16, 32)
               for p, q, _ in stale_pairs)
    # ghost samples are dropped, which (on fig8) leaves the WAN class
    # unsampled: refresh stays conservative instead of averaging ghosts
    rep = comm.refresh(D.targeted_probes(drifted, stale_pairs))
    assert not rep.refreshed and 0 not in rep.drift
    # pairs built over the SURVIVING members (the README workflow) pick
    # live representatives and the drift is caught
    live_pairs = D.representative_pairs(fig8, comm.members)
    assert all(p in comm.members and q in comm.members
               for p, q, _ in live_pairs)
    rep = comm.refresh(D.targeted_probes(drifted, live_pairs))
    assert rep.refreshed
    assert comm.topo.levels[0].latency == pytest.approx(90e-3, rel=1e-6)
    from repro.core.topology import magpie_site_view
    viewed = Communicator(fig8, policy="paper", backend="sim",
                          view=magpie_site_view(fig8))
    with pytest.raises(ValueError, match="view-based"):
        viewed.refresh(D.targeted_probes(drifted, stale_pairs))


def test_measure_drift_sees_latency_only_drift(fig8):
    """Regression: drift was judged at the large probe size only, where a
    fat link's latency is a rounding error — tripled WAN latency (30 ms ->
    90 ms, bandwidth unchanged) moved the 1 MiB ratio by ~7% and slipped
    under the 10% threshold while every latency-bound plan went stale."""
    drifted = Topology(fig8.coords, [
        Level("wan", fig8.levels[0].latency * 3, fig8.levels[0].bandwidth,
              fig8.levels[0].overhead)] + list(fig8.levels[1:]))
    probes = D.targeted_probes(drifted, D.representative_pairs(fig8))
    drift = D.measure_drift(fig8, probes)
    assert drift[0] > 1.5          # the small probe exposes it
    comm = Communicator(fig8, policy="auto", backend="sim")
    assert comm.refresh(probes).refreshed
    assert comm.topo.levels[0].latency == pytest.approx(90e-3, rel=1e-6)


def test_communicator_refresh_threshold(big):
    comm = Communicator(big, policy="auto", backend="sim")
    comm.bcast(1e6, root=0)
    # no drift -> no-op, cache intact
    rep = comm.refresh(D.targeted_probes(comm.topo,
                                         D.representative_pairs(comm.topo)))
    assert not rep.refreshed and rep.worst < 0.01
    info = comm.cache_info()
    comm.bcast(1e6, root=0)
    assert comm.cache_info().hits == info.hits + 1
    # real drift -> levels refit, plans invalidated (stats preserved)
    drifted = Topology(big.coords,
                       [Level("dcn", 30e-6, 2e9, 2e-6)] + list(big.levels[1:]))
    rep = comm.refresh(D.targeted_probes(drifted,
                                         D.representative_pairs(comm.topo)))
    assert rep.refreshed and rep.worst > 0.1
    assert comm.topo.levels[0].bandwidth == pytest.approx(2e9, rel=1e-6)
    before = comm.cache_info()
    comm.bcast(1e6, root=0)   # re-plans under the fresh costs
    assert comm.cache_info().misses == before.misses + 1


# ------------------------------------------------------------------ #
# Simulator fault injection.
# ------------------------------------------------------------------ #

def test_simulate_rounds_fault_free_path_identical(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    low = comm.plan("allreduce", root=0, nbytes=64e3).lower(64e3)
    assert simulate_rounds(low, fig8) == \
        simulate_rounds(low, fig8, fail_at={}) == \
        simulate_rounds(low, fig8, fail_at=None)


def test_simulate_rounds_rank_death_stalls_subtree(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    plan = comm.plan("bcast", root=0, nbytes=64e3)
    low = plan.lower(64e3)
    completion = simulate_rounds(low, fig8, fail_at={16: 0.0})
    assert completion[16] == 0.0      # dead ranks report their death time
    # 16 is the site-1 coordinator: its entire subtree starves
    starved = {r for r, t in completion.items() if t == math.inf}
    sub = set()
    stack = [16]
    while stack:
        n = stack.pop()
        sub.update(plan.tree.children.get(n, []))
        stack.extend(plan.tree.children.get(n, []))
    assert starved == sub
    # ranks outside the dead subtree finish at their fault-free times
    clean = simulate_rounds(low, fig8)
    for r in set(completion) - starved - {16}:
        assert completion[r] == clean[r]


def test_simulate_rounds_dead_nic_blocks_queued_sends():
    """Regression: a sender dying mid-injection must take its WHOLE
    remaining FIFO queue with it — a later queued send must not start from
    the stale NIC time and get spuriously delivered (which would mute the
    starvation signal the detector relies on)."""
    from repro.core.rounds import Lowered, SegSend

    topo = Topology(np.zeros((3, 0), dtype=np.int64),
                    [Level("one", 0.0, 1.0)])  # 1 B/s, zero latency
    sends = (SegSend(0, 1, 10.0, 0, 0, "copy", True, ()),
             SegSend(0, 2, 1.0, 0, 0, "copy", True, ()))
    low = Lowered("bcast", "tree", 0, 11.0, (0, 1, 2), 1, 11.0, 1, sends)
    clean = simulate_rounds(low, topo)
    assert clean == {0: 11.0, 1: 10.0, 2: 11.0}  # FIFO: 2nd send queues
    failed = simulate_rounds(low, topo, fail_at={0: 5.0})
    assert failed[0] <= 5.0  # dead rank: capped at death, no lost-send credit
    assert failed[1] == math.inf and failed[2] == math.inf


def test_simulate_rounds_late_death_spares_early_sends(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    low = comm.plan("bcast", root=0, nbytes=64e3).lower(64e3)
    clean = simulate_rounds(low, fig8)
    # dying AFTER the collective completed changes nothing but the dead
    # rank's own (capped) completion
    late = simulate_rounds(low, fig8, fail_at={16: clean[16] + 1.0})
    assert all(late[r] == clean[r] for r in clean if r != 16)
    assert late[16] == clean[16]


def test_end_to_end_recovery_latency_measurable(fig8):
    """The full elastic loop on the sim plane: death -> detector timeout ->
    repair -> re-run; recovery latency decomposes into its three terms."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    plan = comm.plan("allreduce", root=0, nbytes=64e3)
    t_fail = 0.01
    completion = simulate_rounds(plan.lower(64e3), fig8,
                                 fail_at={16: t_fail})
    assert any(t == math.inf for t in completion.values())  # detectable
    clock = [t_fail]
    hb = HeartbeatTracker(["h16"], timeout_s=0.5, clock=lambda: clock[0])
    clock[0] = t_fail + 0.6
    assert hb.dead_hosts() == ["h16"]
    comm.repair(failed=[16])
    post = comm.allreduce(64e3)
    assert math.isfinite(post.time)
    recovery = 0.6 + post.time  # detection + post-repair collective
    assert recovery < 1.0


# ------------------------------------------------------------------ #
# launch/train.py: in-place repair vs checkpoint-restart (subprocess).
# ------------------------------------------------------------------ #

def test_train_in_place_repair_with_quorum(subproc, tmp_path):
    """4 pods, one dies at step 2: quorum holds, so training repairs the
    communicator in place and keeps going — no checkpoint rewind, no step
    replay (6 steps -> exactly 6 losses), repairs=1, recoveries=0."""
    subproc(f"""
from repro.launch.train import train
out = train("gpt-100m", steps=6, mesh_spec="4x1x1", seq=32, batch=4,
            comm="multilevel", zero1=True, ckpt_dir=r"{tmp_path}",
            ckpt_every=3, fail_at={{2: [1]}}, smoke=True, log_every=100)
assert out["repairs"] == 1 and out["recoveries"] == 0, out
assert len(out["losses"]) == 6, out
import numpy as np
assert np.isfinite(out["losses"]).all()
assert out["final_loss"] < 8.0
print("OK in-place:", out["repairs"])
""", n_devices=4, timeout=1500)


def test_train_in_place_repair_with_compressed_ef(subproc, tmp_path):
    """The elastic x compression interplay: a pod failure during
    multilevel_compress training trims the EF residual's leading pod dim
    to the survivors (each keeps its own rounding error) and continues."""
    subproc(f"""
from repro.launch.train import train
out = train("gpt-100m", steps=6, mesh_spec="4x1x1", seq=32, batch=4,
            comm="multilevel_compress", zero1=True, ckpt_dir=r"{tmp_path}",
            ckpt_every=3, fail_at={{2: [1]}}, smoke=True, log_every=100)
assert out["repairs"] == 1 and out["recoveries"] == 0, out
assert len(out["losses"]) == 6, out
import numpy as np
assert np.isfinite(out["losses"]).all()
print("OK elastic+EF:", out["final_loss"])
""", n_devices=4, timeout=1500)
