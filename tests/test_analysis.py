"""Static-analysis subsystem tests.

Validation is mutation-driven: each seeded defect class must be caught by
the intended pass, and every legitimate lowering the planner can emit —
{tree, sag, rsag} x all registered ops x {fig8, 512-chip}, including
post-repair spliced plans — must verify with ZERO findings.  The hazard
analyzer must flag a constructed ``after=`` cycle (which previously
surfaced only as a cryptic concurrent-simulator error), and the repo
itself must lint clean.
"""
import dataclasses
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.hazards import (HazardError, HazardWarning,
                                    analyze_engine, check_hazards)
from repro.analysis.lint import lint_source, lint_tree
from repro.analysis.verify import (VerificationError, check_lowered,
                                   quick_check, verify_lowered)
from repro.core import Communicator, Engine
from repro.core import rounds as R
from repro.core.simulator import simulate_concurrent, simulate_rounds
from repro.core.topology import (LAN, SMP, WAN, Topology,
                                 paper_fig8_topology, tpu_v5e_multipod)
from repro.core.trees import PAPER_POLICY, build_multilevel_tree

MIB = 2.0 ** 20
ALL_OPS = ("bcast", "reduce", "barrier", "gather", "scatter", "allreduce",
           "allgather")


@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


@pytest.fixture(scope="module")
def fig8_tree(fig8):
    return build_multilevel_tree(fig8, 0, tuple(range(fig8.nprocs)),
                                 PAPER_POLICY)


@st.composite
def topologies(draw, uniform_leaves=False):
    """Random 2-strata topologies (sites -> machines -> procs)."""
    sites = draw(st.integers(1, 3))
    uniform = draw(st.integers(1, 4)) if uniform_leaves else None
    coords = []
    mid = 0
    for s in range(sites):
        machines = draw(st.integers(1, 3))
        for m in range(machines):
            procs = uniform if uniform else draw(st.integers(1, 4))
            coords += [[s, mid]] * procs
            mid += 1
    return Topology(np.array(coords), [WAN, LAN, SMP])


def _mut(low, fn):
    """Return ``low`` with its send list rewritten by ``fn(list) -> None``."""
    sends = list(low.sends)
    fn(sends)
    return dataclasses.replace(low, sends=tuple(sends))


def _rules(low):
    return {f.rule for f in verify_lowered(low)}


# ------------------------------------------------------------------ #
# Mutation validation: each seeded defect class -> the intended pass.
# ------------------------------------------------------------------ #

def _defect_classes(fig8, fig8_tree):
    """(name, mutated Lowered, rule the intended pass reports) triples."""
    base = R.lower_tree("allreduce", fig8_tree, fig8, 16 * MIB, "bdp")
    scat = R.lower_tree("scatter", fig8_tree, fig8, MIB)
    gath = R.lower_tree("gather", fig8_tree, fig8, MIB)
    red = next(i for i, s in enumerate(base.sends) if s.kind == "reduce")
    cp = next(i for i, s in enumerate(base.sends) if s.kind == "copy")
    members = base.members

    def swap(sends, i, **kw):
        sends[i] = dataclasses.replace(sends[i], **kw)

    return [
        # 1. dropped send: holdings contract violated at some rank
        ("dropped-send", _mut(base, lambda s: s.pop()), "semantics"),
        # 2. double fold: the same contribution reduced twice
        ("double-fold",
         _mut(base, lambda s: s.append(
             dataclasses.replace(s[red], deps=(red,), first=True))),
         "semantics"),
        # 3. duplicated copy delivery
        ("dup-copy",
         _mut(base, lambda s: s.append(
             dataclasses.replace(s[cp], deps=(cp,)))),
         "semantics"),
        # 4. forward dependency: unexecutable by the linear injection pass
        ("forward-dep",
         _mut(base, lambda s: swap(s, 0, deps=(1,))),
         "injection-order"),
        # 5. genuine wait-for cycle between two sends
        ("dep-cycle",
         _mut(base, lambda s: (swap(s, 0, deps=(1,)),
                               swap(s, 1, deps=(0,)))),
         "dependency-cycle"),
        # 6. splice to a dead/non-member rank
        ("dead-rank-splice",
         _mut(base, lambda s: swap(s, 0, dst=9999)),
         "member-closure"),
        # 7. self-send
        ("self-send",
         _mut(base, lambda s: swap(s, 0, dst=s[0].src)),
         "no-self-send"),
        # 8. wrong wire bytes: symbolically fine, physically half a segment
        ("half-bytes",
         _mut(base, lambda s: swap(s, 0, nbytes=s[0].nbytes / 2)),
         "byte-conservation"),
        # 9. segment id out of range
        ("bad-seg",
         _mut(base, lambda s: swap(s, 0, seg=base.nsegs + 3)),
         "segment-range"),
        # 10. chunk leak: scatter sends a chunk to a bystander — final
        # holdings still satisfy the op, only the routing check sees it
        ("chunk-leak-scatter",
         _mut(scat, lambda s: s.append(dataclasses.replace(
             s[0], src=scat.root, dst=members[-1], deps=()))),
         "semantics"),
        # 11. chunk leak on the gather side: a relay forwards a chunk to a
        # second destination besides its parent
        ("chunk-leak-gather",
         _mut(gath, lambda s: s.append(dataclasses.replace(
             s[0], dst=members[-1], deps=(0,)))),
         "semantics"),
    ]


def test_mutation_matrix(fig8, fig8_tree):
    """Every seeded defect class is detected, and by the intended pass."""
    for name, low, want_rule in _defect_classes(fig8, fig8_tree):
        rules = _rules(low)
        assert want_rule in rules, (name, rules)
        with pytest.raises(VerificationError):
            check_lowered(low)


def test_clean_programs_have_zero_findings(fig8, fig8_tree):
    base = R.lower_tree("allreduce", fig8_tree, fig8, 16 * MIB, "bdp")
    assert verify_lowered(base) == []
    check_lowered(base)  # does not raise
    quick_check(base)


def test_verification_error_carries_findings(fig8, fig8_tree):
    low = _mut(R.lower_tree("bcast", fig8_tree, fig8, MIB),
               lambda s: s.__setitem__(
                   0, dataclasses.replace(s[0], dst=s[0].src)))
    with pytest.raises(VerificationError) as ei:
        check_lowered(low, context="unit")
    assert ei.value.findings and ei.value.context == "unit"
    assert "no-self-send" in str(ei.value)


def test_check_semantics_rejects_personalised_chunk_leak(fig8, fig8_tree):
    """The extended check_semantics catches a leaked chunk directly — the
    final-state contract alone cannot (it inspects only terminal cells)."""
    gath = R.lower_tree("gather", fig8_tree, fig8, MIB)
    leaked = _mut(gath, lambda s: s.append(dataclasses.replace(
        s[0], dst=gath.members[-1], deps=(0,))))
    R.check_semantics(gath)  # legit program passes
    with pytest.raises(ValueError, match="chunk routing"):
        R.check_semantics(leaked)


# ------------------------------------------------------------------ #
# Zero false positives over everything the planner can emit.
# ------------------------------------------------------------------ #

def test_no_false_positives_fig8_matrix(fig8, fig8_tree):
    for nbytes in (MIB, 16 * MIB):
        for seg in (None, "bdp"):
            for op in ALL_OPS:
                low = R.lower_tree(op, fig8_tree, fig8, nbytes, seg)
                assert verify_lowered(low) == [], (op, nbytes, seg)
            members = tuple(range(fig8.nprocs))
            low = R.lower_sag_bcast(fig8, 0, members, nbytes, seg)
            assert verify_lowered(low) == [], ("sag", nbytes, seg)
            try:
                low = R.lower_rsag_allreduce(fig8, members, nbytes, seg)
            except ValueError:
                continue  # non-uniform leaf groups: legal rejection
            assert verify_lowered(low) == [], ("rsag", nbytes, seg)


@pytest.mark.parametrize("op", ALL_OPS)
def test_no_false_positives_512chip(op):
    topo = tpu_v5e_multipod()
    members = tuple(range(topo.nprocs))
    tree = build_multilevel_tree(topo, 0, members, PAPER_POLICY)
    low = R.lower_tree(op, tree, topo, MIB, "bdp")
    assert verify_lowered(low) == [], op
    if op == "bcast":
        assert verify_lowered(
            R.lower_sag_bcast(topo, 0, members, MIB, "bdp")) == []
    if op == "allreduce":
        assert verify_lowered(
            R.lower_rsag_allreduce(topo, members, MIB, "bdp")) == []


@settings(deadline=None, max_examples=25)
@given(topologies(), st.sampled_from(ALL_OPS),
       st.sampled_from([512.0, 64e3, 4 * MIB]),
       st.sampled_from([None, "bdp", 4096.0]), st.data())
def test_property_tree_lowerings_verify_clean(topo, op, nbytes, seg, data):
    root = data.draw(st.integers(0, topo.nprocs - 1))
    tree = build_multilevel_tree(topo, root)
    low = R.lower(op, "tree", tree, topo, nbytes, segment_bytes=seg)
    assert verify_lowered(low) == [], (op, nbytes, seg)


@settings(deadline=None, max_examples=15)
@given(topologies(), st.sampled_from([512.0, 4 * MIB]),
       st.sampled_from([None, "bdp"]), st.data())
def test_property_sag_lowerings_verify_clean(topo, nbytes, seg, data):
    root = data.draw(st.integers(0, topo.nprocs - 1))
    low = R.lower_sag_bcast(topo, root, range(topo.nprocs), nbytes, seg)
    assert verify_lowered(low) == []


@settings(deadline=None, max_examples=15)
@given(topologies(uniform_leaves=True), st.sampled_from([512.0, 4 * MIB]),
       st.sampled_from([None, "bdp"]))
def test_property_rsag_lowerings_verify_clean(topo, nbytes, seg):
    low = R.lower_rsag_allreduce(topo, range(topo.nprocs), nbytes, seg)
    assert verify_lowered(low) == []


# ------------------------------------------------------------------ #
# Communicator.verify_plans and the automatic post-repair re-proof.
# ------------------------------------------------------------------ #

def _warm(comm, sizes=(MIB,), ops=ALL_OPS):
    for op in ops:
        for nb in sizes:
            comm.plan(op, nbytes=nb).lower(nb)


def test_verify_plans_counts_and_passes(fig8):
    comm = Communicator(fig8, policy="auto")
    assert comm.verify_plans() == 0  # empty cache: nothing to prove
    _warm(comm, sizes=(MIB, 16 * MIB))
    assert comm.verify_plans() >= len(ALL_OPS)


def test_repair_reverifies_spliced_plans(fig8):
    comm = Communicator(fig8, policy="auto")
    _warm(comm)
    rep = comm.repair([3, 17, 40])  # auto-verify runs inside
    assert rep.repaired + rep.evicted > 0
    assert comm.verify_plans() > 0  # and the explicit call agrees


def test_repair_512chip_post_splice_verifies():
    comm = Communicator(tpu_v5e_multipod(), policy="auto")
    for op in ("allreduce", "bcast", "gather"):
        comm.plan(op, nbytes=MIB).lower(MIB)
    comm.repair([7, 100, 300, 511])
    assert comm.verify_plans() > 0


def _buggy_repair_tree(monkeypatch):
    """Simulate the defect class verify_plans exists for: a splice that
    leaves a rank attached under TWO parents (the orphan was re-homed but
    the stale edge survived), so downstream deliveries duplicate."""
    import repro.core.communicator as C
    from repro.core.trees import Tree, repair_tree

    def bad(tree, topo, failed, nbytes=0.0):
        good = repair_tree(tree, topo, failed, nbytes=nbytes)
        children = {p: list(cs) for p, cs in good.children.items()}
        leaf = next(c for cs in children.values() for c in cs
                    if not children.get(c) and c not in
                    children.get(good.root, []))
        children.setdefault(good.root, []).append(leaf)
        return Tree(good.root, children)

    monkeypatch.setattr(C, "repair_tree", bad)


def test_repair_raises_on_buggy_splice(fig8, monkeypatch):
    """A splice that corrupts a plan cannot survive repair: the automatic
    verify_plans pass fails the whole call with a precise finding."""
    comm = Communicator(fig8, policy="auto")
    _warm(comm, ops=("bcast", "reduce", "allreduce"))
    _buggy_repair_tree(monkeypatch)
    with pytest.raises(VerificationError):
        comm.repair([3])
    monkeypatch.undo()
    comm.clear_cache()
    _warm(comm, ops=("bcast", "reduce", "allreduce"))
    comm.repair([5])  # a correct splice repairs (and verifies) fine


def test_repair_verify_optout(fig8, monkeypatch):
    comm = Communicator(fig8, policy="auto")
    _warm(comm, ops=("bcast", "reduce", "allreduce"))
    _buggy_repair_tree(monkeypatch)
    rep = comm.repair([3], verify=False)  # explicit opt-out: no proof
    assert rep.repaired > 0  # the corrupted plans ARE in the cache now
    with pytest.raises(VerificationError):
        comm.verify_plans()


# ------------------------------------------------------------------ #
# Simulator sanitize mode.
# ------------------------------------------------------------------ #

def test_sanitize_is_timing_neutral(fig8, fig8_tree):
    low = R.lower_tree("allreduce", fig8_tree, fig8, 4 * MIB, "bdp")
    assert simulate_rounds(low, fig8) == \
        simulate_rounds(low, fig8, sanitize=True)


def test_sanitize_rejects_broken_program(fig8, fig8_tree):
    low = _mut(R.lower_tree("bcast", fig8_tree, fig8, MIB),
               lambda s: s.__setitem__(
                   0, dataclasses.replace(s[0], deps=(1,))))
    with pytest.raises(VerificationError, match="injection-order"):
        simulate_rounds(low, fig8, sanitize=True)
    with pytest.raises(VerificationError):
        simulate_concurrent([low], fig8, sanitize=True)


def test_sanitize_memoises_per_program(fig8, fig8_tree):
    from repro.core import simulator as SIM

    low = R.lower_tree("reduce", fig8_tree, fig8, MIB)
    SIM._SANITIZED.discard(low)
    simulate_rounds(low, fig8, sanitize=True)
    assert low in SIM._SANITIZED  # second run is a set lookup
    simulate_rounds(low, fig8, sanitize=True)


# ------------------------------------------------------------------ #
# Hazard analyzer.
# ------------------------------------------------------------------ #

def test_clean_batches_have_no_hazards(fig8):
    comm = Communicator(fig8, policy="auto")
    eng = Engine(comm, check=True)
    hs = [eng.issue("allreduce", 1e6) for _ in range(4)]
    eng.issue("bcast", 1e5, members=comm.members[:8], after=[hs[-1]])
    assert analyze_engine(eng) == []
    eng.wait_all()


def test_after_cycle_flagged_not_cryptic(fig8):
    """A constructed after= cycle (post-issue mutation — the public API
    only allows backward refs).  Unchecked, it used to surface deep in the
    concurrent simulator as 'programs ... never completed'; the analyzer
    names the cycle and the handles BEFORE execution."""
    comm = Communicator(fig8, policy="auto")
    eng = Engine(comm)
    a = eng.issue("bcast", 1e6, members=comm.members[:4])
    b = eng.issue("reduce", 1e6, members=comm.members[4:8], after=[a])
    a.after = (b,)
    # the prior failure mode, for the record: a cryptic executor error
    with pytest.raises(ValueError, match="never completed"):
        eng.wait_all()
    # re-seed and check the analyzer catches it statically instead
    a = eng.issue("bcast", 1e6, members=comm.members[:4])
    b = eng.issue("reduce", 1e6, members=comm.members[4:8], after=[a])
    a.after = (b,)
    hz = analyze_engine(eng)
    assert any(h.kind == "deadlock-cycle" and h.severity == "error"
               and set(h.handles) >= {a.hid, b.hid} for h in hz)
    with pytest.raises(HazardError, match="deadlock-cycle"):
        eng.wait_all(check=True)
    eng._pending.clear()  # drop the poisoned batch


def test_cross_engine_and_dangling_deps_flagged(fig8):
    comm = Communicator(fig8, policy="auto")
    eng, other = Engine(comm), Engine(comm)
    foreign = other.issue("bcast", 1e3)
    h = eng.issue("allreduce", 1e6)
    h.after = (foreign,)  # issue() rejects this path; mutation sneaks it in
    assert any(hz.kind == "cross-engine-dep" for hz in analyze_engine(eng))
    orphan = eng.issue("bcast", 1e3, members=comm.members[:4])
    h.after = (orphan,)
    eng._pending.remove(orphan)  # now neither done nor pending
    assert any(hz.kind == "dangling-dep" for hz in analyze_engine(eng))
    eng._pending.clear()
    other.wait_all()


def test_interleaving_race_warning(fig8):
    comm = Communicator(fig8, policy="auto")
    eng = Engine(comm)
    a = eng.issue("bcast", 1e6, members=comm.members[:8])
    b = eng.issue("reduce", 1e6, members=comm.members[4:12])
    hz = analyze_engine(eng)
    assert any(h.kind == "interleaving-race" and h.severity == "warning"
               and set(h.handles) == {a.hid, b.hid} for h in hz)
    with pytest.warns(HazardWarning, match="interleaving-race"):
        check_hazards(eng)
    # an explicit ordering edge silences it, even transitively
    eng._pending.clear()
    a = eng.issue("bcast", 1e6, members=comm.members[:8])
    mid = eng.issue("barrier", members=comm.members[:8], after=[a])
    eng.issue("reduce", 1e6, members=comm.members[4:12], after=[mid])
    assert analyze_engine(eng) == []
    eng.wait_all()


def test_starvation_warning_requires_unaged_priority(fig8):
    comm = Communicator(fig8, policy="auto")
    starved = Engine(comm, policy="priority")  # age_rate=0: no escape
    fat = starved.issue("bcast", 1e8)
    for _ in range(3):
        starved.issue("barrier", members=comm.members[:8])
    hz = analyze_engine(starved)
    assert any(h.kind == "starvation" and fat.hid in h.handles
               for h in hz)
    starved.wait_all()
    # aging bounds the wait: same stream, no starvation hazard
    aged = Engine(comm, policy="priority", age_rate=1e6)
    aged.issue("bcast", 1e8)
    for _ in range(3):
        aged.issue("barrier", members=comm.members[:8])
    assert not any(h.kind == "starvation" for h in analyze_engine(aged))
    aged.wait_all()


def test_checked_engine_issue_rejects_poison(fig8):
    """Engine(check=True) fails fast at issue() when the new handle trips
    an error-severity hazard, and the poisoned handle is rolled back."""
    comm = Communicator(fig8, policy="auto")
    eng = Engine(comm, check=True)
    good = eng.issue("allreduce", 1e6)
    orphan = eng.issue("bcast", 1e3, members=comm.members[:4])
    eng._pending.remove(orphan)
    with pytest.raises(HazardError, match="dangling-dep"):
        eng.issue("reduce", 1e6, after=[orphan])
    assert eng._pending == [good]  # rollback: batch stays clean
    eng.wait_all()


# ------------------------------------------------------------------ #
# Lint.
# ------------------------------------------------------------------ #

def test_lint_rules_fire_on_seeded_defects():
    src = (
        "import time\n"
        "import jax.numpy as jnp\n"
        "from jax import lax\n"
        "import numpy as np\n"
        "def f(xs=[], m={}):\n"
        "    assert xs\n"
        "    t = time.perf_counter()\n"
        "    r = np.random.rand(3)\n"
        "    import random\n"
        "    random.random()\n"
    )
    rules = {f.rule for f in lint_source(src, "bad.py",
                                         "core/simulator.py")}
    assert rules >= {"RA001", "RA002", "RA003", "RA004"}


def test_lint_scoping_and_suppression():
    # device use inside an allow-listed backend class is legal
    src = ("class JaxExecutor:\n"
           "    def run(self):\n"
           "        import jax\n"
           "        return jax\n")
    assert lint_source(src, "s.py", "serving/scheduler.py") == []
    # ...but not outside it
    src2 = "import jax\nclass JaxExecutor:\n    pass\n"
    assert any(f.rule == "RA002"
               for f in lint_source(src2, "s.py", "serving/scheduler.py"))
    # outside the deterministic set, jax/time are fine; asserts are not
    src3 = "import jax\nimport time\ndef g():\n    assert True\n"
    assert {f.rule for f in lint_source(src3, "k.py",
                                        "kernels/foo.py")} == {"RA001"}
    # the escape hatch
    assert lint_source("def g():\n    assert True  # lint: allow\n",
                       "k.py", None) == []
    # seeded np.random.default_rng stays legal in deterministic modules
    src4 = ("import numpy as np\n"
            "def h(seed):\n"
            "    return np.random.default_rng(seed)\n")
    assert lint_source(src4, "d.py", "core/simulator.py") == []


def test_repo_lints_clean():
    """The CI gate's contract, asserted in-tree: src/repro has zero lint
    findings (bare asserts, device ops / wall clock in deterministic
    modules, mutable defaults)."""
    import repro.analysis as A
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(A.__file__)))
    findings = lint_tree(root)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_analysis_cli_smoke(fig8):
    from repro.analysis.__main__ import cmd_hazards, cmd_lint

    assert cmd_hazards() == 0
    assert cmd_lint() == 0
