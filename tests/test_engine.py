"""Async collective engine tests: nonblocking handles, legal interleavings,
contention-aware scheduling policies, gradient bucketing/overlap, and the
composition with elastic repair."""
import math

import pytest

from repro.core import Communicator, Engine
from repro.core.engine import overlapped_step_times, partition_buckets
from repro.core.rounds import Lowered, SegSend
from repro.core.simulator import simulate_concurrent, simulate_rounds
from repro.core.topology import paper_fig8_topology


@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


def _one_send(nbytes, src=0, dst=1):
    """A minimal program: one copy over the (src, dst) edge."""
    return Lowered("bcast", "tree", src, nbytes, (src, dst), 1, nbytes, 1,
                   (SegSend(src, dst, nbytes, 0, 0, "copy", True, ()),))


# ------------------------------------------------------------------ #
# Handles: issue / wait / wait_all and result identity.
# ------------------------------------------------------------------ #

def test_handle_lifecycle(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    h = eng.issue("allreduce", 64e3)
    assert not h.done
    res = h.wait()
    assert h.done and h.wait() is res  # resolved once, cached
    assert h.started == 0.0 and h.finished == res.time > 0
    st = eng.stats()
    assert (st.issued, st.completed, st.batches) == (1, 1, 1)
    assert eng.now == h.finished


def test_single_handle_bit_identical_to_communicator(fig8):
    """An engine with one live handle prices exactly like the blocking
    call: the concurrent executor only differs under actual contention."""
    comm = Communicator(fig8, policy="auto", backend="sim")
    blocking = comm.allreduce(1 << 22).completion
    eng = Engine(comm)
    assert eng.issue("allreduce", float(1 << 22)).wait().completion \
        == blocking


def test_wait_all_returns_batch_in_issue_order(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    hs = [eng.issue("bcast", 1e3 * (i + 1), root=0) for i in range(3)]
    out = eng.wait_all()
    assert out == [h.result for h in hs]
    assert eng.wait_all() == []  # nothing pending: no-op


def test_issue_validation(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim",
                        members=[0, 1, 2, 3])
    eng = Engine(comm)
    with pytest.raises(KeyError):
        eng.issue("alltoall", 1e3)
    with pytest.raises(ValueError, match="not a member"):
        eng.issue("bcast", 1e3, root=17)
    with pytest.raises(ValueError, match="not members"):
        eng.issue("bcast", 1e3, members=[0, 17])
    with pytest.raises(ValueError, match="unknown policy"):
        Engine(comm, policy="lifo")
    other = Engine(comm)
    h = other.issue("bcast", 1e3)
    with pytest.raises(ValueError, match="different engine"):
        eng.wait(h)
    with pytest.raises(ValueError, match="different engine"):
        eng.issue("bcast", 1e3, after=[h])
    # wait_all must reject foreign handles too: accepting one silently
    # flushed BOTH engines and returned results that were never part of
    # this engine's batch
    with pytest.raises(ValueError, match="different engine"):
        eng.wait_all(handles=[h])
    assert not h.done  # the guard fired before anything flushed
    other.wait_all()


# ------------------------------------------------------------------ #
# Legal interleavings: per-member-set FIFO + explicit dependencies.
# ------------------------------------------------------------------ #

def test_same_member_set_is_fifo(fig8):
    """Two collectives on the same member set never overlap: the second
    starts only when the first has completed on every rank."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    h1 = eng.issue("allreduce", 1e6)
    h2 = eng.issue("allreduce", 1e6)
    eng.wait_all()
    assert h2.started == h1.finished
    assert min(h2.result.completion.values()) >= h1.finished


def test_fifo_holds_across_batches(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    h1 = eng.issue("bcast", 1e6, root=0)
    eng.wait_all()
    h2 = eng.issue("bcast", 1e6, root=0, at=0.0)  # release BEFORE h1 ends
    eng.wait_all()
    assert h2.started >= h1.finished


def test_explicit_dependency_orders_disjoint_sets(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    left, right = tuple(range(16)), tuple(range(16, 32))
    eng = Engine(comm)
    a = eng.issue("bcast", 1e6, root=0, members=left)
    b = eng.issue("bcast", 1e6, root=16, members=right, after=[a])
    eng.wait_all()
    assert b.started >= a.finished
    # a resolved dependency contributes a release floor, not a chain
    c = eng.issue("bcast", 1e6, root=0, members=left, at=0.0, after=[b])
    c.wait()
    assert c.started >= b.finished


def test_disjoint_member_sets_overlap_and_price_as_isolated(fig8):
    """Satellite: K plans on link-disjoint subtrees simulate to the SAME
    per-plan times as isolated runs — concurrency costs nothing when no
    link is shared."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    sets = [tuple(range(16)), tuple(range(16, 32)), tuple(range(32, 48))]
    eng = Engine(comm)
    hs = [eng.issue("bcast", 1e6, root=s[0], members=s) for s in sets]
    eng.wait_all()
    for s, h in zip(sets, hs):
        iso = Communicator(fig8, policy="paper", backend="sim",
                           members=s).bcast(1e6, root=s[0])
        assert h.result.completion == iso.completion
    # genuinely concurrent: makespan is one plan's time, not three
    assert eng.now == max(h.finished for h in hs)
    assert eng.now < 2 * min(h.finished for h in hs)


# ------------------------------------------------------------------ #
# Contention conservation on a shared link.
# ------------------------------------------------------------------ #

def test_shared_link_fair_share_bound(fig8):
    """Satellite: K plans through one link never exceed its bandwidth —
    the last finisher waits at least total_bytes/bandwidth — and complete
    no earlier than the fair-share bound."""
    N, K = 1e6, 4
    lvl = fig8.level_of_edge(0, 1)
    res = simulate_concurrent([_one_send(N) for _ in range(K)], fig8)
    finishes = [max(r.values()) for r in res]
    iso = max(simulate_rounds(_one_send(N), fig8).values())
    assert all(f >= iso for f in finishes)          # never beats isolation
    assert max(finishes) >= K * N / lvl.bandwidth   # bandwidth conserved
    # symmetric release, identical programs: fair share finishes together
    assert max(finishes) - min(finishes) < 1e-12
    assert max(finishes) == pytest.approx(
        K * N / lvl.bandwidth + lvl.latency, rel=1e-9)


def test_staggered_release_shares_then_drains(fig8):
    """A transfer released halfway through another's flow halves the rate
    from that instant: 1 MB alone takes T; two offset by T/2 finish at
    1.5T and 2T (bandwidth terms)."""
    N = 1e6
    lvl = fig8.level_of_edge(0, 1)
    T = N / lvl.bandwidth
    res = simulate_concurrent([_one_send(N), _one_send(N)], fig8,
                              starts=[0.0, T / 2])
    f0 = max(res[0].values()) - lvl.latency
    f1 = max(res[1].values()) - lvl.latency
    assert f0 == pytest.approx(1.5 * T, rel=1e-9)
    assert f1 == pytest.approx(2.0 * T, rel=1e-9)


# ------------------------------------------------------------------ #
# Scheduler policies.
# ------------------------------------------------------------------ #

def _mixed_batch(eng):
    """A fat broadcast whose first WAN transfer occupies edge (0, 16) for
    ~54 s, plus small latency-bound collectives that need that same edge."""
    fat = eng.issue("bcast", float(1 << 26), root=0)
    small = [eng.issue("bcast", 64e3, root=0, members=(0, 16))
             for _ in range(3)]
    return fat, small


def test_priority_small_jumps_fat(fig8):
    """Under "priority", latency-bound collectives preempt the fat
    transfer on shared links instead of fair-sharing its whole lifetime."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    lat = {}
    for policy in ("fifo", "priority"):
        eng = Engine(comm, policy=policy)
        fat, small = _mixed_batch(eng)
        eng.wait_all()
        lat[policy] = (max(h.finished for h in small), fat.finished)
    assert lat["priority"][0] < lat["fifo"][0]  # small ops finish earlier
    # the fat transfer pays at most the small ops' bytes, not a 2x stall
    assert lat["priority"][1] < lat["fifo"][1] * 1.5


def test_priority_ageing_bounds_starvation(fig8):
    """Satellite: under strict priority a sustained stream of small
    high-priority ops on the fat broadcast's WAN edge starves it — its
    finish grows with the stream length.  With priority-ageing the
    preempted broadcast's effective priority rises while it waits, so
    newly released stream ops eventually rank below it and the broadcast
    completes in bounded time, independent of how long the stream runs."""
    N = float(1 << 26)

    def run(n_small, age_rate):
        comm = Communicator(fig8, policy="paper", backend="sim")
        eng = Engine(comm, policy="priority", age_rate=age_rate)
        fat = eng.issue("bcast", N, root=0)
        # same member set => FIFO chain: a back-to-back stream on (0, 16)
        small = [eng.issue("bcast", 64e3, root=0, members=(0, 16),
                           priority=1.0) for _ in range(n_small)]
        eng.wait_all()
        return fat.finished, small

    starved_20, stream_20 = run(20, 0.0)
    starved_60, stream_60 = run(60, 0.0)
    # strict priority: every extra stream op stalls the broadcast for its
    # whole transfer time — the delay grows linearly with the stream
    extra = 40 * 64e3 / fig8.level_of_edge(0, 16).bandwidth
    assert starved_60 - starved_20 >= 0.9 * extra

    # ageing: ops released after ~(N+1)/rate seconds rank below the
    # aged broadcast, so its finish no longer scales with the stream
    rate = N  # the broadcast outranks fresh priority-1.0 ops after ~1 s
    aged_20, _ = run(20, rate)
    aged_60, aged_stream = run(60, rate)
    assert aged_60 < starved_60
    assert aged_60 == pytest.approx(aged_20, abs=1e-9)  # stream-length free
    # the trade is explicit: ops released before the crossover still jump
    # the broadcast, later ones queue behind its WAN transfer
    assert aged_stream[0].finished == stream_60[0].finished
    assert max(h.finished for h in aged_stream) \
        > max(h.finished for h in stream_60)

    with pytest.raises(ValueError, match="age_rate"):
        Engine(Communicator(fig8, backend="sim"), age_rate=-1.0)


def test_sim_policy_argmin_beats_or_matches_both(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    spans = {}
    for policy in ("fifo", "priority", "sim"):
        eng = Engine(comm, policy=policy)
        _mixed_batch(eng)
        eng.wait_all()
        spans[policy] = eng.now
        if policy == "sim":
            assert eng.stats().last_policy.startswith("sim:")
    assert spans["sim"] <= min(spans["fifo"], spans["priority"]) + 1e-12


def test_policy_override_per_wait(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm, policy="fifo")
    _mixed_batch(eng)
    eng.wait_all(policy="priority")
    assert eng.stats().last_policy == "priority"
    with pytest.raises(ValueError, match="unknown policy"):
        eng.issue("bcast", 1e3)
        eng.wait_all(policy="lifo")


# ------------------------------------------------------------------ #
# Plan reuse: asserted via Communicator.stats(), not timing.
# ------------------------------------------------------------------ #

def test_engine_reuses_plans_across_batches(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    for _ in range(4):
        eng.issue("allreduce", 8e6)
        eng.wait_all()
    st = comm.stats()
    assert st.misses == 1 and st.hits == 3
    assert st.evictions == 0
    # subset traffic reuses per-subset plans the same way
    sub = tuple(range(16))
    for _ in range(3):
        eng.issue("bcast", 8e6, root=0, members=sub)
        eng.wait_all()
    subcomm = eng._subcomms[sub]
    assert subcomm.stats().misses == 1 and subcomm.stats().hits == 2


# ------------------------------------------------------------------ #
# Gradient bucketing + overlap.
# ------------------------------------------------------------------ #

def test_partition_buckets():
    sizes = [10.0, 20.0, 30.0, 40.0]
    # reverse (backward) order, 50-byte target
    assert partition_buckets(sizes, 50.0) == [[3, 2], [1, 0]]
    assert partition_buckets(sizes, 1000.0) == [[3, 2, 1, 0]]
    assert partition_buckets(sizes, 5.0) == [[3], [2], [1], [0]]
    assert partition_buckets(sizes, 50.0, reverse=False) == [[0, 1, 2], [3]]
    with pytest.raises(ValueError, match="positive"):
        partition_buckets(sizes, 0.0)


def test_overlapped_step_beats_serial_1p5x_at_64mib(fig8):
    """THE acceptance criterion: the bucketed, overlapped fig8 training
    step is >= 1.5x faster end-to-end than the serial monolithic sync at
    64 MiB of gradients (balanced compute — the communication-bound
    threshold where overlap matters most)."""
    comm = Communicator(fig8, policy="auto", backend="sim")
    L = 12
    layer_bytes = [float(1 << 26) / L] * L
    t_comm = comm.allreduce(float(1 << 26)).time
    res = overlapped_step_times(comm, layer_bytes, [t_comm / L] * L,
                                bucket_bytes=8 * float(1 << 20))
    assert res["serial_s"] == pytest.approx(2 * t_comm, rel=1e-6)
    assert res["speedup"] >= 1.5, res
    assert 0.0 < res["overlap_efficiency"] <= 1.0
    # overlap hides work, it never invents it
    assert res["compute_s"] <= res["overlapped_s"] <= res["serial_s"]


def test_overlap_degenerates_gracefully(fig8):
    """One giant bucket = no overlap: the 'overlapped' step collapses to
    the serial one (sync starts only after the full backward)."""
    comm = Communicator(fig8, policy="auto", backend="sim")
    layer_bytes = [1e6] * 4
    res = overlapped_step_times(comm, layer_bytes, [0.05] * 4,
                                bucket_bytes=1e9)
    assert res["n_buckets"] == 1
    assert res["overlapped_s"] == pytest.approx(res["serial_s"], rel=5e-2)
    with pytest.raises(ValueError, match="align"):
        overlapped_step_times(comm, [1e6], [0.1, 0.2], bucket_bytes=1e6)


# ------------------------------------------------------------------ #
# Elastic interop: failure during overlap.
# ------------------------------------------------------------------ #

def test_repair_reissues_pending_drains_flushed(fig8):
    """Satellite: Communicator.repair composes with the engine — handles
    already resolved DRAIN (results stand), pending handles are RE-ISSUED
    on the repaired plans with dead ranks removed and dead roots
    replaced."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    done = eng.issue("allreduce", 1e6)
    eng.wait_all()
    drained = done.result
    pending = eng.issue("bcast", 1e6, root=16)     # root dies below
    sub = eng.issue("bcast", 1e6, root=16, members=tuple(range(16, 32)))
    rep = eng.repair(failed=range(16, 24))
    assert rep.failed == tuple(range(16, 24))
    assert eng.stats().replanned == 2
    assert done.result is drained                   # drained, untouched
    assert pending.root == 0                        # dead root replaced
    assert not set(pending.members) & set(rep.failed)
    assert sub.members == tuple(range(24, 32)) and sub.root == 24
    for r in eng.wait_all([pending, sub]):
        assert all(math.isfinite(t) for t in r.completion.values())
        assert not set(r.completion) & set(rep.failed)


def test_repair_losing_every_member_raises_atomically(fig8):
    """A doomed handle aborts the whole repair BEFORE anything mutates:
    the communicator keeps its members and other pending handles keep
    theirs (no half-repaired engine)."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    other = eng.issue("allreduce", 1e3)
    eng.issue("bcast", 1e3, root=16, members=tuple(range(16, 24)))
    with pytest.raises(ValueError, match="lose every member"):
        eng.repair(failed=range(16, 24))
    assert comm.members == tuple(range(fig8.nprocs))  # untouched
    assert other.members == comm.members
    assert comm.stats().repairs == 0


def test_subset_scatter_sized_by_subset_member_count(fig8):
    """Regression: issue() sized a subset scatter's device operand by the
    PARENT communicator's member count (48) instead of the subset's,
    undershooting the per-rank chunk ~12x (wrong size bucket, wrong
    default priority)."""
    import numpy as np

    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm)
    sub = (0, 1, 2, 16)
    full = np.zeros((4, 10), np.float32)  # the root's [P, ...] buffer
    h = eng.issue("scatter", full, root=0, members=sub)
    assert h.nbytes == full.nbytes / len(sub)
    # gather operands are already per-rank: no division
    g = eng.issue("gather", np.zeros((7,), np.float32), root=0, members=sub)
    assert g.nbytes == 28.0


def test_sim_policy_started_reflects_executed_schedule(fig8):
    """Regression: under "sim", Handle.started was computed from the
    pre-candidate dependency sets — a serial-chained winner reported
    handles as started at release although they queued behind the chain."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    eng = Engine(comm, policy="sim")
    hs = [eng.issue("allreduce", 1e6, members=tuple(range(16 * i, 16 * i + 16)))
          for i in range(2)]
    eng.wait_all()
    chosen = eng.stats().last_policy
    if chosen in ("sim:serial", "sim:serial-sjf"):
        first, second = (hs if chosen == "sim:serial" else
                         sorted(hs, key=lambda h: h.nbytes))
        assert second.started >= first.finished
    for h in hs:  # started is always consistent with the handle's own times
        assert h.started <= h.finished
        assert h.started >= 0.0


# ------------------------------------------------------------------ #
# Span accounting: the monitor's raw material must be complete.
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("policy", ["fifo", "priority", "sim"])
def test_spans_carry_predicted_and_measured(fig8, policy):
    """Every handle's trace span reports predicted_s (isolated plan cost)
    and measured_s (executed span) under every scheduling policy — the
    health monitor's straggler scoring and the feedback loop both read
    these, so a policy that dropped them would silently blind both."""
    from repro.obs import PID_PROGRAMS, Tracer
    tr = Tracer()
    comm = Communicator(fig8, policy="paper", backend="sim", tracer=tr)
    eng = Engine(comm, policy=policy, tracer=tr)
    hs = [eng.issue("allreduce", 1e6),
          eng.issue("bcast", 2e6, root=0, priority=1.0),
          eng.issue("allgather", 5e5, members=tuple(range(16)))]
    eng.wait_all()
    tr.link_records()  # materialize deferred spans
    op_spans = [s for s in tr.spans
                if s[0] == PID_PROGRAMS and "predicted_s" in s[5]]
    assert len(op_spans) == len(hs)
    by_op = {s[5]["op"]: s for s in op_spans}
    for h in hs:
        pid, key, name, t0, t1, args = by_op[h.op]
        assert name == h.op
        assert (t0, t1) == (h.started, h.finished)
        assert args["predicted_s"] > 0.0
        assert args["measured_s"] == pytest.approx(t1 - t0)
        assert args["measured_s"] >= 0.0
        assert args["members"] == len(h.members)


def test_span_timestamps_monotone_across_repair(fig8):
    """Per-track span timestamps stay monotone through Engine.repair:
    post-repair batches are stamped on the same advancing clock, so the
    exported trace (and anything windowing over it) never sees time run
    backwards within a track."""
    from repro.obs import Tracer
    tr = Tracer()
    comm = Communicator(fig8, policy="paper", backend="sim", tracer=tr)
    eng = Engine(comm, tracer=tr)
    eng.issue("allreduce", 1e6)
    eng.issue("bcast", 1e6, root=16)
    eng.wait_all()
    eng.repair(failed=range(16, 24))
    eng.issue("allreduce", 1e6)
    eng.issue("reduce", 2e6, root=0)
    eng.wait_all()
    tr.link_records()
    tracks: dict = {}
    for pid, key, name, t0, t1, args in tr.spans:
        assert t1 >= t0
        tracks.setdefault((pid, key), []).append((t0, t1))
    assert tracks
    for spans in tracks.values():
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a0  # insertion order never rewinds the track
