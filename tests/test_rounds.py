"""Rounds-IR tests: conservation properties of segmented plans, convergence
of segmented simulation to the unsegmented baseline, the per-rank phase
hand-off fix, and the large-message acceptance bar (segmented/bandwidth-
optimal plans >= 2x faster than the unsegmented multilevel plans at 64 MiB
on the paper's Fig. 8 topology, with "auto" picking the right algorithm on
each side of the size crossover)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Communicator
from repro.core import rounds as R
from repro.core import schedule as S
from repro.core.simulator import simulate, simulate_rounds
from repro.core.topology import (Level, Topology, WAN, LAN, SMP,
                                 paper_fig8_topology)
from repro.core.trees import binomial_tree, build_multilevel_tree

MIB = 2.0 ** 20
ALL_OPS = ("bcast", "reduce", "barrier", "gather", "scatter", "allreduce",
           "allgather")


@st.composite
def topologies(draw, uniform_leaves=False):
    """Random 2-strata topologies (sites -> machines -> procs)."""
    sites = draw(st.integers(1, 3))
    uniform = draw(st.integers(1, 4)) if uniform_leaves else None
    coords = []
    mid = 0
    for s in range(sites):
        machines = draw(st.integers(1, 3))
        for m in range(machines):
            procs = uniform if uniform else draw(st.integers(1, 4))
            coords += [[s, mid]] * procs
            mid += 1
    return Topology(np.array(coords), [WAN, LAN, SMP])


def _structural_invariants(low):
    """IR invariants every lowering must satisfy: deps point strictly
    backward, no self-sends, chunk/seg ids in range."""
    for i, snd in enumerate(low.sends):
        assert snd.src != snd.dst, (i, snd)
        assert all(d < i for d in snd.deps), (i, snd)
        assert snd.kind in ("copy", "reduce")
        assert snd.seg is None or 0 <= snd.seg < low.nsegs
        assert snd.nbytes >= 0.0


def _recv_bytes(low):
    # snd.nbytes is wire bytes: a whole chunk for seg=None sends, one
    # segment piece otherwise
    got = {}
    for snd in low.sends:
        if snd.kind == "copy":
            got[snd.dst] = got.get(snd.dst, 0.0) + snd.nbytes
    return got


# ------------------------------------------------------------------ #
# Conservation: every byte exactly once, every fold exactly once.
# ------------------------------------------------------------------ #

@settings(deadline=None, max_examples=30)
@given(topologies(), st.sampled_from(ALL_OPS),
       st.sampled_from([512.0, 64e3, 4 * MIB]),
       st.sampled_from([None, "bdp", 4096.0]), st.data())
def test_tree_lowering_conservation(topo, op, nbytes, seg, data):
    """Tree lowerings of all seven ops deliver every byte exactly once per
    receiver and fold every contribution exactly once — interpret() raises
    on any violation, and the final holdings must match the op's contract."""
    root = data.draw(st.integers(0, topo.nprocs - 1))
    tree = build_multilevel_tree(topo, root)
    low = R.lower(op, "tree", tree, topo, nbytes, segment_bytes=seg)
    _structural_invariants(low)
    R.check_semantics(low)
    if op == "bcast" and topo.nprocs > 1:
        # byte conservation, explicitly: every non-root receives nbytes
        got = _recv_bytes(low)
        for r in tree.members():
            if r != root:
                assert got[r] == pytest.approx(nbytes), r


@settings(deadline=None, max_examples=20)
@given(topologies(), st.sampled_from([512.0, 64e3, 4 * MIB]),
       st.sampled_from([None, "bdp"]), st.data())
def test_sag_lowering_conservation(topo, nbytes, seg, data):
    root = data.draw(st.integers(0, topo.nprocs - 1))
    members = range(topo.nprocs)
    low = R.lower_sag_bcast(topo, root, members, nbytes, seg)
    _structural_invariants(low)
    R.check_semantics(low)
    got = _recv_bytes(low)
    for r in members:
        if r != root:
            assert got[r] == pytest.approx(nbytes), r


@settings(deadline=None, max_examples=20)
@given(topologies(uniform_leaves=True), st.sampled_from([512.0, 4 * MIB]),
       st.sampled_from([None, "bdp"]))
def test_rsag_lowering_conservation(topo, nbytes, seg):
    low = R.lower_rsag_allreduce(topo, range(topo.nprocs), nbytes, seg)
    _structural_invariants(low)
    R.check_semantics(low)


def test_rsag_rejects_non_uniform_leaf_groups():
    coords = np.array([[0, 0]] * 3 + [[0, 1]] * 2)
    topo = Topology(coords, [WAN, LAN, SMP])
    with pytest.raises(ValueError, match="uniform leaf-group sizes"):
        R.lower_rsag_allreduce(topo, range(5), 1e6)
    # forcing the unloweable algorithm is a clear error, not an assert —
    # under both searching and fixed policies, at plan time
    for policy in ("auto", "paper"):
        forced = Communicator(topo, policy=policy, algorithm="rsag")
        with pytest.raises(ValueError, match="no candidate"):
            forced.allreduce(1e6)
    # ...while the unforced search falls back to the tree algorithm
    auto = Communicator(topo, policy="auto")
    assert auto.plan("allreduce", nbytes=1e6).algorithm == "tree"
    assert auto.allreduce(1e6).time > 0


# ------------------------------------------------------------------ #
# Convergence: segmented -> unsegmented as segment size -> nbytes.
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("op", ["bcast", "reduce", "allreduce"])
def test_segmented_sim_converges_to_unsegmented(op):
    topo = paper_fig8_topology()
    tree = build_multilevel_tree(topo, 0)
    nbytes = 4 * MIB
    t_unseg = max(simulate_rounds(
        R.lower(op, "tree", tree, topo, nbytes), topo).values())
    gaps = []
    for seg in (nbytes / 16, nbytes / 4, nbytes):
        low = R.lower(op, "tree", tree, topo, nbytes, segment_bytes=seg)
        R.check_semantics(low)
        t = max(simulate_rounds(low, topo).values())
        gaps.append(abs(t - t_unseg) / t_unseg)
    # shrinking segments only pipeline (never slow the plan down much);
    # coarsening them converges on the whole-message plan, exactly at the end
    assert gaps[0] >= gaps[-1]
    assert gaps[-1] == pytest.approx(0.0, abs=1e-12)
    low1 = R.lower(op, "tree", tree, topo, nbytes, segment_bytes=nbytes)
    assert low1.nsegs == 1
    # ...and the one-segment IR agrees with the whole-message Schedule
    # simulator on the collective's time
    t_sched = max(simulate(getattr(S, op)(tree, nbytes), topo).values())
    t_one = max(simulate_rounds(low1, topo).values())
    assert t_one == pytest.approx(t_sched, rel=5e-3)


def test_segmentation_pipelines_large_messages():
    """The point of the refactor: at large sizes the segmented tree plan
    overlaps the WAN hop of segment k with the LAN/SMP fan-out of earlier
    segments, strictly beating the whole-message plan."""
    topo = paper_fig8_topology()
    tree = build_multilevel_tree(topo, 0)
    nbytes = 64 * MIB
    t_unseg = max(simulate_rounds(
        R.lower("bcast", "tree", tree, topo, nbytes), topo).values())
    t_seg = max(simulate_rounds(
        R.lower("bcast", "tree", tree, topo, nbytes, "bdp"), topo).values())
    assert t_seg < t_unseg


# ------------------------------------------------------------------ #
# Satellite: per-rank phase hand-off in the Schedule simulator.
# ------------------------------------------------------------------ #

def test_phase_handoff_is_per_rank_not_global():
    """The allreduce down phase starts from the ROOT's fold: each rank's
    allreduce completion equals its bcast completion in a broadcast seeded
    at the root's reduce-fold time (joined with the rank's own up-phase
    tail) — a per-rank dependency contract, with no global barrier term in
    it anywhere."""
    topo = paper_fig8_topology()
    tree = build_multilevel_tree(topo, 0)
    nbytes = 256e3
    done = simulate(S.allreduce(tree, nbytes), topo)
    up = simulate(S.reduce(tree, nbytes), topo)
    down = simulate(S.bcast(tree, nbytes), topo, start=up[tree.root])
    assert done[tree.root] == pytest.approx(up[tree.root], rel=1e-12)
    for r in tree.members():
        assert done[r] == pytest.approx(max(down[r], up[r]), rel=1e-12), r


def test_rounds_allreduce_overlaps_phases():
    """At the rounds-IR level the hand-off is per SEGMENT: the root
    broadcasts segment k while leaves still push segment k+1 up, so a
    segmented allreduce strictly beats reduce-then-bcast run back to back."""
    topo = paper_fig8_topology()
    tree = build_multilevel_tree(topo, 0)
    nbytes = 16 * MIB
    t = {op: max(simulate_rounds(
            R.lower(op, "tree", tree, topo, nbytes, "bdp"), topo).values())
         for op in ("allreduce", "reduce", "bcast")}
    assert t["allreduce"] < 0.95 * (t["reduce"] + t["bcast"])


# ------------------------------------------------------------------ #
# Acceptance: the large-message bar on the paper's Fig. 8 topology.
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


def test_auto_selects_algorithm_by_size(fig8):
    comm = Communicator(fig8, policy="auto")
    assert comm.plan("bcast", root=0, nbytes=1024.0).algorithm == "tree"
    assert comm.plan("allreduce", nbytes=1024.0).algorithm == "tree"
    # From an ANL root (the regime Fig. 8 sums over) the small-size argmin
    # lands on the paper's multilevel tree: exactly one WAN crossing.  (From
    # root 0 the oblivious binomial's two *parallel* WAN edges edge it out
    # by the LAN hop — the argmin is honest about that.)
    assert comm.plan("bcast", root=17, nbytes=1024.0).algorithm == "tree"
    assert comm.slow_crossings("bcast", root=17, nbytes=1024.0) == 1
    big_b = comm.plan("bcast", root=0, nbytes=64 * MIB)
    big_a = comm.plan("allreduce", nbytes=64 * MIB)
    assert big_b.algorithm == "sag"
    assert big_a.algorithm == "rsag"


def test_large_message_speedup_at_least_2x(fig8):
    """64 MiB bcast and allreduce: segmented (auto) plans beat the
    unsegmented multilevel plans by >= 2x simulated time."""
    nbytes = 64 * MIB
    auto = Communicator(fig8, policy="auto")
    paper = Communicator(fig8, policy="paper")  # unsegmented multilevel
    for op in ("bcast", "allreduce"):
        t_paper = (paper.bcast(nbytes, root=0) if op == "bcast"
                   else paper.allreduce(nbytes)).time
        t_auto = (auto.bcast(nbytes, root=0) if op == "bcast"
                  else auto.allreduce(nbytes)).time
        assert t_paper / t_auto >= 2.0, (op, t_paper, t_auto)
        # and the winning plans are semantically sound
        plan = auto.plan(op, root=0 if op == "bcast" else None,
                         nbytes=nbytes)
        R.check_semantics(plan.lower(nbytes))


def test_explicit_knobs_override_policy(fig8):
    nbytes = 64 * MIB
    forced = Communicator(fig8, policy="paper", algorithm="sag",
                          segment_bytes="bdp")
    assert forced.plan("bcast", root=0, nbytes=nbytes).algorithm == "sag"
    off = Communicator(fig8, policy="auto", segment_bytes="off",
                       algorithm="tree")
    plan = off.plan("bcast", root=0, nbytes=nbytes)
    assert plan.algorithm == "tree" and plan.segment is None
    assert plan.lower(nbytes).nsegs == 1


# ------------------------------------------------------------------ #
# Device execution of the lowered IR (8 emulated devices).
# ------------------------------------------------------------------ #

def test_lowered_sag_rsag_on_devices(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import Communicator
from repro.core import rounds as R
from repro.core.topology import tpu_v5e_multipod

# shrink the chunk floor so tiny test payloads still exercise multi-chunk
# sag/rsag programs on device
R.MIN_CHUNK_BYTES = 1.0

topo = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
mesh = jax.make_mesh((8,), ("all",))
x = np.arange(8.0, dtype=np.float32)

for algorithm, op, want in [("sag", "bcast", np.full(8, 3.0)),
                            ("rsag", "allreduce", np.full(8, 28.0)),
                            (None, "bcast", np.full(8, 3.0)),
                            (None, "allreduce", np.full(8, 28.0))]:
    comm = Communicator(topo, policy="paper", backend="ppermute",
                        axis="all", algorithm=algorithm)
    fn = (lambda v: comm.bcast(v, root=3)) if op == "bcast" else \
         (lambda v: comm.allreduce(v))
    out = np.asarray(jax.jit(shard_map(fn, mesh=mesh, in_specs=P("all"),
                                       out_specs=P("all")))(jnp.asarray(x)))
    np.testing.assert_allclose(out, want.astype(np.float32), rtol=1e-6)
    if algorithm is not None:  # the forced plans really were multi-chunk
        plan = comm.plan(op, root=3 if op == "bcast" else None, nbytes=4.0)
        assert plan.algorithm == algorithm
        assert plan.lower(4.0).nchunks > 1, (algorithm, op)
print("OK")
""")
