"""Tests for live health monitoring: contention deconvolution, unbiased
feedback from contended traces, the online HealthMonitor (drift detection,
targeted re-probe, mid-run refit with plan-cache invalidation, straggler
scoring, SLO windows), metrics export, and the benchmark history gate."""
import dataclasses
import os
import sys
import types

import pytest

from repro.core import Communicator, discovery as D
from repro.core.engine import Engine
from repro.core.simulator import simulate_rounds
from repro.core.topology import paper_fig8_topology
from repro.obs import (FeedbackLoop, HealthMonitor, Histogram,
                       MetricsRegistry, Tracer, deconvolve, occupancy)

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks"))
import bench_schema  # noqa: E402

MIB = float(1 << 20)


def _wan_scaled(factor):
    t = paper_fig8_topology()
    t.levels = tuple(
        dataclasses.replace(l, bandwidth=l.bandwidth * factor)
        if l.name == "wan" else l for l in t.levels)
    return t


def _wan_index(topo):
    return next(i for i, l in enumerate(topo.levels) if l.name == "wan")


# link tuple: (src, dst, level, t0, t1, nbytes, kind, first, label,
#              flow_end, gid)
def _rec(src, dst, t0, flow_end, *, t1=None, lvl=0, nb=1.0, first=True,
         gid=1):
    return (src, dst, lvl, t0, flow_end if t1 is None else t1, nb,
            "send", first, "t", flow_end, gid)


# ------------------------------------------------------------------ #
# Contention deconvolution on synthetic and lone traces.
# ------------------------------------------------------------------ #

def test_deconvolve_noop_on_lone_trace():
    """A lone simulate_rounds program never self-overlaps on an edge, so
    deconvolution must return exactly the traced samples — the two
    feedback feeding paths agree on uncontended traffic."""
    topo = paper_fig8_topology()
    comm = Communicator(topo, policy="auto", backend="sim")
    tr = Tracer()
    prog = comm.plan("allreduce", nbytes=MIB).lower(MIB)
    simulate_rounds(prog, topo, tracer=tr)
    assert deconvolve(tr) == tr.link_samples()


def test_deconvolve_fair_sharing_exact():
    """Two flows splitting one directed edge: each elementary segment is
    charged 1/occupancy, recovering the isolated streaming time exactly."""
    # full overlap on [0, 2): each held half the link -> 1.0s alone
    full = [_rec(0, 1, 0.0, 2.0), _rec(0, 1, 0.0, 2.0)]
    assert [s[3] for s in deconvolve(full)] == [1.0, 1.0]
    # partial: A flows [0,3), B [1,2) -> A = 1 + 0.5 + 1, B = 0.5
    part = [_rec(0, 1, 0.0, 3.0), _rec(0, 1, 1.0, 2.0)]
    assert [round(s[3], 12) for s in deconvolve(part)] == [2.5, 0.5]
    # the observed latency tail (t1 - flow_end) is added back untouched
    tail = [_rec(0, 1, 0.0, 2.0, t1=2.25), _rec(0, 1, 0.0, 2.0)]
    assert [round(s[3], 12) for s in deconvolve(tail)] == [1.25, 1.0]


def test_deconvolve_couples_only_same_group_and_edge():
    """Bandwidth is shared per (sharing group, directed edge): records in
    different simulator invocations, on different edges, or on opposite
    directions of one edge never stretch each other."""
    recs = [_rec(0, 1, 0.0, 2.0, gid=1), _rec(0, 1, 0.0, 2.0, gid=2),
            _rec(1, 0, 0.0, 2.0, gid=1), _rec(2, 3, 0.0, 2.0, gid=1)]
    assert [s[3] for s in deconvolve(recs)] == [2.0] * 4


def test_occupancy_summary():
    rows = occupancy([_rec(0, 1, 0.0, 2.0), _rec(0, 1, 0.0, 2.0),
                      _rec(2, 3, 0.0, 1.0)])
    assert rows[0]["n"] == 3
    assert rows[0]["transfer_s"] == pytest.approx(5.0)
    assert rows[0]["busy_s"] == pytest.approx(3.0)  # union per edge
    assert rows[0]["mean_overlap"] == pytest.approx(5.0 / 3.0)


# ------------------------------------------------------------------ #
# Contended feedback: unbiased refit from a busy engine window.
# ------------------------------------------------------------------ #

def _busy_trace(model, truth, reps=1):
    """Overlapping member sets so transfers genuinely share WAN edges."""
    comm = Communicator(model, backend="sim", policy="auto")
    tr = Tracer()
    eng = Engine(comm, policy="fifo", truth=truth, tracer=tr)
    sets = [tuple(range(48)), tuple(range(0, 32)), tuple(range(16, 48)),
            tuple(range(0, 16)) + tuple(range(32, 48))]
    for _ in range(reps):
        for i, mem in enumerate(sets):
            eng.issue("allreduce", (1 + i) * MIB, members=mem)
            eng.issue("bcast", 2 * MIB, members=mem, root=mem[0])
        eng.wait_all()
    return tr


def _regret(comm, truth, nbytes=16 * MIB):
    low = comm.plan("allreduce", nbytes=nbytes).lower(nbytes)
    t = max(simulate_rounds(low, truth).values())
    oracle = Communicator(truth, policy=comm.policy, backend="sim")
    best = oracle.plan("allreduce", nbytes=nbytes).lower(nbytes)
    return t / max(simulate_rounds(best, truth).values()) - 1.0


def test_contended_feedback_recovers_wan():
    """The ISSUE acceptance: deconvolved residuals from a contended
    multi-program trace drive refit_levels to the same WAN fit a lone
    collective yields, taking true-network plan regret from >=10% to
    <=2%; the biased control (no deconvolution) misfits the same trace."""
    truth = paper_fig8_topology()
    wan = _wan_index(truth)
    tr = _busy_trace(_wan_scaled(8.0), truth)
    assert occupancy(tr)[wan]["mean_overlap"] > 1.05  # really contended

    comm = Communicator(_wan_scaled(8.0), backend="sim", policy="auto")
    fb = FeedbackLoop(comm, threshold=0.15)
    pre = _regret(comm, truth)
    assert pre >= 0.10
    fb.observe_trace(tr)
    assert fb.maybe_refit().refit
    assert _regret(comm, truth) <= 0.02
    fitted = comm.topo.levels[wan].bandwidth
    assert fitted == pytest.approx(truth.levels[wan].bandwidth, rel=1e-6)

    # lone-collective reference: the contended fit agrees with it
    comm2 = Communicator(_wan_scaled(8.0), backend="sim", policy="auto")
    fb2 = FeedbackLoop(comm2, threshold=0.15)
    fb2.run("allreduce", 16 * MIB, truth=truth)
    fb2.maybe_refit()
    assert fitted == pytest.approx(comm2.topo.levels[wan].bandwidth,
                                   rel=1e-6)

    # control: the SAME trace without deconvolution fits a biased WAN
    comm3 = Communicator(_wan_scaled(8.0), backend="sim", policy="auto")
    fb3 = FeedbackLoop(comm3, threshold=0.15)
    fb3.observe_trace(tr, deconvolve=False)
    fb3.maybe_refit()
    biased = comm3.topo.levels[wan].bandwidth
    assert abs(biased / truth.levels[wan].bandwidth - 1.0) > 0.10


# ------------------------------------------------------------------ #
# HealthMonitor: construction, drift detection, refit, plan caches.
# ------------------------------------------------------------------ #

def test_monitor_ctor_validation():
    comm = Communicator(paper_fig8_topology(), backend="sim")
    with pytest.raises(ValueError, match="threshold"):
        HealthMonitor(comm, threshold=0.0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        HealthMonitor(comm, ewma_alpha=1.5)
    with pytest.raises(ValueError, match="positive"):
        HealthMonitor(comm, window=0)
    with pytest.raises(ValueError, match="communicator or engine"):
        HealthMonitor()
    view = Communicator(paper_fig8_topology(), backend="sim",
                        view=paper_fig8_topology())
    with pytest.raises(ValueError, match="view"):
        HealthMonitor(view)
    assert HealthMonitor(view, refit=False).refit is False
    eng = Engine(comm)
    other = Communicator(paper_fig8_topology(), backend="sim")
    with pytest.raises(ValueError, match="disagree"):
        HealthMonitor(other, engine=eng)


def test_monitor_attaches_to_engine():
    comm = Communicator(paper_fig8_topology(), backend="sim")
    eng = Engine(comm)
    assert eng.tracer is None
    mon = HealthMonitor(engine=eng)
    assert eng.monitor is mon and mon.comm is comm
    assert eng.tracer is not None and mon.tracer is eng.tracer


def test_monitor_detects_drift_and_refits_feedback_path():
    """Mis-modeled WAN under live engine traffic: the monitor's windowed
    deconvolved residuals trip the detector, the passive refit rewrites
    the WAN class, and EVERY plan cache — main communicator and the
    engine's per-subset communicators — re-points at the new topology."""
    truth = paper_fig8_topology()
    comm = Communicator(_wan_scaled(8.0), backend="sim", policy="auto")
    eng = Engine(comm, policy="fifo", truth=truth)
    mon = HealthMonitor(engine=eng, threshold=0.25, min_samples=4,
                        check_every=1)
    sub = tuple(range(0, 24))
    before = comm.topo
    eng.issue("allreduce", 4 * MIB)
    eng.issue("allreduce", 2 * MIB, members=sub)
    eng.wait_all()
    events = mon.check()
    kinds = [ev.kind for ev in events]
    assert "drift" in kinds and "refit" in kinds
    drift = next(ev for ev in events if ev.kind == "drift")
    assert drift.detail["name"] == "wan" and drift.detail["ratio"] > 1.25
    refit = next(ev for ev in events if ev.kind == "refit")
    assert refit.detail["via"] == "feedback"
    assert mon.refits == 1
    assert comm.topo is not before
    wan = _wan_index(truth)
    assert comm.topo.levels[wan].bandwidth == pytest.approx(
        truth.levels[wan].bandwidth, rel=1e-6)
    # the engine's subset communicator was re-pointed and its cache
    # invalidated (refresh_plans) — the next flush replans on new costs
    assert eng._comm_for(sub).topo is comm.topo
    # residual windows reset: post-refit traffic judged against the new
    # model raises no further alarms
    eng.issue("allreduce", 4 * MIB)
    eng.wait_all()
    assert mon.check() == []
    assert mon.refits == 1


def test_monitor_targeted_probe_path():
    """With a probe callable, drift triggers a re-probe SCOPED to the
    implicated link class and applies it via Communicator.refresh."""
    truth = paper_fig8_topology()
    comm = Communicator(_wan_scaled(8.0), backend="sim", policy="auto")
    eng = Engine(comm, policy="fifo", truth=truth)
    wan = _wan_index(truth)
    seen = []

    def probe(pairs):
        seen.extend(pairs)
        return D.targeted_probes(truth, pairs)

    mon = HealthMonitor(engine=eng, threshold=0.25, min_samples=4,
                        check_every=1, probe=probe)
    eng.issue("allreduce", 4 * MIB)
    eng.wait_all()
    events = mon.check()
    refit = next(ev for ev in events if ev.kind == "refit")
    assert refit.detail["via"] == "probe"
    assert seen and all(p[2] == wan for p in seen)
    assert comm.topo.levels[wan].bandwidth == pytest.approx(
        truth.levels[wan].bandwidth, rel=1e-6)


def test_monitor_no_false_alarm_under_contention():
    """A CALIBRATED model under heavily contended traffic must not drift:
    deconvolution is what keeps busy-engine residuals unbiased."""
    truth = paper_fig8_topology()
    comm = Communicator(paper_fig8_topology(), backend="sim",
                        policy="auto")
    eng = Engine(comm, policy="fifo", truth=truth)
    mon = HealthMonitor(engine=eng, threshold=0.25, min_samples=4,
                        check_every=1)
    sets = [tuple(range(48)), tuple(range(0, 32)), tuple(range(16, 48))]
    for mem in sets:
        eng.issue("allreduce", 4 * MIB, members=mem)
    eng.wait_all()
    assert mon.check() == []
    assert mon.refits == 0
    for ratio in mon.drift().values():
        assert ratio == pytest.approx(1.0, abs=0.05)


def test_monitor_observe_only():
    """refit=False: drift is reported but nothing is rewritten."""
    truth = paper_fig8_topology()
    comm = Communicator(_wan_scaled(8.0), backend="sim", policy="auto")
    eng = Engine(comm, policy="fifo", truth=truth)
    mon = HealthMonitor(engine=eng, threshold=0.25, min_samples=4,
                        check_every=1, refit=False)
    before = comm.topo
    eng.issue("allreduce", 4 * MIB)
    eng.wait_all()
    events = mon.check()
    assert [ev.kind for ev in events] == ["drift"]
    assert comm.topo is before and mon.refits == 0


# ------------------------------------------------------------------ #
# Straggler scoring and the rolling request window.
# ------------------------------------------------------------------ #

def _handle(members, factor, pred, nbytes):
    return types.SimpleNamespace(op="allreduce", root=None, nbytes=nbytes,
                                 members=tuple(members), started=0.0,
                                 finished=factor * pred)


def test_straggler_scoring_flags_and_recovers():
    comm = Communicator(paper_fig8_topology(), backend="sim",
                        policy="auto")
    mon = HealthMonitor(comm, straggler_factor=2.0, refit=False)
    nb = MIB
    prog = comm.plan("allreduce", nbytes=nb).lower(nb)
    pred = max(simulate_rounds(prog, comm.topo).values())
    normal = [_handle((r, r + 1), 1.0, pred, nb) for r in (0, 2, 4)]
    slow = [_handle((6, 7), 5.0, pred, nb)]
    mon.observe_handles(normal + slow)
    events = mon.check()
    assert sorted(ev.detail["rank"] for ev in events
                  if ev.kind == "straggler") == [6, 7]
    assert list(mon.stragglers())[:2] in ([6, 7], [7, 6])
    assert set(mon.snapshot()["stragglers"]) == {6, 7}
    # the EWMA decays once the ranks behave; the flags clear, silently
    for _ in range(6):
        mon.observe_handles([_handle((6, 7), 1.0, pred, nb)])
    mon.check()
    assert mon.snapshot()["stragglers"] == {}
    assert not any(ev.kind == "straggler" for ev in list(mon.events)[1:]
                   if ev.detail.get("rank") in (6, 7)
                   and ev.step > events[0].step)


def _req(state, ttft=None, tpot=None):
    return types.SimpleNamespace(state=types.SimpleNamespace(name=state),
                                 ttft=ttft, tpot=tpot)


def test_request_window_and_snapshot():
    comm = Communicator(paper_fig8_topology(), backend="sim")
    mon = HealthMonitor(comm, window=4, refit=False)
    for i in range(8):
        mon.observe_request(_req("DONE", ttft=0.1 * (i + 1), tpot=0.01))
    mon.observe_request(_req("SHED"))
    mon.observe_request(_req("SHED"), evicted=True)
    mon.on_step(now=1.5, step=3)
    s = mon.snapshot()
    req = s["requests"]
    assert (req["n_done"], req["n_shed"], req["n_evicted"]) == (8, 2, 1)
    # the window holds only the last 4 outcomes: DONE DONE SHED SHED
    assert req["shed_rate"] == pytest.approx(0.5)
    # and the last 4 TTFTs: 0.5..0.8
    assert req["ttft"]["p50"] == pytest.approx(0.65)
    assert s["step"] == 3 and s["now"] == 1.5
    assert s["checks"] == 0 and s["events"] == []


def test_on_step_checks_every_n():
    comm = Communicator(paper_fig8_topology(), backend="sim")
    mon = HealthMonitor(comm, check_every=4, refit=False)
    for i in range(9):
        mon.on_step(now=float(i), step=i)
    assert mon.snapshot()["checks"] == 2


# ------------------------------------------------------------------ #
# Metrics: bounded histogram window, Prometheus exposition.
# ------------------------------------------------------------------ #

def test_histogram_window_bounds_memory():
    h = Histogram("x", window=100)
    for i in range(10_000):
        h.observe(float(i))
    assert h.count == 100
    assert len(h.samples) == 100
    # digests reflect the window, not the discarded history
    assert h.percentile(0) >= 9900.0
    assert Histogram("y").window == Histogram.DEFAULT_WINDOW
    unbounded = Histogram("z", window=None)
    for i in range(10_000):
        unbounded.observe(float(i))
    assert unbounded.count == 10_000
    with pytest.raises(ValueError, match="window"):
        Histogram("w", window=0)


def test_registry_histogram_window_conflicts():
    reg = MetricsRegistry()
    h = reg.histogram("lat", window=64)
    assert reg.histogram("lat") is h  # unspecified window: no conflict
    assert reg.histogram("lat", window=64) is h
    with pytest.raises(ValueError, match="window"):
        reg.histogram("lat", window=128)


def test_to_prometheus_format():
    reg = MetricsRegistry()
    reg.counter("sched.steps").inc(3)
    reg.gauge("monitor.worst_drift").set(0.25)
    h = reg.histogram("req.ttft", window=16)
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert text.endswith("\n")
    assert "# TYPE monitor_worst_drift gauge" in lines
    assert "# TYPE req_ttft summary" in lines
    assert "# TYPE sched_steps counter" in lines
    assert "sched_steps 3" in lines
    assert "monitor_worst_drift 0.25" in lines
    assert 'req_ttft{quantile="0.5"}' in " ".join(lines)
    assert "req_ttft_count 4" in lines
    sum_line = next(l for l in lines if l.startswith("req_ttft_sum "))
    assert float(sum_line.split()[1]) == pytest.approx(1.0)
    # exposition grammar: every non-comment line is `name[{labels}] value`
    for line in lines:
        if line.startswith("#"):
            continue
        name, value = line.rsplit(" ", 1)
        assert name and not name[0].isdigit()
        float(value)  # parses as a number (or raises)
    # an empty registry exposes nothing, not a lone newline
    assert MetricsRegistry().to_prometheus() == ""


# ------------------------------------------------------------------ #
# Benchmark history gate (bench_schema --history).
# ------------------------------------------------------------------ #

def test_history_compare_gates():
    hist = {"BENCH_engine.json": {"speedup": 1.6, "passed": True},
            "BENCH_monitor.json": {"post_refit_regret": 0.0,
                                   "detection_latency_steps": 6,
                                   "passed": True}}
    ok = {"BENCH_engine.json": {"speedup": 1.58, "passed": True},
          "BENCH_monitor.json": {"post_refit_regret": 0.01,
                                 "detection_latency_steps": 7,
                                 "passed": True}}
    assert bench_schema.compare_history(hist, ok) == []
    # a "high" metric collapsing, a "low" metric growing past slack,
    # and a boolean flipping all fail
    bad = {"BENCH_engine.json": {"speedup": 1.2, "passed": True},
           "BENCH_monitor.json": {"post_refit_regret": 0.08,
                                  "detection_latency_steps": 6,
                                  "passed": False}}
    msgs = bench_schema.compare_history(hist, bad)
    assert len(msgs) == 3
    assert any("speedup" in m for m in msgs)
    assert any("post_refit_regret" in m for m in msgs)
    assert any("passed: True -> False" in m for m in msgs)
    # new artifacts / metrics absent from history are not regressions
    assert bench_schema.compare_history({}, ok) == []
    assert bench_schema.compare_history(
        hist, {"BENCH_new.json": {"passed": True}}) == []


def test_history_file_matches_committed_artifacts():
    """The committed BENCH_history.json must agree with the committed
    artifacts' headlines — the CI gate runs exactly this comparison."""
    import json
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, bench_schema.HISTORY_FILE)
    assert os.path.exists(path), "seed with bench_schema.py --history --update"
    with open(path) as f:
        history = json.load(f)["headlines"]
    current = bench_schema.collect_headlines(root)
    assert bench_schema.compare_history(history, current) == []
    # every gated artifact is covered by the snapshot
    for artifact in bench_schema.HISTORY_GATES:
        assert artifact in history
