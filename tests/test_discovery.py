"""Property tests for the topology discovery subsystem.

The contract under test (ISSUE 3 acceptance): from simulated probes with up
to 10% multiplicative noise, the clusterer recovers the EXACT stratum
partition of both canned topologies; the fitted levels reproduce the ground
truth at zero noise; persistence round-trips canonicalised coords + levels;
and plans built on a discovered topology cost within 5% of ground-truth
plans when charged on the true network.
"""
import math
import types

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Communicator
from repro.core.discovery import (DEFAULT_PROBE_SIZES, ProbeSet,
                                  cluster_probes, discover,
                                  environment_topology, fit_levels,
                                  fit_topology, simulated_probes)
from repro.core.simulator import probe_time, simulate_rounds
from repro.core.topology import (LAN, SMP, WAN, Level, Topology,
                                 paper_fig8_topology, tpu_v5e_multipod)


# ---------------------------------------------------------------------- #
# helpers
# ---------------------------------------------------------------------- #

def same_partition(a, b) -> bool:
    """True iff two label vectors induce the identical equivalence classes
    (labels may differ — only the grouping matters)."""
    a, b = np.asarray(a), np.asarray(b)
    joint = len(np.unique(np.stack([a, b], axis=1), axis=0))
    return joint == len(np.unique(a)) == len(np.unique(b))


def assert_exact_strata(truth: Topology, disc: Topology):
    assert disc.nprocs == truth.nprocs
    assert disc.nstrata == truth.nstrata, (
        f"expected {truth.nstrata} strata, discovered {disc.nstrata}")
    for l in range(truth.nstrata):
        assert same_partition(truth.coords[:, l], disc.coords[:, l]), \
            f"stratum {l} partition differs"


# ---------------------------------------------------------------------- #
# recovery: the clusterer finds the exact strata
# ---------------------------------------------------------------------- #

def test_noiseless_recovery_is_exact_fig8():
    truth = paper_fig8_topology()
    disc = fit_topology(simulated_probes(truth, noise=0.0))
    assert_exact_strata(truth, disc)
    # with the injection-rate probe the postal parameters come back exactly
    for got, want in zip(disc.levels, truth.levels):
        assert got.latency == pytest.approx(want.latency, rel=1e-9)
        assert got.bandwidth == pytest.approx(want.bandwidth, rel=1e-9)
        assert got.overhead == pytest.approx(want.overhead, rel=1e-9)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.0, 0.10), st.integers(0, 2 ** 16))
def test_fig8_partition_recovered_under_noise(noise, seed):
    truth = paper_fig8_topology()
    disc = fit_topology(simulated_probes(truth, noise=noise, seed=seed))
    assert_exact_strata(truth, disc)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_tpu_v5e_multipod_partition_recovered_at_10pct(seed):
    truth = tpu_v5e_multipod()  # 512 chips, the perf-relevant scale
    disc = fit_topology(simulated_probes(truth, noise=0.10, seed=seed))
    assert_exact_strata(truth, disc)


def test_homogeneous_network_discovers_zero_strata():
    """No cost gaps -> no strata: one link class, and the communicator
    still plans/executes on the flat result (the paper's degenerate case)."""
    truth = Topology(np.zeros((8, 1)), [SMP, SMP])
    disc = fit_topology(simulated_probes(truth, noise=0.05, seed=7))
    assert disc.nstrata == 0
    assert len(disc.levels) == 1
    t = Communicator(disc, policy="auto").bcast(4e3, root=0).time
    assert t > 0


def test_probes_match_simulator_probe_time():
    """The vectorised probe matrix IS the simulator's scalar probe
    semantics, pairwise."""
    topo = paper_fig8_topology()
    p = simulated_probes(topo, noise=0.0)
    for a, b in [(0, 1), (0, 17), (0, 47), (20, 40)]:
        for k, s in enumerate(p.sizes):
            assert p.times[a, b, k] == pytest.approx(
                probe_time(topo, a, b, s), rel=1e-12)


def test_probeset_validates_shapes():
    with pytest.raises(ValueError):
        ProbeSet(sizes=(1e3, 1e6), times=np.zeros((4, 4)))
    with pytest.raises(ValueError):
        ProbeSet(sizes=(1e6, 1e3), times=np.zeros((4, 4, 2)))
    with pytest.raises(ValueError):
        ProbeSet(sizes=(1e3, 1e6), times=np.zeros((4, 4, 2)),
                 inject=np.zeros((3, 3)))


# ---------------------------------------------------------------------- #
# persistence + canonicalisation
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("topo", [
    paper_fig8_topology(),
    tpu_v5e_multipod(pods=2, boards=4, chips_per_board=4),
], ids=["fig8", "tpu"])
def test_json_roundtrip(topo):
    back = Topology.from_json(topo.to_json())
    assert np.array_equal(back.coords, topo.coords)
    assert back.levels == topo.levels


def test_json_roundtrip_of_discovered_topology():
    disc = fit_topology(simulated_probes(paper_fig8_topology(),
                                         noise=0.08, seed=11))
    back = Topology.from_json(disc.to_json())
    assert np.array_equal(back.coords, disc.coords)
    assert back.levels == disc.levels


def test_json_roundtrip_zero_strata():
    topo = Topology(np.zeros((4, 0), dtype=np.int64), [SMP])
    back = Topology.from_json(topo.to_json())
    assert back.coords.shape == (4, 0)
    assert back.levels == topo.levels


@st.composite
def random_topologies(draw):
    sites = draw(st.integers(1, 4))
    coords, mid = [], 0
    for s in range(sites):
        for _ in range(draw(st.integers(1, 3))):
            coords += [[s, mid]] * draw(st.integers(1, 4))
            mid += 1
    return Topology(np.array(coords), [WAN, LAN, SMP])


@settings(max_examples=25, deadline=None)
@given(random_topologies())
def test_canonicalisation_is_idempotent(topo):
    again = Topology(topo.coords, topo.levels)
    assert np.array_equal(again.coords, topo.coords)
    # and a json round-trip of the canonical form is the identity
    back = Topology.from_json(topo.to_json())
    assert np.array_equal(back.coords, topo.coords)


@settings(max_examples=10, deadline=None)
@given(random_topologies())
def test_comm_level_matrix_matches_scalar(topo):
    lm = topo.comm_level_matrix()
    assert lm.shape == (topo.nprocs, topo.nprocs)
    for p in range(topo.nprocs):
        for q in range(topo.nprocs):
            if p == q:
                assert lm[p, q] == topo.nstrata
            else:
                diff = np.nonzero(topo.coords[p] != topo.coords[q])[0]
                want = int(diff[0]) if diff.size else topo.nstrata
                assert lm[p, q] == want == topo.comm_level(p, q)
    with pytest.raises(ValueError):
        topo.comm_level(0, 0)


# ---------------------------------------------------------------------- #
# the Fast-Tuning cache
# ---------------------------------------------------------------------- #

def test_discover_persists_and_reloads(tmp_path):
    truth = paper_fig8_topology()
    path = str(tmp_path / "fleet.topo.json")
    first = discover("sim", topo=truth, noise=0.05, seed=3, path=path)
    # second call must NOT re-probe: hand it a different ground truth and
    # check the cached fit comes back
    other = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
    cached = discover("sim", topo=other, path=path)
    assert np.array_equal(cached.coords, first.coords)
    assert cached.levels == first.levels
    refreshed = discover("sim", topo=other, path=path, refresh=True)
    assert refreshed.nprocs == other.nprocs


def test_from_probes_uses_cache_path(tmp_path):
    path = str(tmp_path / "fleet.topo.json")
    paper_fig8_topology().save(path)
    probes = simulated_probes(
        tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2))
    comm = Communicator.from_probes(probes, path=path, policy="paper")
    assert comm.topo.nprocs == 48  # loaded fig8, probes never consulted
    comm2 = Communicator.from_probes(probes, path=path, refresh=True,
                                     policy="paper")
    assert comm2.topo.nprocs == 8  # refitted and re-persisted
    assert Topology.load(path).nprocs == 8


# ---------------------------------------------------------------------- #
# environment probes
# ---------------------------------------------------------------------- #

def _fake_device(process_index, slice_index=None, platform="cpu"):
    return types.SimpleNamespace(process_index=process_index,
                                 slice_index=slice_index, platform=platform)


def test_environment_topology_two_strata():
    devs = [_fake_device(process_index=i // 2, slice_index=i // 4)
            for i in range(8)]
    topo = environment_topology(devs)
    assert topo.nstrata == 2  # [slice, process]
    assert same_partition(topo.coords[:, 0], [i // 4 for i in range(8)])
    assert same_partition(topo.coords[:, 1], [i // 2 for i in range(8)])


def test_environment_topology_drops_constant_strata():
    devs = [_fake_device(process_index=i // 2) for i in range(8)]
    topo = environment_topology(devs)
    assert topo.nstrata == 1  # slice column constant -> dropped
    single = environment_topology([_fake_device(0) for _ in range(4)])
    assert single.nstrata == 0  # one host: flat, one link class
    assert len(single.levels) == 1


def test_device_probes_on_host_mesh(subproc):
    """End-to-end timed probes on a forced 2-device host platform: the
    matrix is fully populated, positive, and feeds the fitting pipeline
    (host 'links' are homogeneous, so no strata should appear)."""
    out = subproc("""
from repro.core.discovery import device_probes, fit_topology
p = device_probes(repeats=1, roundtrips=2, sizes=(1024.0, 65536.0))
assert p.times.shape == (2, 2, 2), p.times.shape
assert (p.times[0, 1] > 0).all() and (p.times[1, 0] > 0).all()
t = fit_topology(p)
assert t.nprocs == 2 and t.nstrata == 0, (t.nprocs, t.nstrata)
print("DEVICE_PROBES_OK")
""", n_devices=2)
    assert "DEVICE_PROBES_OK" in out


def test_environment_topology_tpu_levels():
    devs = [_fake_device(process_index=i // 4, slice_index=i // 8,
                         platform="tpu") for i in range(16)]
    topo = environment_topology(devs)
    assert [l.name for l in topo.levels] == ["dcn", "ici_far", "ici"]


# ---------------------------------------------------------------------- #
# plan quality: discovered topologies steer plans as well as the truth
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("noise,seed", [(0.0, 0), (0.10, 3), (0.10, 9)])
def test_from_probes_plan_regret_within_5pct(noise, seed):
    truth = paper_fig8_topology()
    comm_true = Communicator(truth, policy="auto")
    comm_disc = Communicator.from_probes(
        simulated_probes(truth, noise=noise, seed=seed), policy="auto")
    for op in ("bcast", "allreduce"):
        for k in (10, 14, 18, 22, 26):  # 1 KiB .. 64 MiB
            nb = float(1 << k)
            t_true = max(simulate_rounds(
                comm_true.plan(op, root=0, nbytes=nb).lower(nb),
                truth).values())
            t_disc = max(simulate_rounds(
                comm_disc.plan(op, root=0, nbytes=nb).lower(nb),
                truth).values())
            assert t_disc <= t_true * 1.05, (
                f"{op} @ {nb:.0f}B: discovered plan {t_disc:.6f}s vs "
                f"ground truth {t_true:.6f}s")


def test_fitted_levels_average_out_noise():
    """Per-level parameters are fitted over O(P^2) pairs, so 10% per-pair
    noise shrinks to ~1% on the class estimate (the reason plan regret
    stays within tolerance)."""
    truth = paper_fig8_topology()
    disc = fit_topology(simulated_probes(truth, noise=0.10, seed=5))
    for got, want in zip(disc.levels, truth.levels):
        assert got.latency == pytest.approx(want.latency, rel=0.05)
        assert got.bandwidth == pytest.approx(want.bandwidth, rel=0.05)
