"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,Sq,Sk,Hkv,G,hd,dt", [
    (2, 256, 256, 2, 2, 64, jnp.float32),
    (1, 512, 512, 1, 4, 128, jnp.bfloat16),
    (2, 256, 256, 4, 1, 64, jnp.float32),
    (1, 256, 256, 2, 2, 128, jnp.bfloat16),
    (1, 128, 128, 1, 1, 64, jnp.float32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_matches_oracle(B, Sq, Sk, Hkv, G, hd, dt, causal):
    H = Hkv * G
    q = jax.random.normal(KEY, (B, Sq, H, hd), dt)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Sk, Hkv, hd), dt)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Sk, Hkv, hd), dt)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    o_ref = ref.mha_reference(q, k, v, causal=causal)
    tol = 2e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_kernel_sliding_window(window):
    B, S, Hkv, G, hd = 1, 256, 2, 2, 64
    q = jax.random.normal(KEY, (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            block_q=128, block_k=128)
    o_ref = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=3e-5)


def test_flash_kernel_matches_model_flash_vjp_fwd():
    """The jnp custom-VJP flash in models.layers and the Pallas kernel are
    the same algorithm — cross-validate them directly."""
    from repro.models.layers import _flash
    B, S, Hkv, G, hd = 1, 256, 2, 2, 64
    q = jax.random.normal(KEY, (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, Hkv, hd), jnp.float32)
    o_pallas = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    o_jnp = _flash(q, k, v, True, None, 128, 128, 0)
    np.testing.assert_allclose(np.asarray(o_pallas), np.asarray(o_jnp), atol=3e-5)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 6), st.floats(0.1, 100.0))
def test_quant_roundtrip_error_bound(ntiles, scale):
    """Property: blockwise int8 roundtrip error <= amax/127 per block half-ulp."""
    n = 256 * 32 * ntiles
    x = np.asarray(jax.random.normal(KEY, (n,), jnp.float32)) * scale
    q, s, pad = ops.quantize_int8(jnp.asarray(x))
    xd = np.asarray(ops.dequantize_int8(q, s, pad))
    blocks = x.reshape(-1, 256)
    bound = np.abs(blocks).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-9
    assert (np.abs(xd.reshape(-1, 256) - blocks) <= bound + 1e-6).all()


def test_quant_matches_reference_exactly():
    x = jax.random.normal(KEY, (256 * 32 * 2,), jnp.float32) * 5
    q, s, pad = ops.quantize_int8(x)
    qr, sr = ref.quantize_int8_reference(x)
    assert pad == 0
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quant_padding_path():
    x = jax.random.normal(KEY, (1000,), jnp.float32)
    q, s, pad = ops.quantize_int8(x)
    assert pad == 256 * 32 - 1000
    xd = ops.dequantize_int8(q, s, pad)
    assert xd.shape == (1000,)
    assert float(jnp.abs(xd - x).max()) < 0.05


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 16), (1, 64, 1, 64, 16),
])
def test_wkv_kernel_matches_chunk_scan(B, S, H, hd, chunk):
    """Pallas WKV kernel vs the jnp chunked-recurrence oracle."""
    from repro.kernels.wkv import wkv_chunked
    from repro.models.layers import _wkv_chunk_scan
    r = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 3),
                                         (B, S, H, hd))) * 0.6 + 0.39
    u = jax.random.normal(jax.random.fold_in(KEY, 4), (H, hd), jnp.float32) * 0.5
    o_kernel = wkv_chunked(r, k, v, w, u, chunk=chunk)
    o_ref, _ = _wkv_chunk_scan(r, k, v, w, u, chunk)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               atol=1e-4)
