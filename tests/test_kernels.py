"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,Sq,Sk,Hkv,G,hd,dt", [
    (2, 256, 256, 2, 2, 64, jnp.float32),
    (1, 512, 512, 1, 4, 128, jnp.bfloat16),
    (2, 256, 256, 4, 1, 64, jnp.float32),
    (1, 256, 256, 2, 2, 128, jnp.bfloat16),
    (1, 128, 128, 1, 1, 64, jnp.float32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_matches_oracle(B, Sq, Sk, Hkv, G, hd, dt, causal):
    H = Hkv * G
    q = jax.random.normal(KEY, (B, Sq, H, hd), dt)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Sk, Hkv, hd), dt)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Sk, Hkv, hd), dt)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    o_ref = ref.mha_reference(q, k, v, causal=causal)
    tol = 2e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_kernel_sliding_window(window):
    B, S, Hkv, G, hd = 1, 256, 2, 2, 64
    q = jax.random.normal(KEY, (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            block_q=128, block_k=128)
    o_ref = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=3e-5)


def test_flash_kernel_matches_model_flash_vjp_fwd():
    """The jnp custom-VJP flash in models.layers and the Pallas kernel are
    the same algorithm — cross-validate them directly."""
    from repro.models.layers import _flash
    B, S, Hkv, G, hd = 1, 256, 2, 2, 64
    q = jax.random.normal(KEY, (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, Hkv, hd), jnp.float32)
    o_pallas = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    o_jnp = _flash(q, k, v, True, None, 128, 128, 0)
    np.testing.assert_allclose(np.asarray(o_pallas), np.asarray(o_jnp), atol=3e-5)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 6), st.floats(0.1, 100.0))
def test_quant_roundtrip_error_bound(ntiles, scale):
    """Property: blockwise int8 roundtrip error <= amax/127 per block half-ulp."""
    n = 256 * 32 * ntiles
    x = np.asarray(jax.random.normal(KEY, (n,), jnp.float32)) * scale
    q, s, pad = ops.quantize_int8(jnp.asarray(x))
    xd = np.asarray(ops.dequantize_int8(q, s, pad))
    blocks = x.reshape(-1, 256)
    bound = np.abs(blocks).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-9
    assert (np.abs(xd.reshape(-1, 256) - blocks) <= bound + 1e-6).all()


def test_quant_matches_reference_exactly():
    x = jax.random.normal(KEY, (256 * 32 * 2,), jnp.float32) * 5
    q, s, pad = ops.quantize_int8(x)
    qr, sr = ref.quantize_int8_reference(x)
    assert pad == 0
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_quant_padding_path():
    x = jax.random.normal(KEY, (1000,), jnp.float32)
    q, s, pad = ops.quantize_int8(x)
    assert pad == 256 * 32 - 1000
    xd = ops.dequantize_int8(q, s, pad)
    assert xd.shape == (1000,)
    assert float(jnp.abs(xd - x).max()) < 0.05


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 16), (1, 64, 1, 64, 16),
])
def test_wkv_kernel_matches_chunk_scan(B, S, H, hd, chunk):
    """Pallas WKV kernel vs the jnp chunked-recurrence oracle."""
    from repro.kernels.wkv import wkv_chunked
    from repro.models.layers import _wkv_chunk_scan
    r = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 3),
                                         (B, S, H, hd))) * 0.6 + 0.39
    u = jax.random.normal(jax.random.fold_in(KEY, 4), (H, hd), jnp.float32) * 0.5
    o_kernel = wkv_chunked(r, k, v, w, u, chunk=chunk)
    o_ref, _ = _wkv_chunk_scan(r, k, v, w, u, chunk)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               atol=1e-4)


# ---------------------------------------------------------------------- #
# Flash backward (Pallas custom-VJP) vs the jnp VJP oracle
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("B,Sq,Sk,Hkv,G,hd,causal,window,q_offset", [
    (1, 256, 256, 2, 2, 32, True, None, 0),      # GQA causal
    (1, 256, 256, 1, 1, 64, False, None, 0),     # full attention
    (1, 256, 256, 2, 1, 32, True, 64, 0),        # sliding window
    (1, 128, 256, 2, 2, 32, True, None, 128),    # Sq != Sk, offset (decode)
])
def test_flash_bwd_matches_jnp_vjp(B, Sq, Sk, Hkv, G, hd, causal, window,
                                   q_offset):
    """The Pallas backward (dq/dk/dv kernels behind jax.custom_vjp) against
    the blockwise-recompute jnp VJP in models.layers — same algorithm, so
    the grads should agree to float32 roundoff."""
    from repro.kernels import flash_attention as fa
    from repro.models.layers import _flash
    H = Hkv * G
    q = jax.random.normal(KEY, (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, Sk, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, Sk, Hkv, hd), jnp.float32)

    def lp(q, k, v):
        return jnp.sum(jnp.sin(fa.flash_attention(
            q, k, v, causal, window, 64, 64, q_offset, None)))

    def lj(q, k, v):
        return jnp.sum(jnp.sin(_flash(q, k, v, causal, window, 64, 64,
                                      q_offset)))

    gp = jax.grad(lp, argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(lj, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip(("dq", "dk", "dv"), gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   err_msg=name)


def test_flash_fwd_lse_matches_jnp():
    """fwd returns the log-sum-exp the backward recompute depends on — its
    layout (B,Hkv,G,Sq) and values must match the jnp online softmax."""
    from repro.kernels import flash_attention as fa
    from repro.models.layers import _flash_fwd_impl
    B, S, Hkv, G, hd = 1, 256, 2, 2, 64
    q = jax.random.normal(KEY, (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, Hkv, hd), jnp.float32)
    o, lse = fa.flash_attention_fwd(q, k, v, causal=True, block_q=64,
                                    block_k=64)
    oj, lsej = _flash_fwd_impl(q, k, v, True, None, 64, 64, 0)
    assert lse.shape == lsej.shape == (B, Hkv, G, S)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oj), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lsej), atol=1e-5)


def test_chunked_attention_impl_switch():
    """impl='pallas' routes chunked_attention through the Pallas kernels
    (fwd AND bwd) and must match impl='jnp' in both."""
    from repro.models.layers import chunked_attention
    B, S, Hkv, G, hd = 1, 256, 2, 2, 32
    q = jax.random.normal(KEY, (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 9), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 10), (B, S, Hkv, hd), jnp.float32)

    def loss(impl):
        return lambda q: jnp.sum(jnp.sin(chunked_attention(
            q, k, v, chunk_q=128, chunk_k=128, impl=impl)))

    op = chunked_attention(q, k, v, chunk_q=128, chunk_k=128, impl="pallas")
    oj = chunked_attention(q, k, v, chunk_q=128, chunk_k=128, impl="jnp")
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=1e-5)
    gp = jax.grad(loss("pallas"))(q)
    gj = jax.grad(loss("jnp"))(q)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gj), atol=2e-5)
    with pytest.raises(ValueError):
        chunked_attention(q, k, v, chunk_q=128, chunk_k=128, impl="bogus")


# ---------------------------------------------------------------------- #
# Fused quantise + error feedback
# ---------------------------------------------------------------------- #

@settings(deadline=None, max_examples=15)
@given(st.integers(1, 3), st.integers(0, 100), st.floats(0.05, 50.0))
def test_quantize_ef_fused_bitidentical_to_two_pass(ntiles, off, scale):
    """Property: the fused kernel's (q, scales, residual) are BIT-identical
    to quantise(x+ef) / dequantise / subtract through the same kernels —
    fusion removes HBM round trips, not a single bit of the arithmetic."""
    n = 256 * 32 * ntiles - off
    x = jax.random.normal(jax.random.fold_in(KEY, n), (n,), jnp.float32) * scale
    ef = jax.random.normal(jax.random.fold_in(KEY, n + 1), (n,), jnp.float32) * 1e-3
    qf, sf, rf, pad = ops.quantize_ef_int8(x, ef)
    q2, s2, pad2 = ops.quantize_int8(x + ef)
    assert pad == pad2 == off % (256 * 32)
    r2 = (x + ef) - ops.dequantize_int8(q2, s2, pad2)
    np.testing.assert_array_equal(np.asarray(qf), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(rf), np.asarray(r2))


def test_apply_error_feedback_kernel_matches_jnp():
    """Kernel vs pure-jnp EF update: the corrected gradient is bit-identical
    (both compute g+ef in jnp); the residual agrees to 1 ulp (the jit'd
    kernel divides by 127 via reciprocal, the eager path by true division)."""
    from repro.core import compression
    for n in (256 * 32, 4096, 333):
        g = jax.random.normal(jax.random.fold_in(KEY, n), (n,), jnp.float32)
        ef = jax.random.normal(jax.random.fold_in(KEY, n + 1), (n,), jnp.float32) * 1e-3
        gk, rk = compression.apply_error_feedback(g, ef, use_kernel=True)
        gj, rj = compression.apply_error_feedback(g, ef, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(gj))
        np.testing.assert_allclose(np.asarray(rk), np.asarray(rj), atol=1e-6)
        assert rk.shape == rj.shape == (n,)


def test_quant_constants_single_source():
    """The tiling constants live in core.compression; every consumer must
    read the same objects (satellite: no BLOCK/QBLOCK/TILE drift)."""
    from repro.core import compression
    from repro.kernels import quant
    assert quant.QBLOCK == ref.QBLOCK == compression.BLOCK
    assert quant.TILE == compression.TILE
    assert quant.QTILE == compression.QTILE == compression.BLOCK * compression.TILE
    assert compression.WIRE_BYTES_PER_ELEM == 1.0 + 4.0 / compression.BLOCK


def test_pad_to_block():
    from repro.core import compression
    p, pad = compression.pad_to_block(jnp.ones(5), 8)
    assert p.shape == (8,) and pad == 3
    assert float(p[5:].sum()) == 0.0
    p, pad = compression.pad_to_block(jnp.ones(8), 8)
    assert p.shape == (8,) and pad == 0
    with pytest.raises(ValueError):
        compression.pad_to_block(jnp.ones((2, 3)), 8)


def test_resolve_interpret_auto_detect():
    from repro.kernels.backend import on_tpu, resolve_interpret
    assert on_tpu() == (jax.default_backend() == "tpu")
    assert resolve_interpret(None) == (not on_tpu())
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_compressed_psum_use_kernel_validation():
    from repro.core import compression
    with pytest.raises(ValueError):
        compression._resolve_use_kernel(True, 128)   # kernel tiled for BLOCK
    assert compression._resolve_use_kernel(False, 128) is False
    assert compression._resolve_use_kernel(None, 128) is False
