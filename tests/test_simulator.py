"""Simulator + cost-model tests: the paper's §4 claims, quantitatively."""
import math

import pytest

from repro.core import schedule as S
from repro.core.costmodel import (MAX_SEGMENTS, binomial_bcast_cost,
                                  multilevel_bcast_cost,
                                  pipeline_segment_bytes, roofline_terms,
                                  two_level_bcast_cost)
from repro.core.simulator import simulate
from repro.core.topology import (Topology, WAN, LAN, SMP,
                                 paper_fig8_topology, magpie_machine_view,
                                 magpie_site_view, flat_view,
                                 tpu_v5e_multipod)
from repro.core.trees import binomial_tree, build_multilevel_tree, PAPER_POLICY


def _bcast_time(tree, topo, nbytes):
    return max(simulate(S.bcast(tree, nbytes), topo).values())


@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


def test_fig8_ordering(fig8):
    """Paper Fig. 8: multilevel <= MagPIe-site < MagPIe-machine <= binomial
    over the paper's message-size range; strict multilevel win at mid sizes
    where the LAN hop matters."""
    for nbytes in (16e3, 64e3, 256e3):
        t_bin = _bcast_time(binomial_tree(0, range(fig8.nprocs)), fig8, nbytes)
        t_mach = _bcast_time(
            build_multilevel_tree(magpie_machine_view(fig8), 0), fig8, nbytes)
        t_site = _bcast_time(
            build_multilevel_tree(magpie_site_view(fig8), 0), fig8, nbytes)
        t_ml = _bcast_time(build_multilevel_tree(fig8, 0), fig8, nbytes)
        eps = 1e-9
        assert t_ml <= t_site + eps, (nbytes, t_ml, t_site)
        assert t_site < t_mach + eps, (nbytes, t_site, t_mach)
        assert t_mach <= t_bin * 1.001 + eps, (nbytes, t_mach, t_bin)
    # Strict multilevel-vs-site win appears for ANL-rooted broadcasts (the
    # LAN hop the 2-level site view can't see); sum over roots like the
    # paper's timing app.
    tot_site = sum(_bcast_time(build_multilevel_tree(
        magpie_site_view(fig8), r), fig8, 256e3) for r in range(0, 48, 8))
    tot_ml = sum(_bcast_time(build_multilevel_tree(fig8, r), fig8, 256e3)
                 for r in range(0, 48, 8))
    assert tot_ml < tot_site


def test_fig8_multilevel_wins_all_roots(fig8):
    """The benefit holds regardless of which rank is the broadcast root
    (the timing app sweeps every root)."""
    nbytes = 256e3
    worse = 0
    for root in range(0, fig8.nprocs, 7):
        t_bin = _bcast_time(binomial_tree(root, range(fig8.nprocs)), fig8, nbytes)
        t_ml = _bcast_time(build_multilevel_tree(fig8, root), fig8, nbytes)
        if t_ml >= t_bin:
            worse += 1
    assert worse == 0


def test_cost_model_log_c_to_one():
    """§4 closed form: binomial pays log2(C) slow messages, multilevel 1."""
    P, C, N = 64, 8, 1e6
    args = (WAN.latency, WAN.bandwidth, SMP.latency, SMP.bandwidth)
    t_bin = binomial_bcast_cost(P, C, N, *args)
    t_ml = multilevel_bcast_cost(P, C, N, *args)
    slow = WAN.latency + N / WAN.bandwidth
    assert t_bin - t_ml == pytest.approx((math.log2(C) - 1) * slow, rel=1e-6)


def test_simulator_matches_cost_model_scaling():
    """Simulated binomial/multilevel ratio tracks the closed form within 2x
    (the model ignores sender occupancy, so exact match is not expected)."""
    # Latency-dominated regime (occupancy << WAN latency) — where the
    # paper's log2(C) sequential-slow-hop analysis applies; at larger N the
    # postal occupancy model lets binomial pipeline its WAN sends and the
    # closed form no longer binds (see test_adaptive_policy_*).
    P, C, N = 32, 8, 4e3
    site = [i // (P // C) for i in range(P)]
    topo = Topology(__import__("numpy").array([site]).T, [WAN, SMP])
    t_bin = _bcast_time(binomial_tree(0, range(P)), topo, N)
    t_ml = _bcast_time(build_multilevel_tree(topo, 0), topo, N)
    args = (WAN.latency, WAN.bandwidth, SMP.latency, SMP.bandwidth)
    pred = binomial_bcast_cost(P, C, N, *args) / multilevel_bcast_cost(P, C, N, *args)
    assert t_bin / t_ml == pytest.approx(pred, rel=1.0)
    assert t_bin / t_ml > 1.3


@pytest.fixture(scope="module")
def many_clusters():
    """16 machines x 4 procs across 4 sites — the many-cluster Grid regime
    where slow-link message counts dominate (the paper's target)."""
    import numpy as np
    site = [i // 16 for i in range(64)]
    mach = [i // 4 for i in range(64)]
    return Topology(np.stack([site, mach], 1), [WAN, LAN, SMP])


@pytest.mark.parametrize("op,nbytes", [
    (S.reduce, 1e3), (S.gather, 1e3), (S.allreduce, 1e3), (S.bcast, 1e3),
    (S.scatter, 64.0),  # scatter payloads aggregate; needs tiny per-rank N
])
def test_ops_multilevel_beats_oblivious_latency_regime(many_clusters, op, nbytes):
    """With many clusters and latency-dominated messages, minimising slow-
    link message counts wins for every collective — the paper's claim."""
    topo = many_clusters
    t_bin = max(simulate(op(binomial_tree(0, range(topo.nprocs)), nbytes),
                         topo).values())
    t_ml = max(simulate(op(build_multilevel_tree(topo, 0), nbytes),
                        topo).values())
    assert t_ml < t_bin


def test_adaptive_policy_never_worse_than_paper(many_clusters, fig8):
    """Beyond-paper §6 extension: per-level Bar-Noy/Kipnis shape selection
    is >= the paper's fixed flat/binomial policy at every size, and repairs
    its large-message regression vs the oblivious binomial."""
    from repro.core.trees import adaptive_policy, PAPER_POLICY
    for topo in (many_clusters, fig8):
        for nb in (1e3, 64e3, 1e6):
            t_p = max(simulate(S.bcast(build_multilevel_tree(
                topo, 0, policy=PAPER_POLICY), nb), topo).values())
            t_a = max(simulate(S.bcast(build_multilevel_tree(
                topo, 0, policy=adaptive_policy(topo, nb)), nb), topo).values())
            assert t_a <= t_p * 1.01
    # regression repair at 1 MB on the many-cluster topology
    nb = 1e6
    topo = many_clusters
    t_bin = max(simulate(S.bcast(binomial_tree(0, range(topo.nprocs)), nb),
                         topo).values())
    t_a = max(simulate(S.bcast(build_multilevel_tree(
        topo, 0, policy=adaptive_policy(topo, nb)), nb), topo).values())
    assert t_a <= t_bin * 1.01


def test_gather_bandwidth_concentration_tradeoff(fig8):
    """Observed trade-off (recorded in EXPERIMENTS §Perf): for BANDWIDTH-
    dominated gathers, the multilevel tree concentrates the whole remote
    site's payload onto one WAN link, while the oblivious binomial spreads
    it over several NICs in parallel — multilevel loses there.  The paper's
    experiments are latency/message-count bound, where it wins."""
    big = 512e3
    t_bin = max(simulate(S.gather(binomial_tree(0, range(fig8.nprocs)), big),
                         fig8).values())
    t_ml = max(simulate(S.gather(build_multilevel_tree(fig8, 0), big),
                        fig8).values())
    assert t_ml > t_bin  # documents the concentration effect


def test_barrier(fig8):
    t = build_multilevel_tree(fig8, 0)
    done = simulate(S.barrier(t), fig8)
    assert len(done) == fig8.nprocs
    assert max(done.values()) > 0


def test_gather_sizes_grow(fig8):
    """Gather message sizes must equal subtree_size * nbytes."""
    t = build_multilevel_tree(fig8, 0)
    sizes = t.subtree_sizes()
    sched = S.gather(t, 10.0)
    for msgs in sched.phases[0].msgs.values():
        for m in msgs:
            assert m.nbytes == sizes[m.src] * 10.0


def test_tpu_topology_mapping():
    topo = tpu_v5e_multipod(pods=2, boards=4, chips_per_board=4)
    t = build_multilevel_tree(topo, 0)
    dcn_edges = [(p, c) for p, cs in t.children.items() for c in cs
                 if topo.comm_level(p, c) == 0]
    assert len(dcn_edges) == 1  # one DCN message total — the paper's rule


def test_roofline_terms():
    r = roofline_terms(hlo_flops=1e15, hlo_bytes=1e12, ici_bytes=1e9,
                       chips=256, dcn_bytes=1e8)
    assert r["bound"] in ("compute", "memory", "collective")
    assert r["step_s"] == max(r["compute_s"], r["memory_s"], r["collective_s"])


def test_pipeline_segment_bytes_power_of_two_invariant():
    """Regression: the nbytes/max_segments clamp used to return a raw
    quotient (e.g. 1562500.0 for 100 MB), violating the documented
    power-of-two invariant; the floored value must round back UP so the
    segment count stays <= MAX_SEGMENTS."""
    levels = [WAN, LAN, SMP]
    for nbytes in (1e8, 3e7, float(1 << 26), float(1 << 26) * 1.37, 5e3):
        seg = pipeline_segment_bytes(levels, nbytes)
        assert 0 < seg <= nbytes
        if seg < nbytes:  # whole-message clamp is the one allowed exception
            assert seg == 2.0 ** round(math.log2(seg)), (nbytes, seg)
        assert math.ceil(nbytes / seg) <= MAX_SEGMENTS


def test_probe_time_is_postal_one_way():
    from repro.core.simulator import probe_time

    topo = paper_fig8_topology()
    lvl = topo.level_of_edge(0, 47)  # cross-site: WAN
    assert lvl.name == "wan"
    assert probe_time(topo, 0, 47, 1e6) == pytest.approx(
        lvl.overhead + lvl.latency + 1e6 / lvl.bandwidth)


# ------------------------------------------------------------------ #
# The concurrent executor (engine substrate): single-program results must
# stay BIT-identical to the linear-pass executor, for every plan family.
# ------------------------------------------------------------------ #

def test_concurrent_single_program_bit_identical(fig8):
    """simulate_rounds([plan]) — the contention path with one live program
    — reproduces simulate_rounds(plan) exactly: == on every float, across
    tree/sag/rsag, segmented and not."""
    from repro.core import Communicator
    from repro.core.simulator import simulate_rounds

    comm = Communicator(fig8, policy="auto", backend="sim")
    for op, nb in [("bcast", 64e3), ("bcast", float(1 << 26)),
                   ("allreduce", 8e3), ("allreduce", float(1 << 26)),
                   ("gather", 16e3), ("scatter", 4e3),
                   ("allgather", 4e3), ("reduce", 256e3)]:
        low = comm.plan(op, root=0, nbytes=nb).lower(nb)
        assert simulate_rounds([low], fig8)[0] == simulate_rounds(low, fig8), \
            (op, nb)
    # a non-zero start offset shifts both executors identically
    low = comm.plan("allreduce", root=0, nbytes=64e3).lower(64e3)
    assert simulate_rounds([low], fig8, start=1.5)[0] \
        == simulate_rounds(low, fig8, start=1.5)


def test_concurrent_rejects_fail_at_and_bad_deps(fig8):
    from repro.core import Communicator
    from repro.core.simulator import simulate_concurrent, simulate_rounds

    comm = Communicator(fig8, policy="paper", backend="sim")
    low = comm.plan("bcast", root=0, nbytes=1e3).lower(1e3)
    with pytest.raises(ValueError, match="fail_at"):
        simulate_rounds([low], fig8, fail_at={3: 0.0})
    with pytest.raises(ValueError, match="dependency"):
        simulate_concurrent([low], fig8, deps={0: [0]})  # self-dep
    with pytest.raises(ValueError, match="never completed"):
        simulate_concurrent([low, low], fig8, deps={0: [1], 1: [0]})
    with pytest.raises(ValueError, match="start times"):
        simulate_concurrent([low], fig8, starts=[0.0, 1.0])


def test_concurrent_link_disjoint_programs_price_as_isolated(fig8):
    """Conservation satellite, simulator plane: programs over disjoint
    subtrees couple through nothing — per-plan completions equal the
    isolated runs bit-for-bit."""
    from repro.core import Communicator
    from repro.core.simulator import simulate_concurrent, simulate_rounds

    lows = []
    for lo in (0, 16, 32):
        sub = Communicator(fig8, policy="paper", backend="sim",
                           members=list(range(lo, lo + 16)))
        lows.append(sub.plan("allreduce", root=lo, nbytes=1e6).lower(1e6))
    out = simulate_concurrent(lows, fig8)
    for low, got in zip(lows, out):
        assert got == simulate_rounds(low, fig8)


def test_concurrent_program_deps_serialize(fig8):
    """deps={j: [i]} releases j only when i has completed on EVERY rank."""
    from repro.core import Communicator
    from repro.core.simulator import simulate_concurrent

    comm = Communicator(fig8, policy="paper", backend="sim")
    low = comm.plan("allreduce", root=0, nbytes=1e6).lower(1e6)
    free = simulate_concurrent([low, low], fig8)
    chained = simulate_concurrent([low, low], fig8, deps={1: [0]})
    assert min(chained[1].values()) >= max(chained[0].values())
    assert max(chained[1].values()) > max(free[1].values())
