"""Tests for the unified Communicator API: op dispatch, plan caching,
tree_rounds properties, sim equivalence, and cross-backend agreement."""
import pytest

from repro.core import Communicator, OPS, SimResult, Tree, size_bucket
from repro.core import schedule as S
from repro.core.simulator import simulate
from repro.core.topology import Topology, WAN, LAN, SMP, paper_fig8_topology
from repro.core.trees import (binomial_tree, build_multilevel_tree,
                              chain_tree, flat_tree, postal_tree,
                              PAPER_POLICY)
from repro.core.tree_exec import tree_rounds


@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


# ------------------------------------------------------------------ #
# tree_rounds properties across every builder (satellite coverage).
# ------------------------------------------------------------------ #

def _round_trees(n=23):
    topo = paper_fig8_topology()
    members = list(range(n))
    return {
        "flat": flat_tree(0, members),
        "binomial": binomial_tree(3, members),
        "chain": chain_tree(0, members),
        "postal2": postal_tree(0, members, lam=2),
        "postal5": postal_tree(5, members, lam=5),
        "multilevel": build_multilevel_tree(topo, 7),
    }


@pytest.mark.parametrize("kind", list(_round_trees()))
def test_tree_rounds_properties(kind):
    """Rounds have disjoint (src,dst) pairs, every non-root rank receives
    exactly once, and parents never inject before they have received."""
    tree = _round_trees()[kind]
    rounds = tree_rounds(tree)
    recv_round = {tree.root: -1}
    for r, edges in enumerate(rounds):
        assert edges, f"empty round {r}"
        srcs = [s for s, _ in edges]
        dsts = [d for _, d in edges]
        # disjointness: one injection per sender, one receive per dst
        assert len(srcs) == len(set(srcs)), (kind, r, "double injection")
        assert len(dsts) == len(set(dsts)), (kind, r, "double receive")
        assert not set(srcs) & set(dsts), (kind, r, "rank sends and receives")
        for s, d in edges:
            assert s in recv_round and recv_round[s] < r, \
                (kind, r, "parent injects before receiving")
            assert d not in recv_round, (kind, r, "duplicate receive")
            recv_round[d] = r
    assert set(recv_round) == set(tree.members())
    # edge set is exactly the tree's edges
    flat = {e for edges in rounds for e in edges}
    assert flat == {(p, c) for p, cs in tree.children.items() for c in cs}


def test_tree_rounds_deep_chain():
    """The iterative schedule/simulator paths survive very deep trees."""
    n = 3000
    t = chain_tree(0, range(n))
    assert t.depth() == n - 1
    assert len(t.subtree_sizes()) == n
    topo = Topology([[0]] * n, [WAN, SMP])
    done = simulate(S.reduce(t, 1e3), topo)  # recursive version overflowed
    assert len(done) == n


def test_validate_raises_value_error():
    """Tree.validate must raise real exceptions, not bare asserts — and must
    terminate (with an error) on cyclic children maps."""
    with pytest.raises(ValueError, match="invalid tree"):
        Tree(0, {0: [1], 1: [0]}).validate()  # cycle
    with pytest.raises(ValueError, match="invalid tree"):
        Tree(0, {0: [1, 1]}).validate()       # duplicate child
    with pytest.raises(ValueError, match="root .* has a parent"):
        Tree(0, {0: [1], 2: [0, 1]}).validate()
    good = binomial_tree(0, range(8))
    good.validate()  # no raise


# ------------------------------------------------------------------ #
# Sim backend: equivalence with direct schedule + simulate calls.
# ------------------------------------------------------------------ #

def test_sim_backend_matches_direct_calls(fig8):
    """The sim backend executes the plan's LOWERED rounds IR: results equal
    a direct lower + simulate_rounds of the same plan, and for unsegmented
    tree plans the overall time stays equivalent to the whole-message
    schedule simulation (the IR only refines per-rank sender accounting)."""
    from repro.core.simulator import simulate_rounds

    comm = Communicator(fig8, policy="paper", backend="sim")
    tree = build_multilevel_tree(fig8, 5, policy=PAPER_POLICY)
    for op, nb in [("bcast", 64e3), ("reduce", 1e3), ("gather", 16e3),
                   ("scatter", 16e3), ("allreduce", 64e3),
                   ("allgather", 4e3)]:
        spec = OPS[op]
        res = (getattr(comm, op)(nb, root=5) if spec.rootful
               else comm._run(op, nb, 5))
        assert isinstance(res, SimResult)
        plan = comm.plan(op, root=5, nbytes=nb)
        assert plan.tree.children == tree.children, op
        assert plan.algorithm == "tree" and plan.segment is None, op
        direct = simulate_rounds(plan.lower(nb), fig8)
        assert res.completion == direct, op
        if op in ("bcast", "reduce", "allreduce"):
            sched_t = max(simulate(getattr(S, op)(tree, nb), fig8).values())
            # fold-drain order at a receiver differs (emission vs child
            # order), shifting per-message overheads only
            assert res.time == pytest.approx(sched_t, rel=5e-3), op
    b = comm._run("barrier", None, 5)
    assert b.completion == simulate_rounds(
        comm.plan("barrier", root=5).lower(0.0), fig8)


def test_all_seven_ops_dispatch(fig8):
    comm = Communicator(fig8, policy="auto", backend="sim")
    assert set(OPS) == {"bcast", "reduce", "barrier", "gather", "scatter",
                        "allreduce", "allgather"}
    times = {}
    for op in OPS:
        if op == "barrier":
            times[op] = comm.barrier().time
        elif OPS[op].rootful:
            times[op] = getattr(comm, op)(8e3, root=0).time
        else:
            times[op] = getattr(comm, op)(8e3).time
    assert all(t > 0 for t in times.values()), times


def test_unknown_op_and_backend_rejected(fig8):
    with pytest.raises(KeyError):
        Communicator(fig8).plan("alltoall")
    with pytest.raises(ValueError, match="unknown backend"):
        Communicator(fig8, backend="mpi")
    with pytest.raises(ValueError, match="not a member"):
        Communicator(fig8, members=[0, 1, 2]).bcast(1e3, root=40)


# ------------------------------------------------------------------ #
# Plan cache: repeat calls must re-run nothing.
# ------------------------------------------------------------------ #

def test_plan_cache_hit_builds_nothing(fig8):
    comm = Communicator(fig8, policy="auto", backend="sim")
    comm.bcast(64e3, root=0)
    info1 = comm.cache_info()
    assert info1.misses == 1 and info1.tree_builds == 3  # auto: 3 candidates
    r2 = comm.bcast(64e3, root=0)
    info2 = comm.cache_info()
    assert info2.hits == info1.hits + 1
    assert info2.tree_builds == info1.tree_builds, "second call rebuilt trees"
    assert r2.time > 0
    # same size-bucket, different exact size: still a plan hit
    comm.bcast(65e3, root=0)
    assert comm.cache_info().tree_builds == info1.tree_builds
    # different root or op: new plan
    comm.bcast(64e3, root=1)
    comm.reduce(64e3, root=0)
    assert comm.cache_info().tree_builds > info1.tree_builds


def test_plan_identity_and_rounds_cached(fig8):
    # size-independent policy: ONE plan per (op, root), any message size —
    # so plan() inspection and a later execution share the cache entry
    comm = Communicator(fig8, policy="paper")
    p1 = comm.plan("bcast", root=0, nbytes=17e3)
    p2 = comm.plan("bcast", root=0, nbytes=900e3)
    assert p1 is p2
    # size-dependent policy: one plan per size octave
    ad = Communicator(fig8, policy="adaptive")
    assert ad.plan("bcast", root=0, nbytes=17e3) is \
        ad.plan("bcast", root=0, nbytes=20e3)
    assert ad.plan("bcast", root=0, nbytes=17e3) is not \
        ad.plan("bcast", root=0, nbytes=900e3)
    r1 = p1.rounds
    assert p1.rounds is r1  # memoised
    assert p1.schedule(32e3) is p1.schedule(32e3)


def test_size_bucket():
    assert size_bucket(0) == -1 and size_bucket(None) == -1
    assert size_bucket(1) == 0
    assert size_bucket(1024) == size_bucket(2000) == 10
    assert size_bucket(2048) == 11


def test_size_bucket_boundaries():
    """Satellite coverage: the degenerate and boundary inputs."""
    assert size_bucket(-1) == -1 and size_bucket(-0.5) == -1
    # sub-2-byte payloads clamp into bucket 0 (log2 < 1 -> int -> <= 0)
    assert size_bucket(0.25) == 0
    assert size_bucket(0.5) == 0
    assert size_bucket(1.0) == 0
    assert size_bucket(1.999) == 0
    # exact powers of two open their own octave
    for k in (1, 2, 10, 20, 30):
        assert size_bucket(2.0 ** k) == k
        assert size_bucket(2.0 ** k - 1) == k - 1
        assert size_bucket(2.0 ** k + 1) == k


def test_plan_cache_eviction_order_and_clear_stats():
    from repro.core import PlanCache

    cache = PlanCache(maxsize=2)
    built = []

    def make(tag):
        def build():
            built.append(tag)
            return tag  # the cache is value-agnostic
        return build

    cache.get_or_build("a", make("a"))
    cache.get_or_build("b", make("b"))
    cache.get_or_build("a", make("a"))          # hit: refreshes a's LRU slot
    cache.get_or_build("c", make("c"))          # evicts b (LRU), not a
    assert built == ["a", "b", "c"]
    cache.get_or_build("a", make("a2"))
    assert built == ["a", "b", "c"]             # a survived the eviction
    cache.get_or_build("b", make("b2"))         # b was evicted: rebuilt
    assert built == ["a", "b", "c", "b2"]
    assert cache.hits == 2 and cache.misses == 4
    cache.clear()
    assert len(cache) == 0
    assert cache.hits == 0 and cache.misses == 0
    assert cache.maxsize == 2                   # capacity is configuration
    cache.get_or_build("a", make("a3"))
    assert cache.misses == 1 and cache.hits == 0


def test_per_call_policy_never_served_stale_plan(fig8):
    """Regression: the cache key omitted the policy, so a per-call
    ``policy=`` override could be handed a plan built under the
    communicator's default policy (and vice versa)."""
    comm = Communicator(fig8, policy="paper", backend="sim")
    p_paper = comm.plan("bcast", root=0, nbytes=64e3)
    p_obliv = comm.plan("bcast", root=0, nbytes=64e3, policy="oblivious")
    assert p_obliv is not p_paper
    assert p_obliv.tree.children != p_paper.tree.children
    # the paper plan crosses the WAN once; the oblivious binomial does not
    wan = lambda t: sum(1 for p, cs in t.children.items() for c in cs
                        if fig8.comm_level(p, c) == 0)
    assert wan(p_paper.tree) == 1 and wan(p_obliv.tree) > 1
    # both entries coexist: repeat calls hit their own entry
    assert comm.plan("bcast", root=0, nbytes=64e3) is p_paper
    assert comm.plan("bcast", root=0, nbytes=64e3,
                     policy="oblivious") is p_obliv
    # an explicit override equal to the default shares the default entry
    assert comm.plan("bcast", root=0, nbytes=64e3, policy="paper") is p_paper
    # per-call size-dependent policies bucket by size even when the
    # communicator default would not
    a1 = comm.plan("bcast", root=0, nbytes=17e3, policy="adaptive")
    a2 = comm.plan("bcast", root=0, nbytes=900e3, policy="adaptive")
    assert a1 is not a2


def test_stats_counts_hits_misses_evictions_repairs(fig8):
    """Satellite: Communicator.stats() exposes plan reuse as counters so
    the engine and benchmarks can ASSERT it instead of timing it."""
    comm = Communicator(fig8, policy="paper", backend="sim", cache_size=2)
    comm.bcast(64e3, root=0)
    comm.bcast(64e3, root=0)
    st = comm.stats()
    assert (st.hits, st.misses, st.evictions) == (1, 1, 0)
    assert st.tree_builds == 1 and st.repairs == 0
    assert (st.currsize, st.maxsize) == (1, 2)
    comm.bcast(64e3, root=1)
    comm.bcast(64e3, root=2)      # capacity 2: evicts the root-0 plan
    assert comm.stats().evictions == 1
    comm.bcast(64e3, root=0)      # rebuilt: a miss, not a hit
    st = comm.stats()
    assert st.misses == 4 and st.evictions == 2
    comm.repair(failed=[40])
    assert comm.stats().repairs == 1
    comm.repair(failed=[40])      # already gone: not a repair
    assert comm.stats().repairs == 1
    # cache_info() keeps its legacy shape
    ci = comm.cache_info()
    assert (ci.hits, ci.misses) == (st.hits, st.misses)


def test_stats_monotonic_and_tree_builds_exactly_accounted():
    """Regression (observability): every CommStats counter is monotone
    across the full elastic lifecycle, and ``tree_builds`` is EXACTLY
    accounted — under a fixed policy it equals the miss count (one tree
    per build), repair() splices without building, refresh() invalidates
    without building (the rebuild is charged to the next miss), and a
    capacity eviction charges one rebuild when the victim re-plans."""
    import dataclasses

    import repro.core.discovery as D

    topo = paper_fig8_topology()   # private copy: refresh mutates levels
    comm = Communicator(topo, policy="paper", backend="sim", cache_size=2)
    prev = comm.stats()

    def step(expect_builds):
        nonlocal prev
        st = comm.stats()
        for f in ("hits", "misses", "evictions", "tree_builds", "repairs"):
            assert getattr(st, f) >= getattr(prev, f), (f, prev, st)
        # the exact identity: policy="paper" builds ONE tree per miss
        assert st.tree_builds == st.misses == expect_builds, (prev, st)
        prev = st
        return st

    comm.plan("bcast", root=0, nbytes=64e3)
    comm.plan("bcast", root=1, nbytes=64e3)
    step(2)
    comm.plan("bcast", root=0, nbytes=64e3)           # hit
    assert step(2).hits == 1

    rep = comm.repair(failed=[40])                    # splice, not rebuild
    assert rep.repaired == 2 and rep.evicted == 0
    assert step(2).repairs == 1
    comm.plan("bcast", root=0, nbytes=64e3)           # repaired plan: a hit
    assert step(2).hits == 2

    drifted = Topology(topo.coords, [dataclasses.replace(
        topo.levels[0], latency=topo.levels[0].latency * 3)]
        + list(topo.levels[1:]))
    probes = D.targeted_probes(drifted,
                               D.representative_pairs(topo, comm.members))
    assert comm.refresh(probes).refreshed
    step(2)                                           # invalidate ≠ build
    comm.plan("bcast", root=0, nbytes=64e3)           # rebuild under new costs
    assert step(3).misses == 3

    comm.plan("bcast", root=1, nbytes=64e3)
    comm.plan("bcast", root=2, nbytes=64e3)           # capacity 2: evicts
    assert step(5).evictions == 1
    comm.plan("bcast", root=0, nbytes=64e3)           # victim re-plans
    st = step(6)
    assert st.evictions == 2 and st.hits == 2
    # the registry enforces monotonicity at the type level, not by promise
    with pytest.raises(ValueError, match="cannot decrease"):
        comm.metrics.counter("comm.tree_builds").inc(-1)


def test_nbytes_of_pinned_sizing_semantics(fig8):
    """Satellite: gather/allgather/scatter plans are sized by the PER-RANK
    contribution.  Scalars already mean that; a device-shaped scatter
    operand is the root's full [P, ...] buffer and must be divided down,
    while gather/allgather operands are the local shard (already
    per-rank)."""
    import numpy as np

    comm = Communicator(fig8, policy="paper", backend="sim")
    P = fig8.nprocs
    # scalars pass through for every sized op
    for op in ("bcast", "reduce", "allreduce", "gather", "scatter",
               "allgather"):
        assert comm._nbytes_of(op, 12345.0) == 12345.0
    assert comm._nbytes_of("barrier", 999.0) == 0.0
    assert comm._nbytes_of("bcast", None) == 0.0
    # device operands: local-shard bytes ...
    shard = np.zeros((64, 8), np.float32)
    assert comm._nbytes_of("gather", shard) == shard.nbytes
    assert comm._nbytes_of("allgather", shard) == shard.nbytes
    assert comm._nbytes_of("bcast", shard) == shard.nbytes
    # ... except scatter, whose operand aggregates all P chunks
    full = np.zeros((P, 64), np.float32)
    assert comm._nbytes_of("scatter", full) == full.nbytes / P
    # regression: the aggregate sizing put scatter plans P size-octaves
    # too high — per-rank sizing must land in the per-chunk bucket
    from repro.core import size_bucket
    assert size_bucket(comm._nbytes_of("scatter", full)) == \
        size_bucket(full.nbytes / P)
    sub = Communicator(fig8, policy="paper", backend="sim",
                       members=[0, 1, 2, 16])
    assert sub._nbytes_of("scatter", np.zeros((4, 10), np.float32)) == 40.0


def test_members_subset(fig8):
    members = [0, 1, 2, 16, 17, 32, 33]
    comm = Communicator(fig8, policy="paper", members=members)
    tree = comm.plan("bcast", root=16, nbytes=1e3).tree
    assert sorted(tree.members()) == sorted(members)
    assert tree.root == 16


def test_deprecated_best_tree_shim(fig8):
    from repro.core.trees import best_tree
    with pytest.warns(DeprecationWarning):
        t = best_tree(fig8, 0, "bcast", 64e3)
    t.validate()
    assert sorted(t.members()) == list(range(fig8.nprocs))


# ------------------------------------------------------------------ #
# Cross-backend agreement on a small device mesh (8 emulated devices).
# ------------------------------------------------------------------ #

def test_backend_agreement_on_mesh(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import Communicator
from repro.core.topology import tpu_v5e_multipod

topo = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
ROOT = 3
x_host = np.arange(8.0, dtype=np.float32)

# --- ppermute backend: explicit tree rounds over the flat axis ---------
mesh1 = jax.make_mesh((8,), ("all",))
pp = Communicator(topo, policy="paper", backend="ppermute", axis="all")
def run_pp(fn):
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh1, in_specs=P("all"), out_specs=P("all")))(
            jnp.asarray(x_host)))

# --- jax backend: axis-decomposed shortcuts over (pod, fast) -----------
mesh2 = jax.make_mesh((2, 4), ("pod", "fast"))
jx = Communicator(topo, backend="jax", slow_axis="pod", fast_axes=("fast",))
def run_jx(fn):
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh2, in_specs=P(("pod", "fast")),
        out_specs=P(("pod", "fast"))))(jnp.asarray(x_host)))

# --- sim backend: postal-model plan for the same topology --------------
sim = Communicator(topo, policy="paper", backend="sim")

# bcast
want = np.full(8, float(ROOT), np.float32)
np.testing.assert_allclose(run_pp(lambda v: pp.bcast(v, root=ROOT)), want)
np.testing.assert_allclose(run_jx(lambda v: jx.bcast(v, root=ROOT)), want)
# reduce (non-root ranks: zeros)
want = np.zeros(8, np.float32); want[ROOT] = x_host.sum()
np.testing.assert_allclose(run_pp(lambda v: pp.reduce(v, root=ROOT)), want)
np.testing.assert_allclose(run_jx(lambda v: jx.reduce(v, root=ROOT)), want)
# allreduce
want = np.full(8, x_host.sum(), np.float32)
np.testing.assert_allclose(run_pp(lambda v: pp.allreduce(v)), want)
np.testing.assert_allclose(run_jx(lambda v: jx.allreduce(v)), want)
# barrier returns a sync token; both must run without error
run_pp(lambda v: v + pp.barrier())
run_jx(lambda v: v + jx.barrier())

# gather/allgather/scatter: each rank's local output is a [P(,1)] buffer;
# shard_map concatenates them rank-major, so reshape to (rank, P).
pg = np.asarray(jax.jit(shard_map(lambda v: pp.gather(v, root=ROOT),
    mesh=mesh1, in_specs=P("all"), out_specs=P("all", None)))(
        jnp.asarray(x_host))).reshape(8, 8)
jg = np.asarray(jax.jit(shard_map(lambda v: jx.gather(v, root=ROOT),
    mesh=mesh2, in_specs=P(("pod", "fast")),
    out_specs=P(("pod", "fast"), None)))(jnp.asarray(x_host))).reshape(8, 8)
np.testing.assert_allclose(pg, jg)
np.testing.assert_allclose(pg[ROOT], x_host)   # root holds everything
np.testing.assert_allclose(pg[(ROOT + 1) % 8], np.zeros(8))  # non-root: 0
pa = np.asarray(jax.jit(shard_map(lambda v: pp.allgather(v),
    mesh=mesh1, in_specs=P("all"), out_specs=P("all", None)))(
        jnp.asarray(x_host))).reshape(8, 8)
ja = np.asarray(jax.jit(shard_map(lambda v: jx.allgather(v),
    mesh=mesh2, in_specs=P(("pod", "fast")),
    out_specs=P(("pod", "fast"), None)))(jnp.asarray(x_host))).reshape(8, 8)
np.testing.assert_allclose(pa, ja)
for row in pa:
    np.testing.assert_allclose(row, x_host)
# scatter: root's [P, P] buffer; rank r keeps row r, so the rank-major
# concatenation of local outputs reassembles the buffer itself.
buf = np.arange(64.0, dtype=np.float32).reshape(8, 8)
ps = np.asarray(jax.jit(shard_map(lambda v: pp.scatter(v, root=ROOT),
    mesh=mesh1, in_specs=P(None, None), out_specs=P("all")))(
        jnp.asarray(buf))).reshape(8, 8)
js = np.asarray(jax.jit(shard_map(lambda v: jx.scatter(v, root=ROOT),
    mesh=mesh2, in_specs=P(None, None),
    out_specs=P(("pod", "fast"))))(jnp.asarray(buf))).reshape(8, 8)
np.testing.assert_allclose(ps, buf)
np.testing.assert_allclose(js, buf)

# the sim backend plans the identical tree the ppermute backend executed
assert sim.plan("bcast", root=ROOT, nbytes=4.0).tree.children == \
    pp.plan("bcast", root=ROOT, nbytes=4.0).tree.children
assert sim.bcast(1e3, root=ROOT).time > 0
print("OK")
""")
