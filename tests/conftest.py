import importlib.util
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# ---------------------------------------------------------------------- #
# Optional-dependency shim: the property tests import `hypothesis` at module
# scope; without this, collection of the whole suite dies on machines that
# lack the dev extras (see requirements-dev.txt).  Prefer the real package,
# fall back to the deterministic stub.
# ---------------------------------------------------------------------- #
if importlib.util.find_spec("hypothesis") is None:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub", os.path.join(os.path.dirname(__file__),
                                         "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    _stub.install()


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N host platform devices.

    Multi-device collective tests must not pollute the main pytest process
    (which keeps the default 1-device view per the project brief).
    """
    import jax

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    # persistent compile cache: repeat suite runs skip the expensive jits.
    # Gated to modern jax: on 0.4.x a warm cache mis-serves the donated-
    # buffer train step (loss 0.0 -> nan on the second suite run, correct
    # when compiled fresh), so there the cache must stay off.
    if hasattr(jax, "shard_map"):
        env.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache_tests")
        env.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")
        env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # Deliberately do NOT forward -O / PYTHONOPTIMIZE: pytest's assertion
    # rewriting protects only in-process test modules, so optimizing the
    # child would strip the snippet's own acceptance asserts and leave it
    # validating nothing.  The CI `python -O` leg gets its source coverage
    # from the in-process tests (kernels/models import directly there).
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
