"""Multi-device collective tests (subprocess with 8 host devices)."""
import jax
import pytest

# The ZeRO-1 train path nests a mesh-less shard_map inside a manual region,
# which needs the modern mesh-context API (jax.shard_map).
NESTED_SHARD_MAP = hasattr(jax, "shard_map")


def test_multilevel_psum_equals_flat(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.collectives import multilevel_psum_tree
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
grads = {"w": jnp.arange(24., dtype=jnp.float32).reshape(4, 6),
         "b": jnp.ones((3,))}
def sync(mode):
    f = lambda g: multilevel_psum_tree(g, "pod", ["data"], mode=mode)
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False))(grads)
flat, ml, mlc = sync("flat"), sync("multilevel"), sync("multilevel_compress")
np.testing.assert_allclose(flat["w"], np.asarray(grads["w"])*4, rtol=1e-6)
np.testing.assert_allclose(ml["w"], flat["w"], rtol=1e-6)
np.testing.assert_allclose(mlc["w"], flat["w"], atol=0.5)  # int8 rounding
np.testing.assert_allclose(ml["b"], flat["b"], rtol=1e-6)
print("OK")
""")


def test_bucketed_psum_tree_matches_monolithic(subproc):
    """The bucketed gradient sync changes COLLECTIVE GRANULARITY only:
    per-bucket fused buffers must reproduce the monolithic flat-buffer
    result for every bucket size, in flat and multilevel modes."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.collectives import bucketed_psum_tree, multilevel_psum_tree
mesh = jax.make_mesh((2, 4), ("pod", "data"))
grads = {"w": jnp.arange(24., dtype=jnp.float32).reshape(4, 6),
         "b": jnp.ones((3,)), "c": [jnp.full((5,), 2.0),
                                    jnp.arange(7., dtype=jnp.float32)]}
def sync(fn):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False))(grads)
mono = sync(lambda g: multilevel_psum_tree(g, "pod", ["data"], mean_over=8))
for mode in ("flat", "multilevel"):
    for bb in (16.0, 64.0, 1e9):  # per-leaf .. single-bucket
        out = sync(lambda g: bucketed_psum_tree(
            g, "pod", ["data"], bucket_bytes=bb, mode=mode, mean_over=8))
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6), mono, out)
import pytest
for bad in ("multilevel_compress", "rsag"):
    try:
        bucketed_psum_tree(grads, "pod", ["data"], bucket_bytes=1.0,
                           mode=bad)
        raise SystemExit(f"mode {bad} must be rejected")
    except ValueError:
        pass
print("OK")
""")


def test_bucketed_apply_updates_matches_dense(subproc):
    """OptConfig.bucket_bytes reroutes the dense gradient sync through
    size-targeted buckets; one optimizer step must land on the same
    parameters as the per-leaf dense path."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import adamw
mesh = jax.make_mesh((2, 4), ("pod", "data"))
params = {"w": jnp.arange(32., dtype=jnp.float32).reshape(8, 4) / 32,
          "b": jnp.ones((8,), jnp.float32)}
grads = {"w": jnp.full((8, 4), 0.25, jnp.float32),
         "b": jnp.arange(8., dtype=jnp.float32) / 8}
def step(cfg):
    opt = adamw.init_opt_state(params, cfg)
    f = lambda p, g, o: adamw.apply_updates(p, g, o, cfg, "pod", 4, 8)
    new_p, _ = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), P(), P()),
                                 out_specs=(P(), P()),
                                 check_vma=False))(params, grads, opt)
    return new_p
for mode in ("flat", "multilevel"):
    dense = step(adamw.OptConfig(comm_mode=mode, zero1=False))
    buck = step(adamw.OptConfig(comm_mode=mode, zero1=False,
                                bucket_bytes=64.0))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7), dense, buck)
print("OK")
""")


def test_opt_config_bucket_validation():
    from repro.optim.adamw import OptConfig

    with pytest.raises(ValueError, match="positive"):
        OptConfig(bucket_bytes=0.0, zero1=False)
    with pytest.raises(ValueError, match="comm_mode"):
        OptConfig(bucket_bytes=1e6, comm_mode="multilevel_compress",
                  zero1=False)
    with pytest.raises(ValueError, match="zero1"):
        OptConfig(bucket_bytes=1e6, comm_mode="multilevel", zero1=True)
    # flat mode never shards the opt state: zero1 flag is inert there
    OptConfig(bucket_bytes=1e6, comm_mode="flat", zero1=True)
    OptConfig(bucket_bytes=1e6, comm_mode="multilevel", zero1=False)


def test_quantize_int8_raises_value_error():
    """Load-bearing validation must be a real exception: a bare assert
    vanishes under ``python -O`` and turns a shape error into silently
    garbled gradients (the CI tier-1 matrix runs a ``python -O`` leg)."""
    import jax.numpy as jnp
    import pytest
    from repro.core.compression import quantize_int8

    with pytest.raises(ValueError, match="1-D buffer"):
        quantize_int8(jnp.zeros((2, 256), jnp.float32))
    with pytest.raises(ValueError, match="block"):
        quantize_int8(jnp.zeros((255,), jnp.float32))
    q, s = quantize_int8(jnp.zeros((512,), jnp.float32))
    assert q.shape == (512,) and s.shape == (2,)


def test_error_feedback_corrects_compressed_drift(subproc):
    """Regression for the dead ``apply_error_feedback`` export: the int8
    slow-axis exchange rounds every step; without the EF residual the bias
    accumulates (~linearly) in a multi-step all-reduce, with it the
    accumulated estimate stays pinned to the exact trajectory."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import (apply_error_feedback, compressed_psum,
                                    quantize_int8, dequantize_int8)

mesh = jax.make_mesh((2,), ("pod",))
N = 512
rng = np.random.default_rng(0)
g_host = rng.normal(size=(2, N)).astype(np.float32) * 1e-3
g = jnp.asarray(g_host).reshape(-1)          # sharded -> local (N,)

def step_noef(acc, g):
    return acc + compressed_psum(g, "pod") / 2
def step_ef(acc, ef, g):
    out, ef = compressed_psum(g, "pod", ef=ef)
    return acc + out / 2, ef

f_noef = jax.jit(shard_map(step_noef, mesh=mesh,
                           in_specs=(P("pod"), P("pod")), out_specs=P("pod")))
f_ef = jax.jit(shard_map(step_ef, mesh=mesh,
                         in_specs=(P("pod"), P("pod"), P("pod")),
                         out_specs=(P("pod"), P("pod"))))

T = 100
exact = np.zeros(N, np.float32)
acc_ne = acc_e = jnp.zeros((2 * N,), jnp.float32)
ef = jnp.zeros((2 * N,), jnp.float32)
for t in range(T):
    exact += g_host.sum(axis=0) / 2
    acc_ne = f_noef(acc_ne, g)
    acc_e, ef = f_ef(acc_e, ef, g)
err_ne = np.abs(np.asarray(acc_ne)[:N] - exact).max()
err_e = np.abs(np.asarray(acc_e)[:N] - exact).max()
# uncorrected drift grows with T; EF keeps the error at one-step rounding
assert err_e < err_ne / 10, (err_e, err_ne)

# apply_error_feedback is the local form of the same correction
gf = jnp.asarray(g_host[0])
corrected, res = apply_error_feedback(gf, jnp.zeros_like(gf))
q, s = quantize_int8(corrected)
np.testing.assert_allclose(np.asarray(corrected - res),
                           np.asarray(dequantize_int8(q, s)), atol=1e-7)
print("OK ef ratio", err_ne / err_e)
""", n_devices=2)


def test_allreduce_tree_threads_ef(subproc):
    """The fused pytree path carries the residual too: Communicator.
    allreduce_tree(mode="multilevel_compress", ef=...) returns (grads,
    new_ef), with compress_ef_zeros sizing the per-rank buffer."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.collectives import compress_ef_zeros
from repro.core.topology import tpu_v5e_multipod
from repro.core import Communicator

topo = tpu_v5e_multipod(pods=2, boards=1, chips_per_board=2)
mesh = jax.make_mesh((2, 2), ("pod", "data"))
comm = Communicator(topo, backend="jax", slow_axis="pod",
                    fast_axes=("data",))
grads = {"w": jnp.full((4, 6), 1e-4, jnp.float32),
         "b": jnp.ones((7,), jnp.float32)}
ef0 = compress_ef_zeros(grads, 2)    # per-rank shard: ceil(31/2 pad) -> 16
assert ef0.shape == (16,), ef0.shape
ef_global = jnp.tile(ef0, 4)         # 4 dp ranks, flat-stacked shards

def sync(g, e):
    return comm.allreduce_tree(g, mode="multilevel_compress", ef=e)
out, ef1 = jax.jit(shard_map(
    sync, mesh=mesh, in_specs=(P(), P(("pod", "data"))),
    out_specs=(P(), P(("pod", "data"))), check_vma=False))(grads, ef_global)
np.testing.assert_allclose(np.asarray(out["w"]),
                           np.asarray(grads["w"]) * 4, atol=0.5)
assert ef1.shape == ef_global.shape
# residual is the quantisation error: folding it back reconstructs the
# exact values on the next exchange (non-zero because 1e-4 rounds at int8)
assert float(jnp.abs(ef1).max()) > 0
print("OK allreduce_tree ef")
""", n_devices=4)


def test_train_step_threads_ef_state(subproc):
    """The optimiser carries the residual: multilevel_compress training
    adds an ``ef`` buffer to the opt state, updates it every step, and
    still reduces the loss — in BOTH the ZeRO-1 (sharded) and dense
    (zero1=False) branches, whose ef spec wiring differs."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.optim.adamw import OptConfig, init_opt_state
cfg = get_config("gpt-100m", smoke=True)
mesh = make_test_mesh(pods=2, data=2, model=1)
params = T.init_model(jax.random.PRNGKey(0), cfg)
for zero1 in (True, False):
    opt_cfg = OptConfig(comm_mode="multilevel_compress", zero1=zero1,
                        lr=1e-3, warmup_steps=2, total_steps=50)
    opt = init_opt_state(params, cfg=opt_cfg, n_slow=2)
    assert "ef" in opt
    # residuals diverge per pod: the state carries one row per pod rank
    for pl, el in zip(jax.tree.leaves(params), jax.tree.leaves(opt["ef"])):
        assert el.shape == (2,) + pl.shape, (el.shape, pl.shape)
    assert all(np.asarray(l).max() == 0 for l in jax.tree.leaves(opt["ef"]))
    p_sh, o_sh, b_sh = STEP.train_in_shardings(cfg, opt_cfg, mesh)
    p = jax.device_put(jax.tree.map(np.asarray, params), p_sh)
    o = jax.device_put(jax.tree.map(np.asarray, opt), o_sh)
    fn = jax.jit(STEP.make_train_fn(cfg, opt_cfg, mesh),
                 donate_argnums=(0, 1))
    losses = []
    for s in range(3):
        t = jax.random.randint(jax.random.PRNGKey(s), (8, 16), 0, cfg.vocab)
        b = {"tokens": jax.device_put(t, b_sh),
             "labels": jax.device_put(t, b_sh)}
        p, o, loss = fn(p, o, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0], (zero1, losses)
    ef_mag = max(float(jnp.abs(l).max()) for l in jax.tree.leaves(o["ef"]))
    assert 0 < ef_mag < 1.0, (zero1, ef_mag)  # residual live and bounded
    # each pod quantises its OWN partial sum: rows must differ (a
    # pod-replicated spec would silently collapse them to pod 0's)
    assert any(float(jnp.abs(l[0] - l[1]).max()) > 0
               for l in jax.tree.leaves(o["ef"])), "pod residuals collapsed"
    print("OK ef state zero1 =", zero1, losses)
""", n_devices=4)


def test_tree_collectives_on_devices(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.trees import build_multilevel_tree
from repro.core.topology import tpu_v5e_multipod
from repro.core import tree_exec
topo = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
mesh1 = jax.make_mesh((8,), ("all",))
x = jnp.arange(8., dtype=jnp.float32)
for root in [0, 3, 7]:
    tree = build_multilevel_tree(topo, root=root)
    out = jax.jit(shard_map(lambda v: tree_exec.tree_bcast(v, tree, "all"),
          mesh=mesh1, in_specs=P("all"), out_specs=P("all")))(x)
    np.testing.assert_allclose(np.asarray(out), np.full(8, float(root)))
    def rd(v):
        r = tree_exec.tree_reduce(v, tree, "all")
        return jnp.where(jax.lax.axis_index("all") == tree.root, r, -1.)
    out = jax.jit(shard_map(rd, mesh=mesh1, in_specs=P("all"),
                            out_specs=P("all")))(x)
    assert float(out[root]) == 28.0, (root, out)
print("OK")
""")


@pytest.mark.skipif(not NESTED_SHARD_MAP,
                    reason="nested mesh-less shard_map needs newer jax")
def test_zero1_multilevel_trains_identically_to_flat(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.optim.adamw import OptConfig, init_opt_state
cfg = get_config("qwen3_4b", smoke=True)
mesh = make_test_mesh(pods=2, data=2, model=2)
ph = jax.tree.map(np.asarray, T.init_model(jax.random.PRNGKey(0), cfg))
results = {}
for mode, zero1 in [("flat", False), ("multilevel", True)]:
    opt_cfg = OptConfig(comm_mode=mode, zero1=zero1, lr=1e-2,
                        warmup_steps=2, total_steps=50)
    p_sh, o_sh, b_sh = STEP.train_in_shardings(cfg, opt_cfg, mesh)
    p = jax.device_put(ph, p_sh)
    opt = jax.device_put(jax.tree.map(np.asarray,
                         init_opt_state(p, opt_cfg)), o_sh)
    fn = jax.jit(STEP.make_train_fn(cfg, opt_cfg, mesh), donate_argnums=(0, 1))
    losses = []
    for s in range(4):
        t = jax.random.randint(jax.random.PRNGKey(s % 2), (8, 16), 0, cfg.vocab)
        b = {"tokens": jax.device_put(t, b_sh), "labels": jax.device_put(t, b_sh)}
        p, opt, loss = fn(p, opt, b)
        losses.append(float(loss))
    results[mode] = losses
    assert losses[-1] < losses[0], (mode, losses)
# ZeRO-1 multilevel must match the flat baseline numerically (same math)
np.testing.assert_allclose(results["flat"], results["multilevel"],
                           rtol=5e-3, atol=5e-3)
print("OK")
""")


@pytest.mark.skipif(not NESTED_SHARD_MAP,
                    reason="model-sharded KV-cache decode diverges (~0.45 "
                           "max logit err) under the legacy SPMD partitioner"
                           " — identical program is exact unsharded; needs "
                           "newer jax")
def test_decode_sharded_cache(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.models.sharding import param_shardings
cfg = get_config("qwen3_4b", smoke=True)
mesh = make_test_mesh(pods=1, data=2, model=2)
params = T.init_model(jax.random.PRNGKey(0), cfg)
params = jax.device_put(params, param_shardings(params, mesh))
B, S = 4, 8
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
with mesh:
    logits_p, cache, pos = jax.jit(
        lambda p, t: T.prefill(p, cfg, {"tokens": t}, s_max=S + 4)
    )(params, toks[:, :S])
    c_sh = STEP.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache))
    cache = jax.device_put(cache, c_sh)
    logits_d, _ = jax.jit(
        lambda p, c, t, i: T.decode_step(p, cfg, c, t, i)
    )(params, cache, toks[:, S:S+1], jnp.int32(pos))
full = T.model_fwd(params, cfg, {"tokens": toks})
np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                           np.asarray(full[:, S]), atol=0.1, rtol=0.05)
print("OK")
""")


def test_opt_config_quant_kernel_validation():
    from repro.optim.adamw import OptConfig

    OptConfig(comm_mode="multilevel_compress", quant_kernel=True)
    OptConfig(comm_mode="multilevel_compress", quant_kernel=False)
    with pytest.raises(ValueError, match="quant_kernel"):
        OptConfig(quant_kernel=True)           # default mode: multilevel
    with pytest.raises(ValueError, match="quant_kernel"):
        OptConfig(comm_mode="flat", quant_kernel=False)


def test_compress_ef_zeros_tile():
    """tile rounds the PER-RANK shard up so the fused Pallas quantiser sees
    a pad-free buffer; default tile=1 keeps the historic sizing."""
    import jax.numpy as jnp
    from repro.core.collectives import compress_ef_zeros
    from repro.core.compression import QTILE

    grads = {"w": jnp.zeros((4, 6)), "b": jnp.zeros((7,))}   # 31 elements
    assert compress_ef_zeros(grads, 2).shape == (16,)
    ef = compress_ef_zeros(grads, 2, tile=QTILE)
    assert ef.shape == (QTILE,)
    assert compress_ef_zeros(grads, 1, tile=4).shape == (32,)


def test_allreduce_tree_ef_tile_padding(subproc):
    """multilevel_psum_tree grows the flat buffer to ef.size * fast_degree
    when the residual was tiled up (compress_ef_zeros tile=...), and rejects
    residuals too small for the pytree."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.collectives import compress_ef_zeros, multilevel_psum_tree

mesh = jax.make_mesh((2, 2), ("pod", "data"))
grads = {"w": jnp.full((4, 6), 1e-4, jnp.float32),
         "b": jnp.ones((7,), jnp.float32)}
ef0 = compress_ef_zeros(grads, 2, tile=12)   # 31 -> pad to 48 -> shard 24
assert ef0.shape == (24,), ef0.shape
ef_global = jnp.tile(ef0, 4)

def sync(g, e):
    return multilevel_psum_tree(g, "pod", ("data",),
                                mode="multilevel_compress", ef=e)
out, ef1 = jax.jit(shard_map(
    sync, mesh=mesh, in_specs=(P(), P(("pod", "data"))),
    out_specs=(P(), P(("pod", "data"))), check_vma=False))(grads, ef_global)
np.testing.assert_allclose(np.asarray(out["w"]),
                           np.asarray(grads["w"]) * 4, atol=0.5)
assert ef1.shape == ef_global.shape

def sync_small(g, e):
    return multilevel_psum_tree(g, "pod", ("data",),
                                mode="multilevel_compress", ef=e)
try:
    jax.jit(shard_map(
        sync_small, mesh=mesh, in_specs=(P(), P(("pod", "data"))),
        out_specs=(P(), P(("pod", "data"))), check_vma=False))(
        grads, jnp.zeros((4 * 8,), jnp.float32))   # shard 8 < needed 16
    raise SystemExit("expected ValueError for too-small ef")
except ValueError as e:
    assert "too small" in str(e), e
print("OK ef tile padding")
""")
