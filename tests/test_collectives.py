"""Multi-device collective tests (subprocess with 8 host devices)."""
import jax
import pytest

# The ZeRO-1 train path nests a mesh-less shard_map inside a manual region,
# which needs the modern mesh-context API (jax.shard_map).
NESTED_SHARD_MAP = hasattr(jax, "shard_map")


def test_multilevel_psum_equals_flat(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.collectives import multilevel_psum_tree
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
grads = {"w": jnp.arange(24., dtype=jnp.float32).reshape(4, 6),
         "b": jnp.ones((3,))}
def sync(mode):
    f = lambda g: multilevel_psum_tree(g, "pod", ["data"], mode=mode)
    return jax.jit(shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False))(grads)
flat, ml, mlc = sync("flat"), sync("multilevel"), sync("multilevel_compress")
np.testing.assert_allclose(flat["w"], np.asarray(grads["w"])*4, rtol=1e-6)
np.testing.assert_allclose(ml["w"], flat["w"], rtol=1e-6)
np.testing.assert_allclose(mlc["w"], flat["w"], atol=0.5)  # int8 rounding
np.testing.assert_allclose(ml["b"], flat["b"], rtol=1e-6)
print("OK")
""")


def test_tree_collectives_on_devices(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.trees import build_multilevel_tree
from repro.core.topology import tpu_v5e_multipod
from repro.core import tree_exec
topo = tpu_v5e_multipod(pods=2, boards=2, chips_per_board=2)
mesh1 = jax.make_mesh((8,), ("all",))
x = jnp.arange(8., dtype=jnp.float32)
for root in [0, 3, 7]:
    tree = build_multilevel_tree(topo, root=root)
    out = jax.jit(shard_map(lambda v: tree_exec.tree_bcast(v, tree, "all"),
          mesh=mesh1, in_specs=P("all"), out_specs=P("all")))(x)
    np.testing.assert_allclose(np.asarray(out), np.full(8, float(root)))
    def rd(v):
        r = tree_exec.tree_reduce(v, tree, "all")
        return jnp.where(jax.lax.axis_index("all") == tree.root, r, -1.)
    out = jax.jit(shard_map(rd, mesh=mesh1, in_specs=P("all"),
                            out_specs=P("all")))(x)
    assert float(out[root]) == 28.0, (root, out)
print("OK")
""")


@pytest.mark.skipif(not NESTED_SHARD_MAP,
                    reason="nested mesh-less shard_map needs newer jax")
def test_zero1_multilevel_trains_identically_to_flat(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.optim.adamw import OptConfig, init_opt_state
cfg = get_config("qwen3_4b", smoke=True)
mesh = make_test_mesh(pods=2, data=2, model=2)
ph = jax.tree.map(np.asarray, T.init_model(jax.random.PRNGKey(0), cfg))
results = {}
for mode, zero1 in [("flat", False), ("multilevel", True)]:
    opt_cfg = OptConfig(comm_mode=mode, zero1=zero1, lr=1e-2,
                        warmup_steps=2, total_steps=50)
    p_sh, o_sh, b_sh = STEP.train_in_shardings(cfg, opt_cfg, mesh)
    p = jax.device_put(ph, p_sh)
    opt = jax.device_put(jax.tree.map(np.asarray,
                         init_opt_state(p, opt_cfg)), o_sh)
    fn = jax.jit(STEP.make_train_fn(cfg, opt_cfg, mesh), donate_argnums=(0, 1))
    losses = []
    for s in range(4):
        t = jax.random.randint(jax.random.PRNGKey(s % 2), (8, 16), 0, cfg.vocab)
        b = {"tokens": jax.device_put(t, b_sh), "labels": jax.device_put(t, b_sh)}
        p, opt, loss = fn(p, opt, b)
        losses.append(float(loss))
    results[mode] = losses
    assert losses[-1] < losses[0], (mode, losses)
# ZeRO-1 multilevel must match the flat baseline numerically (same math)
np.testing.assert_allclose(results["flat"], results["multilevel"],
                           rtol=5e-3, atol=5e-3)
print("OK")
""")


@pytest.mark.skipif(not NESTED_SHARD_MAP,
                    reason="model-sharded KV-cache decode diverges (~0.45 "
                           "max logit err) under the legacy SPMD partitioner"
                           " — identical program is exact unsharded; needs "
                           "newer jax")
def test_decode_sharded_cache(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.models.sharding import param_shardings
cfg = get_config("qwen3_4b", smoke=True)
mesh = make_test_mesh(pods=1, data=2, model=2)
params = T.init_model(jax.random.PRNGKey(0), cfg)
params = jax.device_put(params, param_shardings(params, mesh))
B, S = 4, 8
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
with mesh:
    logits_p, cache, pos = jax.jit(
        lambda p, t: T.prefill(p, cfg, {"tokens": t}, s_max=S + 4)
    )(params, toks[:, :S])
    c_sh = STEP.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache))
    cache = jax.device_put(cache, c_sh)
    logits_d, _ = jax.jit(
        lambda p, c, t, i: T.decode_step(p, cfg, c, t, i)
    )(params, cache, toks[:, S:S+1], jnp.int32(pos))
full = T.model_fwd(params, cfg, {"tokens": toks})
np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                           np.asarray(full[:, S]), atol=0.1, rtol=0.05)
print("OK")
""")
