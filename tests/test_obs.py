"""Tests for the observability layer: deferred tracing equivalence, Chrome
trace-event export (determinism, validity, round-trip), the metrics
registry, structured logging, and the measured-cost feedback loop closing
on a mis-modeled link."""
import dataclasses
import json

import pytest

from repro.core import Communicator
from repro.core.engine import Engine
from repro.core.simulator import simulate_concurrent, simulate_rounds
from repro.core.topology import paper_fig8_topology
from repro.obs import (PID_LINKS, PID_PLANNER, PID_PROGRAMS, PID_REQUESTS,
                       Counter, FeedbackLoop, MetricsRegistry, Tracer,
                       get_logger, percentile, set_json)

MIB = float(1 << 20)


@pytest.fixture(scope="module")
def fig8():
    return paper_fig8_topology()


@pytest.fixture(scope="module")
def lowered(fig8):
    comm = Communicator(fig8, policy="auto", backend="sim")
    return comm.plan("allreduce", nbytes=MIB).lower(MIB)


# ------------------------------------------------------------------ #
# Deferred recording: zero hot-path cost, identical trace.
# ------------------------------------------------------------------ #

def test_deferred_trace_equals_inline(fig8, lowered):
    """The default tracer queues a replay closure instead of recording;
    materializing it must yield byte-for-byte the events inline recording
    produces, and the simulated completions must not depend on tracing."""
    plain = simulate_rounds(lowered, fig8)
    deferred = Tracer()
    inline = Tracer(defer=False)
    got_d = simulate_rounds(lowered, fig8, tracer=deferred, label="x")
    got_i = simulate_rounds(lowered, fig8, tracer=inline, label="x")
    assert got_d == plain and got_i == plain
    # nothing recorded yet on the deferred tracer — the live run paid one
    # closure append, not one append per send
    assert not deferred.links and not deferred.spans
    assert deferred.n_events() == inline.n_events() > 0
    assert deferred.links == inline.links
    assert deferred.instants == inline.instants


def test_deferred_concurrent_equals_inline(fig8):
    comm = Communicator(fig8, policy="paper", backend="sim")
    progs = [comm.plan("allreduce", nbytes=2 * MIB).lower(2 * MIB),
             comm.plan("bcast", nbytes=MIB).lower(MIB)]
    deferred, inline = Tracer(), Tracer(defer=False)
    got_d = simulate_concurrent(progs, fig8, tracer=deferred,
                                labels=["ar", "bc"])
    got_i = simulate_concurrent(progs, fig8, tracer=inline,
                                labels=["ar", "bc"])
    assert got_d == got_i == simulate_concurrent(progs, fig8)
    assert deferred.n_events() == inline.n_events()
    assert deferred.links == inline.links
    assert deferred.spans == inline.spans


# ------------------------------------------------------------------ #
# Chrome trace-event export: determinism, validity, round-trip.
# ------------------------------------------------------------------ #

def _traced_run(fig8):
    tr = Tracer()
    comm = Communicator(fig8, policy="auto", backend="sim", tracer=tr)
    eng = Engine(comm, policy="priority", age_rate=MIB)
    for _ in range(3):
        eng.issue("allreduce", 2 * MIB)
    eng.issue("bcast", MIB, root=0, priority=1.0)
    eng.wait_all()
    return tr


def test_trace_export_deterministic(fig8):
    """Same schedule -> same JSON, independent of dict/set iteration
    order.  (Planner instants carry wall-clock ts, so determinism is
    asserted on the virtual-time pids and on full structure modulo ts.)"""
    a = _traced_run(fig8).to_chrome()
    b = _traced_run(fig8).to_chrome()

    def stable(doc):
        evs = []
        for e in doc["traceEvents"]:
            e = dict(e)
            if e["pid"] == PID_PLANNER:
                e.pop("ts", None)
            evs.append(e)
        return json.dumps({**doc, "traceEvents": evs}, sort_keys=True)

    assert stable(a) == stable(b)


def test_trace_is_valid_chrome_json(fig8):
    doc = _traced_run(fig8).to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs, "empty trace"
    meta = [e for e in evs if e["ph"] == "M"]
    real = [e for e in evs if e["ph"] != "M"]
    # metadata names every process and track
    assert {e["name"] for e in meta} == {"process_name", "thread_name"}
    named_pids = {e["pid"] for e in meta if e["name"] == "process_name"}
    for e in real:
        assert set(e) >= {"name", "ph", "pid", "tid", "ts"}
        assert e["ph"] in ("X", "i")
        assert e["pid"] in named_pids
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
        else:
            assert e["s"] == "t"
    # events are sorted: ts is monotone within each (pid, tid) track
    seen: dict = {}
    for e in real:
        k = (e["pid"], e["tid"])
        assert e["ts"] >= seen.get(k, 0.0)
        seen[k] = e["ts"]
    # all four subsystems landed on the one timeline
    assert {PID_LINKS, PID_PROGRAMS, PID_PLANNER} <= {e["pid"] for e in real}


def test_trace_roundtrip_and_save(fig8, tmp_path):
    tr = _traced_run(fig8)
    doc = tr.to_chrome()
    assert json.loads(json.dumps(doc)) == doc
    p = tmp_path / "run.trace.json"
    tr.save(str(p))
    assert json.load(open(p)) == doc


def test_engine_spans_carry_predictions(fig8):
    doc = _traced_run(fig8).to_chrome()
    spans = [e for e in doc["traceEvents"]
             if e["pid"] == PID_PROGRAMS and e["ph"] == "X"
             and e["name"] in ("allreduce", "bcast")]
    assert len(spans) == 4
    for e in spans:
        assert e["args"]["measured_s"] > 0
        assert e["args"]["predicted_s"] > 0
    plan_instants = [e for e in doc["traceEvents"]
                     if e["pid"] == PID_PLANNER and e["ph"] == "i"]
    assert plan_instants
    assert any(e["args"]["hit"] for e in plan_instants)  # 3x same allreduce
    for e in plan_instants:
        assert {"op", "algorithm", "segment", "hit"} <= set(e["args"])


def test_scheduler_request_lifecycle_spans(fig8):
    from repro.serving import SLO, Scheduler, SimExecutor, make_requests

    tr = Tracer()
    sch = Scheduler(SimExecutor(vocab=64, block_size=4), n_blocks=17,
                    block_size=4, max_slots=2, s_max=32,
                    prefill_token_budget=64,
                    compute_model=lambda pre, dec: 1e-3 * (1 + pre + dec),
                    tracer=tr)
    sch.run(make_requests([0.0, 0.002, 0.004, 0.006], vocab=64,
                          prompt_len=6, gen_len=4, slo=None, seed=0))
    doc = tr.to_chrome()
    req = [e for e in doc["traceEvents"]
           if e["pid"] == PID_REQUESTS and e["ph"] == "X"]
    names = {e["name"] for e in req}
    assert {"prefill", "decode"} <= names
    assert "waiting" in names  # max_slots=2 forces queueing
    decodes = [e for e in req if e["name"] == "decode"]
    assert len(decodes) == 4
    for e in decodes:
        assert e["args"]["ttft_s"] > 0 and e["args"]["tokens"] == 4


# ------------------------------------------------------------------ #
# Metrics registry.
# ------------------------------------------------------------------ #

def test_counter_is_monotonic():
    c = Counter("x")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    c.reset()
    assert c.value == 0


def test_registry_get_or_create_and_kind_guard():
    m = MetricsRegistry()
    assert m.counter("a") is m.counter("a")
    m.gauge("g").set(7)
    m.histogram("h").observe(1.0)
    with pytest.raises(ValueError, match="already registered"):
        m.histogram("a")
    snap = m.snapshot()
    assert snap["a"] == 0 and snap["g"] == 7.0
    assert snap["h"]["count"] == 1 and snap["h"]["p50"] == 1.0
    assert m.names() == ["a", "g", "h"]


def test_percentile_matches_numpy_and_nan_on_empty():
    import numpy as np

    xs = [5.0, 1.0, 9.0, 3.0]
    assert percentile(xs, 50) == float(np.percentile(xs, 50))
    assert percentile([], 99) != percentile([], 99)  # NaN


# ------------------------------------------------------------------ #
# Structured logging.
# ------------------------------------------------------------------ #

def test_logger_human_format_matches_print(capsys):
    get_logger("train").info("step 3 | loss 1.234", event="step", step=3)
    assert capsys.readouterr().out == "[train] step 3 | loss 1.234\n"


def test_logger_json_mode(capsys):
    set_json(True)
    try:
        get_logger("serve").info("report", event="report", p99_ttft_s=0.25)
    finally:
        set_json(False)
    rec = json.loads(capsys.readouterr().out)
    assert rec == {"logger": "serve", "msg": "report", "event": "report",
                   "p99_ttft_s": 0.25}
    # and the switch actually reverted
    get_logger("serve").info("done")
    assert capsys.readouterr().out == "[serve] done\n"


# ------------------------------------------------------------------ #
# Feedback loop: measured costs correct the plan selector.
# ------------------------------------------------------------------ #

def _regret(comm, truth, nbytes):
    low = comm.plan("allreduce", nbytes=nbytes).lower(nbytes)
    t_sel = max(simulate_rounds(low, truth).values())
    oracle = Communicator(truth, policy=comm.policy, backend="sim")
    best = oracle.plan("allreduce", nbytes=nbytes).lower(nbytes)
    return t_sel / max(simulate_rounds(best, truth).values()) - 1.0


def test_feedback_corrects_mismodeled_wan(fig8):
    """THE closed-loop regression: the model overstates WAN bandwidth 8x,
    so the argmin picks a plan that is >10% worse on the true network.
    One traced execution -> residuals expose the WAN class -> refit
    recovers the true bandwidth through refit_levels -> the re-planned
    regret drops to ~0."""
    truth = fig8
    model = paper_fig8_topology()
    model.levels = tuple(
        dataclasses.replace(l, bandwidth=l.bandwidth * 8.0)
        if l.name == "wan" else l for l in model.levels)
    comm = Communicator(model, policy="auto", backend="sim")
    nb = 16 * MIB

    pre_regret = _regret(comm, truth, nb)
    assert pre_regret > 0.10

    fb = FeedbackLoop(comm, threshold=0.15)
    pred, meas = fb.run("allreduce", nb, truth=truth)
    assert meas > pred * 1.5  # the model is optimistic on the truth
    wan = next(r for r in fb.residual_table() if r["name"] == "wan")
    assert wan["measured_over_model"] > 2.0

    report = fb.maybe_refit()
    assert report.refit and report.worst > 0.15
    wan_i = next(i for i, l in enumerate(truth.levels) if l.name == "wan")
    assert comm.topo.levels[wan_i].bandwidth == pytest.approx(
        truth.levels[wan_i].bandwidth, rel=1e-6)

    post_regret = _regret(comm, truth, nb)
    assert post_regret < pre_regret
    assert post_regret < 0.01
    # post-refit evidence is judged against the NEW model: residual ~ 1
    pred2, meas2 = fb.run("allreduce", nb, truth=truth)
    assert meas2 == pytest.approx(pred2, rel=1e-6)
    wan2 = next(r for r in fb.residual_table() if r["name"] == "wan")
    assert wan2["measured_over_model"] == pytest.approx(1.0, rel=1e-6)


def test_feedback_no_drift_is_a_noop(fig8):
    comm = Communicator(paper_fig8_topology(), policy="auto", backend="sim")
    fb = FeedbackLoop(comm, threshold=0.15)
    fb.run("allreduce", MIB)  # truth defaults to the model itself
    report = fb.maybe_refit()
    assert not report.refit and report.worst < 0.05
    assert fb.refits == 0


def test_feedback_rejects_view_communicators(fig8):
    from repro.core.topology import magpie_site_view

    comm = Communicator(fig8, policy="paper", backend="sim",
                        view=magpie_site_view(fig8))
    with pytest.raises(ValueError, match="view-based"):
        FeedbackLoop(comm)
