"""Property-based tests of the paper's tree builders and multilevel composer."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topology import Topology, WAN, LAN, SMP, paper_fig8_topology
from repro.core.trees import (binomial_tree, flat_tree, chain_tree,
                              postal_tree, build_multilevel_tree,
                              PAPER_POLICY, LevelPolicy, Tree)
from repro.core.tree_exec import tree_rounds


@st.composite
def topologies(draw):
    """Random 2-strata topologies (sites -> machines -> procs)."""
    sites = draw(st.integers(1, 4))
    coords = []
    mid = 0
    for s in range(sites):
        machines = draw(st.integers(1, 3))
        for m in range(machines):
            procs = draw(st.integers(1, 5))
            coords += [[s, mid]] * procs
            mid += 1
    return Topology(np.array(coords), [WAN, LAN, SMP])


@given(st.integers(1, 64), st.integers(0, 63))
def test_binomial_tree_invariants(n, root_idx):
    members = list(range(n))
    root = members[root_idx % n]
    t = binomial_tree(root, members)
    t.validate()
    assert sorted(t.members()) == members
    rounds = 0 if n == 1 else int(np.ceil(np.log2(n)))
    # tree depth bounded by the round count; schedule takes exactly `rounds`
    assert t.depth() <= rounds
    if n > 1:
        assert len(tree_rounds(t)) == rounds


@given(st.integers(1, 40), st.sampled_from(["flat", "chain"]))
def test_flat_chain_invariants(n, kind):
    members = list(range(n))
    t = flat_tree(0, members) if kind == "flat" else chain_tree(0, members)
    t.validate()
    assert sorted(t.members()) == members
    if kind == "flat":
        assert t.depth() <= 1
    else:
        assert t.depth() == n - 1


@given(st.integers(1, 50), st.integers(1, 6))
def test_postal_tree_spanning(n, lam):
    t = postal_tree(0, list(range(n)), lam=lam)
    t.validate()
    assert sorted(t.members()) == list(range(n))


@settings(deadline=None, max_examples=60)
@given(topologies(), st.data())
def test_multilevel_tree_properties(topo, data):
    root = data.draw(st.integers(0, topo.nprocs - 1))
    t = build_multilevel_tree(topo, root)
    t.validate()
    assert sorted(t.members()) == list(range(topo.nprocs))
    # THE paper's claim: exactly (#groups at stratum 0) - 1 edges cross the
    # slowest level, and within each site exactly (#machines - 1) edges cross
    # the LAN level.
    lvl_count = {0: 0, 1: 0, 2: 0}
    for p, cs in t.children.items():
        for c in cs:
            lvl_count[topo.comm_level(p, c)] += 1
    n_sites = len(set(topo.coords[:, 0]))
    n_machines = len(set(topo.coords[:, 1]))
    assert lvl_count[0] == n_sites - 1
    assert lvl_count[1] == n_machines - n_sites
    assert lvl_count[2] == topo.nprocs - n_machines


@settings(deadline=None, max_examples=30)
@given(topologies(), st.data())
def test_tree_rounds_schedule(topo, data):
    """Round schedule: every non-root receives exactly once, senders only
    send after receiving, one injection per sender per round."""
    root = data.draw(st.integers(0, topo.nprocs - 1))
    t = build_multilevel_tree(topo, root)
    rounds = tree_rounds(t)
    received = {root: -1}
    for r, edges in enumerate(rounds):
        senders = [s for s, _ in edges]
        assert len(senders) == len(set(senders)), "double injection"
        for s, d in edges:
            assert s in received and received[s] < r
            assert d not in received, "duplicate receive"
            received[d] = r
    assert set(received) == set(t.members())


def test_fig8_tree_is_fig4():
    """The paper's Fig. 4 example: root at SDSC -> exactly one WAN edge, one
    LAN edge between the two NCSA/ANL machines."""
    topo = paper_fig8_topology()
    t = build_multilevel_tree(topo, root=0, policy=PAPER_POLICY)
    wan = [(p, c) for p, cs in t.children.items() for c in cs
           if topo.comm_level(p, c) == 0]
    lan = [(p, c) for p, cs in t.children.items() for c in cs
           if topo.comm_level(p, c) == 1]
    assert len(wan) == 1 and wan[0][0] == 0
    assert len(lan) == 1
    # root serves its WAN child first (Fig. 4: slow edges go first)
    assert topo.comm_level(0, t.children[0][0]) == 0


def test_root_not_first_member():
    topo = paper_fig8_topology()
    t = build_multilevel_tree(topo, root=40)  # inside the 3rd machine
    t.validate()
    assert t.root == 40


def test_select_tree_is_argmin_of_candidates():
    """Beyond-paper: cost-model-driven selection never loses to either the
    multilevel tree or the oblivious binomial on any (op, size) — closing
    the gather/scatter bandwidth-concentration weakness.  (Migrated off the
    deprecated trees.best_tree shim, which pytest now escalates to an
    error — see pytest.ini.)"""
    from repro.core import schedule as S
    from repro.core.communicator import select_tree
    from repro.core.simulator import simulate

    topo = paper_fig8_topology()
    for op in ("bcast", "reduce", "gather", "scatter", "allreduce"):
        for nb in (1e3, 512e3):
            fn = getattr(S, op)
            t_ml = max(simulate(fn(build_multilevel_tree(topo, 0), nb),
                                topo).values())
            t_bin = max(simulate(fn(binomial_tree(0, range(topo.nprocs)), nb),
                                 topo).values())
            chosen, _ = select_tree(topo, 0, op, nb, policy="auto")
            t_best = max(simulate(fn(chosen, nb), topo).values())
            assert t_best <= min(t_ml, t_bin) + 1e-12, (op, nb)


def test_best_tree_shim_warns_and_still_works():
    """The deprecated shim must emit a real DeprecationWarning (escalated to
    an error by pytest.ini for unsuspecting callers) AND still return the
    argmin tree, so downstream code migrates on a working path."""
    import pytest
    from repro.core.trees import best_tree

    topo = paper_fig8_topology()
    with pytest.warns(DeprecationWarning,
                      match="trees.best_tree is deprecated"):
        t = best_tree(topo, 0, "bcast", 64e3)
    t.validate()
    assert sorted(t.members()) == list(range(topo.nprocs))
    # unexpected (unasserted) use raises under the suite's warning filter
    with pytest.raises(DeprecationWarning):
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            best_tree(topo, 0, "bcast", 1e3)
