"""Serving subsystem: paged-attention numerics, block allocator, scheduler,
and open-loop load generation.

The numeric core — paged decode must be *bit-identical* to the dense cache
path for full-attention stacks — runs in-process on the default 1-device
view; the multi-request greedy-equivalence test drives the real
``JaxExecutor`` through the scheduler and checks every generated token
against a per-request dense reference decode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (SLO, BlockAllocator, JaxExecutor, OutOfBlocks,
                           ReqState, Scheduler, SimExecutor, blocks_needed,
                           build_block_tables, bursty_arrivals,
                           default_compute_model, make_requests,
                           poisson_arrivals, summarize)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------- #
# kv_cache: host-side block bookkeeping
# ---------------------------------------------------------------------- #

def test_blocks_needed():
    assert blocks_needed(0, 16) == 1     # a request always holds >= 1 block
    assert blocks_needed(1, 16) == 1
    assert blocks_needed(16, 16) == 1
    assert blocks_needed(17, 16) == 2
    assert blocks_needed(33, 16) == 3


def test_block_allocator_never_hands_out_null_block():
    alloc = BlockAllocator(8, 4)
    assert alloc.capacity == 7
    got = alloc.alloc(7)
    assert 0 not in got
    assert sorted(got) == list(range(1, 8))
    assert got == list(range(1, 8))      # deterministic low-id-first order


def test_block_allocator_all_or_nothing_oom():
    alloc = BlockAllocator(4, 4)
    alloc.alloc(2)
    n_free_before = alloc.n_free
    with pytest.raises(OutOfBlocks):
        alloc.alloc(2)                   # only 1 free
    assert alloc.n_free == n_free_before  # nothing partially taken
    assert alloc.can_alloc(1) and not alloc.can_alloc(2)


def test_block_allocator_free_validation():
    alloc = BlockAllocator(4, 4)
    got = alloc.alloc(2)
    alloc.free(got)
    assert alloc.n_free == alloc.capacity
    with pytest.raises(ValueError):
        alloc.free([got[0]])             # double free
    with pytest.raises(ValueError):
        alloc.free([0])                  # null block is not freeable
    with pytest.raises(ValueError):
        alloc.free([99])                 # out of range
    with pytest.raises(ValueError):
        BlockAllocator(1, 4)             # no room beside the null block


def test_build_block_tables_pads_with_null_block():
    tab = build_block_tables([[3, 1], [2]], max_blocks=3, n_slots=4)
    assert tab.dtype == np.int32 and tab.shape == (4, 3)
    np.testing.assert_array_equal(
        tab, [[3, 1, 0], [2, 0, 0], [0, 0, 0], [0, 0, 0]])
    with pytest.raises(ValueError):
        build_block_tables([[1, 2, 3, 4]], max_blocks=3)


# ---------------------------------------------------------------------- #
# loadgen: open-loop arrival processes
# ---------------------------------------------------------------------- #

def test_poisson_arrivals_rate_and_determinism():
    a = poisson_arrivals(50.0, 40.0, seed=3)
    b = poisson_arrivals(50.0, 40.0, seed=3)
    assert a == b
    assert all(0 <= t < 40.0 for t in a)
    assert a == sorted(a)
    # ~2000 expected arrivals: the realized rate should be within 10%
    assert 0.9 * 2000 < len(a) < 1.1 * 2000
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 1.0)


def test_bursty_arrivals_preserve_mean_rate():
    a = bursty_arrivals(50.0, 60.0, seed=0, burst_factor=8.0, duty=0.125)
    assert 0.85 * 3000 < len(a) < 1.15 * 3000
    # ON windows really are denser: first 12.5% of each period carries
    # burst_factor/1 = 8x the average density
    on = sum(1 for t in a if (t % 2.0) / 2.0 < 0.125)
    assert on > 0.8 * len(a)             # duty 1/8 at 8x rate => ~all arrivals
    with pytest.raises(ValueError):
        bursty_arrivals(50.0, 1.0, burst_factor=10.0, duty=0.2)  # >1 mean
    with pytest.raises(ValueError):
        bursty_arrivals(50.0, 1.0, duty=1.5)


def test_make_requests_ranges_and_determinism():
    arr = [0.0, 0.5, 1.0]
    r1 = make_requests(arr, vocab=128, prompt_len=(4, 9), gen_len=(2, 5),
                       slo=SLO(0.2, 0.05), seed=7)
    r2 = make_requests(arr, vocab=128, prompt_len=(4, 9), gen_len=(2, 5),
                       slo=SLO(0.2, 0.05), seed=7)
    assert len(r1) == 3
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.prompt, b.prompt)
        assert a.max_new_tokens == b.max_new_tokens
    for r in r1:
        assert 4 <= r.prompt_len <= 9 and 2 <= r.max_new_tokens <= 5
        assert r.prompt.dtype == np.int32 and int(r.prompt.max()) < 128
        assert r.state is ReqState.WAITING
        assert r.slo.ttft_deadline(r.arrival_s) == r.arrival_s + 0.2


# ---------------------------------------------------------------------- #
# scheduler: continuous batching over the token-fabricating executor
# ---------------------------------------------------------------------- #

def _sched(**kw):
    base = dict(n_blocks=1 + 16, block_size=4, max_slots=4, s_max=32,
                prefill_token_budget=64)
    base.update(kw)
    return Scheduler(SimExecutor(vocab=64, block_size=base["block_size"]),
                     **base)


def test_scheduler_validates_arguments():
    with pytest.raises(ValueError):
        _sched(policy="lifo")
    with pytest.raises(ValueError):
        _sched(mode="sparse")
    with pytest.raises(ValueError):
        _sched(s_max=30)                 # not a multiple of block_size


def test_continuous_batching_requests_join_and_leave():
    """Staggered arrivals with a slow compute model: the running batch must
    overlap requests (continuous batching) and every request must finish
    with exactly its requested token count and sane timestamps."""
    arr = [0.0, 0.0, 0.01, 0.02, 0.03, 0.04]
    reqs = make_requests(arr, vocab=64, prompt_len=(3, 9), gen_len=(4, 12),
                         seed=1)
    sch = _sched(compute_model=default_compute_model(1e9,
                                                     flops_per_s=1e12))
    rep = sch.run(reqs)
    assert all(r.state is ReqState.DONE for r in reqs)
    assert 2 <= rep.max_concurrent <= 4
    for r in reqs:
        assert len(r.tokens) == r.max_new_tokens
        assert r.first_token_s >= r.arrival_s
        assert r.finish_s >= r.first_token_s
        assert r.pos == r.prompt_len + r.max_new_tokens - 1
        assert r.blocks == [] and r.slot == -1   # resources returned
    s = rep.summary()
    assert s["n_done"] == 6 and s["n_shed"] == 0
    assert s["throughput_tok_s"] > 0


def test_paged_beats_dense_at_equal_block_budget():
    """Dense reserves worst-case ceil(s_max/block) blocks per request at
    admission; paged allocates on demand — at an equal budget paged must
    sustain strictly more concurrent requests."""
    conc = {}
    for mode in ("paged", "dense"):
        reqs = make_requests([0.0] * 12, vocab=64, prompt_len=4, gen_len=4,
                             seed=2)
        sch = _sched(mode=mode, n_blocks=1 + 3 * 8, block_size=4, s_max=32,
                     max_slots=12)      # dense fits exactly 3 requests
        rep = sch.run(reqs)
        assert all(r.state is ReqState.DONE for r in reqs)
        conc[mode] = rep.max_concurrent
    assert conc["dense"] == 3
    assert conc["paged"] > conc["dense"]


def test_slo_policy_sheds_and_beats_fifo_tail():
    """Overload: fifo's queue pushes p99 TTFT far past the deadline; the slo
    policy sheds expired requests and keeps the served tail inside it."""
    slo = SLO(ttft_s=0.05, tpot_s=0.02)
    arr = poisson_arrivals(200.0, 1.0, seed=1)   # ~200 req into a tiny server
    out = {}
    for policy in ("fifo", "slo"):
        reqs = make_requests(arr, vocab=64, prompt_len=(4, 12), gen_len=(4, 8),
                             slo=slo, seed=2)
        sch = _sched(policy=policy, max_slots=2, prefill_token_budget=16,
                     compute_model=default_compute_model(
                         1e9, flops_per_s=0.5e12))
        out[policy] = (sch.run(reqs).summary(), reqs)
    f, _ = out["fifo"]
    s, sreqs = out["slo"]
    assert f["ttft_p99_s"] > slo.ttft_s          # fifo is genuinely overloaded
    assert s["ttft_p99_s"] < f["ttft_p99_s"]
    assert s["n_shed"] > 0
    for r in sreqs:
        if r.state is ReqState.SHED:
            assert r.finish_s is not None and r.first_token_s is None
    assert 0 < s["slo_attainment"] <= 1.0


def test_over_budget_prompt_still_admitted_when_idle():
    reqs = make_requests([0.0], vocab=64, prompt_len=24, gen_len=2, seed=0)
    rep = _sched(prefill_token_budget=8).run(reqs)   # prompt 3x the budget
    assert reqs[0].state is ReqState.DONE
    assert rep.steps >= 1


def test_impossible_request_fails_loudly():
    reqs = make_requests([0.0], vocab=64, prompt_len=100, gen_len=2, seed=0)
    with pytest.raises(RuntimeError, match="needs more memory"):
        _sched(n_blocks=1 + 8, s_max=128).run(reqs)  # 25 blocks > capacity 8


def test_all_stalled_oom_evicts_youngest():
    """Two growing requests exhaust the pool; the deadlock breaks by
    shedding the youngest and recycling its blocks into the survivor."""
    reqs = make_requests([0.0, 0.001], vocab=64, prompt_len=4, gen_len=12,
                         seed=0)
    # nonzero step cost so the second arrival lands while the first runs
    sch = _sched(n_blocks=1 + 4, block_size=4, s_max=16, max_slots=2,
                 compute_model=default_compute_model(1e9, flops_per_s=1e12))
    rep = sch.run(reqs)
    assert rep.stalled_steps > 0
    assert reqs[0].state is ReqState.DONE        # older request survives
    assert reqs[1].state is ReqState.SHED        # younger one evicted
    assert len(reqs[0].tokens) == reqs[0].max_new_tokens


def test_scheduler_prices_network_through_engine():
    """With the PR 5 engine wired in, step time includes the decode gathers
    on the multilevel topology (the compute model here is zero)."""
    from repro.core import Communicator
    from repro.core.engine import Engine
    from repro.core.topology import paper_fig8_topology

    comm = Communicator(paper_fig8_topology(), backend="sim", policy="paper")
    reqs = make_requests([0.0] * 4, vocab=64, prompt_len=4, gen_len=4, seed=0)
    replicas = [tuple(range(g * 8, (g + 1) * 8)) for g in range(6)]
    sch = _sched(engine=Engine(comm, policy="priority", age_rate=1e6),
                 replicas=replicas, weight_bytes=1e6, gather_bytes=4096.0,
                 bcast_every=2)
    rep = sch.run(reqs)
    assert all(r.state is ReqState.DONE for r in reqs)
    assert rep.now > 0                           # network time advanced the clock
    s = summarize(reqs)
    assert s["ttft_p50_s"] > 0


# ---------------------------------------------------------------------- #
# paged attention numerics vs the dense cache path
# ---------------------------------------------------------------------- #

def _dense_decode_logits(cfg, params, toks, S, n_new):
    """Reference: dense prefill + decode_step, teacher-forced on toks."""
    logits_p, cache, pos = T.prefill(params, cfg, {"tokens": toks[:, :S]},
                                     s_max=S + n_new)
    out = [np.asarray(logits_p)]
    for i in range(n_new):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, S + i:S + i + 1],
                                  jnp.int32(pos + i))
        out.append(np.asarray(lg))
    return out


def _paged_decode_logits(cfg, params, toks, S, n_new, BS):
    """Same computation through the paged pools (pool scatter + block-table
    gather), growing the block table on demand."""
    assert S % BS == 0
    max_blocks = blocks_needed(S + n_new, BS) + 1
    n_blocks = 1 + max_blocks
    alloc = BlockAllocator(n_blocks, BS)
    pools = T.init_paged_pools(cfg, n_blocks, BS)
    blocks = alloc.alloc(S // BS)
    logits_p, cache, _ = T.prefill(params, cfg, {"tokens": toks[:, :S]}, S,
                                   full_local_cache=True)
    pools = T.scatter_prefill_cache(pools, cache, blocks, BS)
    out = [np.asarray(logits_p)]
    for i in range(n_new):
        pos = S + i
        if blocks_needed(pos + 1, BS) > len(blocks):
            blocks.extend(alloc.alloc(1))
        table = jnp.asarray(build_block_tables([blocks], max_blocks))
        lg, pools = T.decode_step_paged(params, cfg, pools, table,
                                        toks[:, S + i:S + i + 1],
                                        jnp.asarray([pos], jnp.int32))
        out.append(np.asarray(lg))
    return out


def test_paged_decode_bit_identical_full_attention():
    """Pure-attention stack: the block-table gather reconstructs the logical
    token order exactly, so paged logits must be *bit-identical* to dense —
    across several block-boundary crossings (block_size 4, 10 steps)."""
    cfg = get_config("qwen3_4b", smoke=True)
    params = T.init_model(KEY, cfg)
    S, n_new, BS = 8, 10, 4
    toks = jax.random.randint(KEY, (1, S + n_new), 0, cfg.vocab)
    dense = _dense_decode_logits(cfg, params, toks, S, n_new)
    paged = _paged_decode_logits(cfg, params, toks, S, n_new, BS)
    for i, (d, p) in enumerate(zip(dense, paged)):
        np.testing.assert_array_equal(d, p, err_msg=f"step {i}")


def test_paged_decode_windowed_matches_through_wrap():
    """Windowed layers: dense wraps the cache modulo the window, paged keeps
    it unwrapped and masks at read time.  Before the window fills the paths
    must agree bit-for-bit; past it (different storage, same math) the
    logits must still agree numerically with identical argmax."""
    cfg = get_config("gemma3_12b", smoke=True)   # window=8 after shrink
    params = T.init_model(KEY, cfg)
    S, n_new, BS = 8, 6, 4
    toks = jax.random.randint(KEY, (1, S + n_new), 0, cfg.vocab)
    dense = _dense_decode_logits(cfg, params, toks, S, n_new)
    paged = _paged_decode_logits(cfg, params, toks, S, n_new, BS)
    np.testing.assert_array_equal(dense[0], paged[0])  # prefill logits
    for i in range(1, n_new + 1):
        np.testing.assert_allclose(dense[i], paged[i], rtol=0, atol=1e-4,
                                   err_msg=f"step {i}")
        assert int(np.argmax(dense[i])) == int(np.argmax(paged[i]))


def test_prefill_last_pos_right_padded():
    """Right-padded variable-length prefill: last_pos logits must equal the
    unpadded prefill's (causality keeps pads out of real scores)."""
    cfg = get_config("qwen3_4b", smoke=True)
    params = T.init_model(KEY, cfg)
    L, S_p = 6, 12
    toks = jax.random.randint(KEY, (1, L), 0, cfg.vocab)
    padded = jnp.zeros((1, S_p), jnp.int32).at[:, :L].set(toks)
    ref, _, _ = T.prefill(params, cfg, {"tokens": toks}, s_max=L)
    got, _, _ = T.prefill(params, cfg, {"tokens": padded}, s_max=S_p,
                          last_pos=jnp.asarray([L - 1]))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("arch", ["rwkv6_1p6b", "recurrentgemma_2b",
                                  "seamless_m4t_medium"])
def test_paged_arch_check_rejects_stateful_stacks(arch):
    cfg = get_config(arch, smoke=True)
    with pytest.raises(ValueError, match="attention-only"):
        T.paged_arch_check(cfg)
    with pytest.raises(ValueError):
        T.init_paged_pools(cfg, 8, 4)


def test_scheduler_jax_executor_greedy_equivalence():
    """End to end: the continuous-batching scheduler over the real paged
    executor must emit, per request, exactly the greedy tokens of a
    standalone dense prefill+decode loop — with variable prompt lengths,
    staggered finishes, and slots being recycled mid-run."""
    cfg = get_config("qwen3_4b", smoke=True)
    params_key = jax.random.PRNGKey(0)
    BS, s_max = 4, 24
    prompts = [3, 8, 5]                  # padded lengths 4 / 8 / 8
    gens = [6, 3, 5]                     # staggered finishes recycle slots
    reqs = make_requests([0.0] * 3, vocab=cfg.vocab, prompt_len=4, gen_len=4,
                         seed=0)
    rng = np.random.default_rng(0)
    for r, L, g in zip(reqs, prompts, gens):
        r.prompt = rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
        r.max_new_tokens = g

    ex = JaxExecutor(cfg, None, n_blocks=1 + 2 * (s_max // BS), block_size=BS,
                     max_slots=2, max_blocks=s_max // BS, seed=0)
    sch = Scheduler(ex, n_blocks=1 + 2 * (s_max // BS), block_size=BS,
                    max_slots=2, s_max=s_max, prefill_token_budget=16)
    rep = sch.run(reqs)
    assert all(r.state is ReqState.DONE for r in reqs)
    assert rep.max_concurrent == 2       # slots recycled across 3 requests

    params = T.init_model(params_key, cfg)   # JaxExecutor used seed=0 too
    for r in reqs:
        toks = jnp.asarray(r.prompt)[None, :]
        logits, cache, pos = T.prefill(params, cfg, {"tokens": toks},
                                       s_max=r.prompt_len + r.max_new_tokens)
        ref = [int(np.argmax(np.asarray(logits[0, -1])))]
        for i in range(r.max_new_tokens - 1):
            lg, cache = T.decode_step(params, cfg, cache,
                                      jnp.asarray([[ref[-1]]], jnp.int32),
                                      jnp.int32(pos + i))
            ref.append(int(np.argmax(np.asarray(lg[0, 0]))))
        assert r.tokens == ref, f"request {r.rid} diverged"
