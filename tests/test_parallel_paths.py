"""Regression tests for the explicit-parallelism paths added in §Perf:
expert-parallel MoE (nested shard_map) and flash-decode (sequence-sharded
KV cache with LSE combine).  Both must be numerically equivalent to the
single-device reference paths."""
import jax
import pytest

# These paths dispatch on the ambient abstract mesh (jax.set_mesh), which
# older toolchains do not expose — the model code falls back to the
# reference path there, making the comparison vacuous.
pytestmark = pytest.mark.skipif(
    not hasattr(jax, "set_mesh"),
    reason="abstract-mesh dispatch (jax.set_mesh) needs newer jax")


def test_ep_moe_matches_reference(subproc):
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_config
from repro.models import layers as L
from repro.models.sharding import param_pspecs
cfg = get_config("olmoe_1b_7b", smoke=True)
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                       capacity_factor=4.0))
p = L.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
y_ref = L.moe_fwd(p, cfg, x)                       # no mesh -> ragged path
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(p, 2),
                   is_leaf=lambda v: isinstance(v, P))
pd = jax.device_put(p, psh)
xd = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
with jax.set_mesh(mesh):
    y_ep = jax.jit(lambda pp, xx: L.moe_fwd(pp, cfg, xx))(pd, xd)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), atol=2e-4)
# chunked scan path must agree with the one-shot path
y_chunked = L.moe_fwd(p, cfg, x, chunk=16)
np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_ref), atol=2e-4)
print("OK")
""")


def test_ep_moe_capacity_drops_bounded(subproc):
    """With the default capacity factor some tokens may drop under extreme
    imbalance; the output must stay finite and close to reference."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_config
from repro.models import layers as L
from repro.models.sharding import param_pspecs
cfg = get_config("llama4_scout_17b_a16e", smoke=True)  # top-1, shared expert
p = L.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
y_ref = L.moe_fwd(p, cfg, x)
mesh = jax.make_mesh((1, 2, 2), ("pod", "data", "model"))
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(p, 2),
                   is_leaf=lambda v: isinstance(v, P))
pd = jax.device_put(p, psh)
with jax.set_mesh(mesh):
    y_ep = jax.jit(lambda pp, xx: L.moe_fwd(pp, cfg, xx))(pd, x)
assert bool(jnp.isfinite(y_ep).all())
# tolerate capacity drops: relative Frobenius error small
rel = float(jnp.linalg.norm(y_ep - y_ref) / jnp.linalg.norm(y_ref))
assert rel < 0.3, rel  # tiny-T smoke is adversarial for top-1 capacity
print("OK rel", rel)
""")


def test_sp_flash_decode_matches_full_forward(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.launch import step as STEP
from repro.launch.mesh import make_test_mesh
from repro.models.sharding import param_shardings
for arch in ["qwen3_4b", "gemma3_12b"]:   # full + sliding-window caches
    cfg = get_config(arch, smoke=True)
    mesh = make_test_mesh(pods=1, data=2, model=2)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, param_shardings(params, mesh))
    B, S = 4, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0, cfg.vocab)
    full = T.model_fwd(params, cfg, {"tokens": toks})
    with jax.set_mesh(mesh):
        _, cache, pos = jax.jit(lambda p, t: T.prefill(
            p, cfg, {"tokens": t}, s_max=S + 4))(params, toks[:, :S])
        c_sh = STEP.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache))
        cache = jax.device_put(cache, c_sh)
        dec = jax.jit(lambda p, c, t, i: T.decode_step(p, cfg, c, t, i))
        l1, cache = dec(params, cache, toks[:, S:S+1], jnp.int32(pos))
        l2, cache = dec(params, cache, toks[:, S+1:S+2], jnp.int32(pos + 1))
    np.testing.assert_allclose(np.asarray(l1[:, 0]), np.asarray(full[:, S]),
                               atol=0.1, rtol=0.05)
    np.testing.assert_allclose(np.asarray(l2[:, 0]), np.asarray(full[:, S+1]),
                               atol=0.1, rtol=0.05)
print("OK")
""")
