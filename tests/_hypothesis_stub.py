"""Minimal, dependency-free stand-in for the `hypothesis` API surface the
test-suite uses, installed by conftest.py ONLY when the real package is
missing (see requirements-dev.txt for the real thing).

It is not a property-based testing engine: strategies draw from a
deterministically seeded PRNG and ``@given`` simply runs the test body for
``max_examples`` drawn tuples.  No shrinking, no database, no health checks —
just enough to keep tier-1 collection and the property tests' example sweeps
alive on machines without hypothesis installed.

Supported surface:
  given, settings(deadline=..., max_examples=...),
  strategies.{integers, floats, booleans, sampled_from, lists, tuples,
              composite, data}
"""
from __future__ import annotations

import functools
import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A strategy is just a callable drawing one example from an RNG."""

    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def example_from(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"<stub-strategy {self._label}>"


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value),
                    f"integers({min_value},{max_value})")


def floats(min_value, max_value, **_kw):
    return Strategy(lambda rng: rng.uniform(min_value, max_value),
                    f"floats({min_value},{max_value})")


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5, "booleans")


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: seq[rng.randrange(len(seq))], "sampled_from")


def lists(elements: Strategy, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        n = rng.randint(min_size, hi)
        return [elements.example_from(rng) for _ in range(n)]

    return Strategy(draw, "lists")


def tuples(*element_strategies):
    return Strategy(
        lambda rng: tuple(s.example_from(rng) for s in element_strategies),
        "tuples")


def composite(fn):
    """@st.composite: fn(draw, *args) -> value; returns a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_one(rng):
            def draw(strategy):
                return strategy.example_from(rng)

            return fn(draw, *args, **kwargs)

        return Strategy(draw_one, f"composite({fn.__name__})")

    return factory


class _DataObject:
    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy, label=None):
        return strategy.example_from(self._rng)


def data():
    return Strategy(lambda rng: _DataObject(rng), "data")


def settings(**kwargs):
    def deco(fn):
        merged = dict(getattr(fn, "_stub_settings", {}))
        merged.update(kwargs)
        fn._stub_settings = merged
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            cfg = getattr(wrapper, "_stub_settings", {})
            n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                args = tuple(s.example_from(rng) for s in strategies)
                try:
                    fn(*args)
                except Exception as e:
                    shown = tuple(a for a in args
                                  if not isinstance(a, _DataObject))
                    raise AssertionError(
                        f"stub-hypothesis falsifying example "
                        f"(iteration {i}): {fn.__name__}{shown!r}") from e

        # Let a later @settings(...) applied above @given reach the wrapper.
        wrapper._stub_settings = dict(getattr(fn, "_stub_settings", {}))
        # pytest must see the zero-arg signature, not the wrapped one —
        # otherwise it treats the strategy parameters as missing fixtures.
        del wrapper.__wrapped__
        return wrapper

    return deco


def assume(condition):
    if not condition:
        raise AssertionError("stub-hypothesis: assume() not satisfied "
                             "(unsupported in stub)")


def install() -> None:
    """Register stub `hypothesis` and `hypothesis.strategies` modules."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.__version__ = "0.0-stub"
    hyp.__is_stub__ = True

    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "composite", "data"):
        setattr(st, name, globals()[name])

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
