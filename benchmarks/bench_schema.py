"""Schema guard for the persisted BENCH_*.json artifacts.

The committed benchmark JSONs are consumed downstream (EXPERIMENTS.md, perf
tracking across PRs); a benchmark refactor that silently renames or drops
keys would corrupt that trajectory.  ``--smoke`` benchmark runs regenerate a
reduced document and compare its *shape* — recursive key structure, with all
scalars collapsed to their kind — against the committed file.

Run directly (``python benchmarks/bench_schema.py --all``) it executes every
registered benchmark's ``--smoke`` leg in one pass — the single CI step that
replaced one step per benchmark.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

# The committed artifacts this guard covers, keyed by repo-root filename.
# A new benchmark registers here (and a `--smoke` leg in the bench-smoke CI
# job) so its persisted schema is guarded from day one.
ARTIFACTS = {
    "BENCH_analysis.json": "benchmarks/bench_analysis.py",
    "BENCH_collectives.json": "benchmarks/bench_collectives.py",
    "BENCH_discovery.json": "benchmarks/bench_discovery.py",
    "BENCH_elastic.json": "benchmarks/bench_elastic.py",
    "BENCH_engine.json": "benchmarks/bench_engine.py",
    "BENCH_kernels.json": "benchmarks/bench_kernels.py",
    "BENCH_monitor.json": "benchmarks/bench_monitor.py",
    "BENCH_obs.json": "benchmarks/bench_obs.py",
    "BENCH_serve.json": "benchmarks/bench_serve.py",
}

# Perf-trajectory gates over the committed artifacts' ``headline`` blocks:
# metric -> ("low"|"high", slack).  "low" means lower is better (regression
# = grew past slack); "high" the reverse.  The slack is relative AND serves
# as an absolute floor, so a zero-valued baseline (post-refit regret 0.0)
# keeps exactly `slack` of absolute headroom instead of none.  Every
# *boolean* headline key is gated implicitly — True may never flip to
# False.  Wall-clock metrics get generous slack (they move with the CI
# machine); model-quality metrics (regret, rel-err) get tight slack because
# the benchmarks computing them are deterministic.
HISTORY_GATES = {
    "BENCH_analysis.json": {
        "verifier_worst_ms": ("low", 1.00),
        "sanitize_overhead_pct_64mib": ("low", 1.00),
        "lint_findings": ("low", 0.0),
    },
    "BENCH_engine.json": {
        "speedup": ("high", 0.05),
    },
    "BENCH_monitor.json": {
        "post_refit_regret": ("low", 0.02),
        "deconvolved_vs_lone_rel_err": ("low", 0.02),
        "detection_latency_steps": ("low", 0.50),
        "monitored_tail_over_pre": ("low", 0.10),
    },
    "BENCH_obs.json": {
        "overhead_pct_64mib_worst": ("low", 1.00),
        "post_refit_regret": ("low", 0.02),
    },
    "BENCH_serve.json": {
        "paged_max_concurrent": ("high", 0.0),
    },
}
HISTORY_FILE = "BENCH_history.json"


def schema_of(x):
    """Recursive shape of a JSON document: dict keys and list element shape
    are kept; scalars collapse to 'num' / 'str' / 'bool' / 'null'."""
    if isinstance(x, dict):
        return {k: schema_of(v) for k, v in sorted(x.items())}
    if isinstance(x, list):
        return [schema_of(x[0])] if x else []
    if isinstance(x, bool):
        return "bool"
    if isinstance(x, (int, float)):
        return "num"
    if x is None:
        return "null"
    return "str"


def check_against_committed(doc: dict, path: str) -> list[str]:
    """Compare ``doc``'s schema to the committed JSON at ``path``.

    Returns a list of human-readable drift messages (empty = no drift).  A
    missing committed file is reported too: the benchmark writes it, so its
    absence means the artifact was never persisted or got deleted.
    """
    if not os.path.exists(path):
        return [f"committed benchmark artifact missing: {path}"]
    with open(path) as f:
        committed = json.load(f)
    drifts: list[str] = []
    _diff(schema_of(committed), schema_of(doc), "$", drifts)
    return drifts


def _diff(a, b, where: str, out: list[str]) -> None:
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{where}.{k}: new key (not in committed file)")
            elif k not in b:
                out.append(f"{where}.{k}: dropped (present in committed file)")
            else:
                _diff(a[k], b[k], f"{where}.{k}", out)
    elif isinstance(a, list) and isinstance(b, list):
        if a and b:
            _diff(a[0], b[0], f"{where}[0]", out)
        # one side empty: benchmarks may legitimately emit empty lists in
        # reduced runs; shape cannot be compared, so stay silent
    elif a != b:
        out.append(f"{where}: {a!r} -> {b!r}")


def collect_headlines(root: str) -> dict:
    """The ``headline`` block of every committed artifact that has one.
    Artifacts without a headline (raw sweeps) have no single scalar worth
    tracking across PRs and are covered by the schema guard alone."""
    out = {}
    for artifact in sorted(ARTIFACTS):
        path = os.path.join(root, artifact)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            doc = json.load(f)
        if isinstance(doc.get("headline"), dict):
            out[artifact] = doc["headline"]
    return out


def compare_history(history: dict, current: dict) -> list[str]:
    """Regressions of ``current`` headlines against the ``history``
    snapshot, per :data:`HISTORY_GATES`.  Pure function of its inputs
    (unit-testable).  Metrics/artifacts absent from history are new —
    reported by ``--history`` as informational, never as regressions."""
    bad: list[str] = []
    for artifact, head in sorted(current.items()):
        prev = history.get(artifact)
        if prev is None:
            continue
        gates = HISTORY_GATES.get(artifact, {})
        for key, now in sorted(head.items()):
            was = prev.get(key)
            if isinstance(was, bool) and isinstance(now, bool):
                if was and not now:
                    bad.append(f"{artifact}:{key}: True -> False")
                continue
            gate = gates.get(key)
            if gate is None or not isinstance(was, (int, float)) \
                    or not isinstance(now, (int, float)):
                continue
            direction, slack = gate
            allowed = slack * abs(was) + slack
            if direction == "low" and now > was + allowed:
                bad.append(f"{artifact}:{key}: {was:g} -> {now:g} "
                           f"(allowed <= {was + allowed:g})")
            elif direction == "high" and now < was - allowed:
                bad.append(f"{artifact}:{key}: {was:g} -> {now:g} "
                           f"(allowed >= {was - allowed:g})")
    return bad


def run_history(root: str, update: bool) -> int:
    """``--history``: gate committed headlines against the committed
    ``BENCH_history.json`` snapshot; ``--update`` reseeds the snapshot from
    the current artifacts (commit it alongside a deliberate perf change)."""
    path = os.path.join(root, HISTORY_FILE)
    current = collect_headlines(root)
    if update:
        with open(path, "w") as f:
            json.dump({"generated_by": "benchmarks/bench_schema.py "
                                        "--history --update",
                       "headlines": current}, f, indent=1)
            f.write("\n")
        print(f"# {HISTORY_FILE}: snapshot of {len(current)} headline(s)")
        return 0
    if not os.path.exists(path):
        print(f"missing {HISTORY_FILE}; seed it with "
              "`bench_schema.py --history --update`", file=sys.stderr)
        return 1
    with open(path) as f:
        history = json.load(f)["headlines"]
    for artifact in sorted(set(current) - set(history)):
        print(f"# {artifact}: new artifact, not in history yet")
    regressions = compare_history(history, current)
    if regressions:
        print("benchmark headline regressions vs committed history:",
              file=sys.stderr)
        for r in regressions:
            print(" ", r, file=sys.stderr)
        print("(intentional? re-run with --history --update and commit "
              "the new BENCH_history.json)", file=sys.stderr)
        return 1
    n = sum(len(HISTORY_GATES.get(a, {})) for a in current)
    print(f"# history: {n} gated metric(s) across {len(current)} "
          "headline(s), no regressions")
    return 0


def main(argv=None) -> int:
    """``--all``: run every registered benchmark's ``--smoke`` leg (each one
    schema-checks its own committed artifact and asserts its acceptance
    criteria).  ``--history``: compare committed headline metrics against
    the ``BENCH_history.json`` snapshot (``--update`` reseeds it).  Flags
    specific to one benchmark (e.g. bench_obs's ``--trace-out``) belong in
    that benchmark's own invocation."""
    argv = list(sys.argv[1:] if argv is None else argv)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if "--history" in argv:
        return run_history(root, update="--update" in argv)
    if "--all" not in argv:
        print("usage: bench_schema.py --all | --history [--update]",
              file=sys.stderr)
        return 2
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    env.get("PYTHONPATH", "")) if p)
    failures = []
    for artifact, script in sorted(ARTIFACTS.items()):
        print(f"== {script} --smoke ({artifact})", flush=True)
        proc = subprocess.run(
            [sys.executable, os.path.join(root, script), "--smoke"],
            cwd=root, env=env)
        if proc.returncode != 0:
            failures.append(f"{script}: exit {proc.returncode}")
    if failures:
        print("bench smoke failures:", file=sys.stderr)
        for f in failures:
            print(" ", f, file=sys.stderr)
        return 1
    print(f"# all {len(ARTIFACTS)} benchmark smokes passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
