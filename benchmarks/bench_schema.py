"""Schema guard for the persisted BENCH_*.json artifacts.

The committed benchmark JSONs are consumed downstream (EXPERIMENTS.md, perf
tracking across PRs); a benchmark refactor that silently renames or drops
keys would corrupt that trajectory.  ``--smoke`` benchmark runs regenerate a
reduced document and compare its *shape* — recursive key structure, with all
scalars collapsed to their kind — against the committed file.

Run directly (``python benchmarks/bench_schema.py --all``) it executes every
registered benchmark's ``--smoke`` leg in one pass — the single CI step that
replaced one step per benchmark.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

# The committed artifacts this guard covers, keyed by repo-root filename.
# A new benchmark registers here (and a `--smoke` leg in the bench-smoke CI
# job) so its persisted schema is guarded from day one.
ARTIFACTS = {
    "BENCH_analysis.json": "benchmarks/bench_analysis.py",
    "BENCH_collectives.json": "benchmarks/bench_collectives.py",
    "BENCH_discovery.json": "benchmarks/bench_discovery.py",
    "BENCH_elastic.json": "benchmarks/bench_elastic.py",
    "BENCH_engine.json": "benchmarks/bench_engine.py",
    "BENCH_kernels.json": "benchmarks/bench_kernels.py",
    "BENCH_obs.json": "benchmarks/bench_obs.py",
    "BENCH_serve.json": "benchmarks/bench_serve.py",
}


def schema_of(x):
    """Recursive shape of a JSON document: dict keys and list element shape
    are kept; scalars collapse to 'num' / 'str' / 'bool' / 'null'."""
    if isinstance(x, dict):
        return {k: schema_of(v) for k, v in sorted(x.items())}
    if isinstance(x, list):
        return [schema_of(x[0])] if x else []
    if isinstance(x, bool):
        return "bool"
    if isinstance(x, (int, float)):
        return "num"
    if x is None:
        return "null"
    return "str"


def check_against_committed(doc: dict, path: str) -> list[str]:
    """Compare ``doc``'s schema to the committed JSON at ``path``.

    Returns a list of human-readable drift messages (empty = no drift).  A
    missing committed file is reported too: the benchmark writes it, so its
    absence means the artifact was never persisted or got deleted.
    """
    if not os.path.exists(path):
        return [f"committed benchmark artifact missing: {path}"]
    with open(path) as f:
        committed = json.load(f)
    drifts: list[str] = []
    _diff(schema_of(committed), schema_of(doc), "$", drifts)
    return drifts


def _diff(a, b, where: str, out: list[str]) -> None:
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{where}.{k}: new key (not in committed file)")
            elif k not in b:
                out.append(f"{where}.{k}: dropped (present in committed file)")
            else:
                _diff(a[k], b[k], f"{where}.{k}", out)
    elif isinstance(a, list) and isinstance(b, list):
        if a and b:
            _diff(a[0], b[0], f"{where}[0]", out)
        # one side empty: benchmarks may legitimately emit empty lists in
        # reduced runs; shape cannot be compared, so stay silent
    elif a != b:
        out.append(f"{where}: {a!r} -> {b!r}")


def main(argv=None) -> int:
    """``--all``: run every registered benchmark's ``--smoke`` leg (each one
    schema-checks its own committed artifact and asserts its acceptance
    criteria).  Flags specific to one benchmark (e.g. bench_obs's
    ``--trace-out``) belong in that benchmark's own invocation."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--all" not in argv:
        print("usage: bench_schema.py --all", file=sys.stderr)
        return 2
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    env.get("PYTHONPATH", "")) if p)
    failures = []
    for artifact, script in sorted(ARTIFACTS.items()):
        print(f"== {script} --smoke ({artifact})", flush=True)
        proc = subprocess.run(
            [sys.executable, os.path.join(root, script), "--smoke"],
            cwd=root, env=env)
        if proc.returncode != 0:
            failures.append(f"{script}: exit {proc.returncode}")
    if failures:
        print("bench smoke failures:", file=sys.stderr)
        for f in failures:
            print(" ", f, file=sys.stderr)
        return 1
    print(f"# all {len(ARTIFACTS)} benchmark smokes passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
