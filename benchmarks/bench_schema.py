"""Schema guard for the persisted BENCH_*.json artifacts.

The committed benchmark JSONs are consumed downstream (EXPERIMENTS.md, perf
tracking across PRs); a benchmark refactor that silently renames or drops
keys would corrupt that trajectory.  ``--smoke`` benchmark runs regenerate a
reduced document and compare its *shape* — recursive key structure, with all
scalars collapsed to their kind — against the committed file.
"""
from __future__ import annotations

import json
import os

# The committed artifacts this guard covers, keyed by repo-root filename.
# A new benchmark registers here (and a `--smoke` leg in the bench-smoke CI
# job) so its persisted schema is guarded from day one.
ARTIFACTS = {
    "BENCH_collectives.json": "benchmarks/bench_collectives.py",
    "BENCH_discovery.json": "benchmarks/bench_discovery.py",
    "BENCH_elastic.json": "benchmarks/bench_elastic.py",
    "BENCH_engine.json": "benchmarks/bench_engine.py",
    "BENCH_kernels.json": "benchmarks/bench_kernels.py",
    "BENCH_serve.json": "benchmarks/bench_serve.py",
}


def schema_of(x):
    """Recursive shape of a JSON document: dict keys and list element shape
    are kept; scalars collapse to 'num' / 'str' / 'bool' / 'null'."""
    if isinstance(x, dict):
        return {k: schema_of(v) for k, v in sorted(x.items())}
    if isinstance(x, list):
        return [schema_of(x[0])] if x else []
    if isinstance(x, bool):
        return "bool"
    if isinstance(x, (int, float)):
        return "num"
    if x is None:
        return "null"
    return "str"


def check_against_committed(doc: dict, path: str) -> list[str]:
    """Compare ``doc``'s schema to the committed JSON at ``path``.

    Returns a list of human-readable drift messages (empty = no drift).  A
    missing committed file is reported too: the benchmark writes it, so its
    absence means the artifact was never persisted or got deleted.
    """
    if not os.path.exists(path):
        return [f"committed benchmark artifact missing: {path}"]
    with open(path) as f:
        committed = json.load(f)
    drifts: list[str] = []
    _diff(schema_of(committed), schema_of(doc), "$", drifts)
    return drifts


def _diff(a, b, where: str, out: list[str]) -> None:
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{where}.{k}: new key (not in committed file)")
            elif k not in b:
                out.append(f"{where}.{k}: dropped (present in committed file)")
            else:
                _diff(a[k], b[k], f"{where}.{k}", out)
    elif isinstance(a, list) and isinstance(b, list):
        if a and b:
            _diff(a[0], b[0], f"{where}[0]", out)
        # one side empty: benchmarks may legitimately emit empty lists in
        # reduced runs; shape cannot be compared, so stay silent
    elif a != b:
        out.append(f"{where}: {a!r} -> {b!r}")
