"""Kernel roofline benchmark: the Pallas kernels (flash fwd/bwd, int8
quantiser, fused quantise+EF, wkv scan) against analytic FLOP/byte models
and the chip roofline, persisted to ``BENCH_kernels.json`` at the repo root.

Four sections:

``kernels``
    One row per kernel x shape: analytic FLOPs + HBM bytes (models below),
    measured wall, and :func:`repro.core.costmodel.kernel_roofline` output —
    which ceiling binds, model wall, achieved-vs-peak fractions.  Off-TPU
    the kernels run in interpret mode, so the achieved fractions are
    structural (the ``backend`` field says what was measured); on TPU the
    same rows are the real roofline numbers.
``compression_path``
    The tentpole traffic claim: modeled HBM bytes/element of the two-pass
    EF update (add, quantise, dequantise, subtract — each an HBM round
    trip) vs the FUSED ``quantize_ef_int8`` kernel (one pass), plus the
    measured walls of both paths.  Acceptance: modeled ratio >= 2x.
``acceptance``
    Pallas flash backward grads vs the jnp custom-VJP oracle
    (``models.layers._flash``) and bit-identity of the fused EF kernel vs
    the two-pass kernel path.
``refit``
    :func:`repro.core.costmodel.refit_hw` applied to the best achieved
    fractions — the derated HW constants downstream rooflines would use on
    this machine (meaningful on TPU; recorded for structure elsewhere).

Byte models count HBM traffic at the BlockSpec level: every staged block is
a fetch (``pl.when`` skips compute, not the copy), blocks whose index map
is constant across the innermost grid dim are fetched once.  FLOP models
count only on-band blocks (``roofline.attn_kv_eff`` — the same blocking the
kernels skip with ``pl.when``).

``--smoke`` runs reduced shapes and checks the committed artifact's schema
instead of overwriting it (see ``bench_schema.py``); CI runs this.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import compression
from repro.core.costmodel import TPU_V5E, kernel_roofline, refit_hw
from repro.kernels import ops
from repro.kernels import flash_attention as fa
from repro.kernels import wkv as wkv_mod
from repro.models import layers

F32 = 4  # bytes


def _time(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)          # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------- #
# Analytic FLOP / HBM-byte models
# ---------------------------------------------------------------------- #

def flash_flops(B, H, Sq, kv_eff, hd, *, bwd: bool) -> float:
    """fwd: qk^T + pv = 4 FLOPs per (q, kv, d) triple over on-band kv.
    bwd: both kernels recompute s (2x2), dq adds dp + ds@k (2x2), dkv adds
    p^T@do + ds^T@q (2x2) -> 14x."""
    per = 14.0 if bwd else 4.0
    return per * B * H * Sq * kv_eff * hd


def flash_fwd_bytes(B, Hkv, G, Sq, Sk, hd, block_q, in_bytes=F32) -> float:
    """Per fold (B*Hkv): q read once (index map constant over j), k/v
    re-staged per q block row, o write, lse write (f32)."""
    n_q = Sq // block_q
    per_fold = (G * Sq * hd * in_bytes          # q
                + n_q * Sk * hd * 2 * in_bytes  # k, v per q row
                + G * Sq * hd * in_bytes        # o
                + G * Sq * F32)                 # lse
    return B * Hkv * per_fold


def flash_bwd_bytes(B, Hkv, G, Sq, Sk, hd, block_q, block_k,
                    in_bytes=F32) -> float:
    """dq kernel (kv innermost: q/do/lse/delta staged once per row, k/v per
    (i,j)) + dkv kernel (q innermost: k/v once per column, q-side per
    (j,i)) + the delta precompute (read do+o, write delta)."""
    n_q, n_k = Sq // block_q, Sk // block_k
    dq = (G * Sq * (2 * hd * in_bytes + 2 * F32)   # q, do, lse, delta
          + n_q * Sk * hd * 2 * in_bytes           # k, v
          + G * Sq * hd * F32)                     # dq write (f32)
    dkv = (Sk * hd * 2 * in_bytes                  # k, v
           + n_k * G * Sq * (2 * hd * in_bytes + 2 * F32)
           + Sk * hd * 2 * F32)                    # dk, dv writes
    delta = B * Hkv * G * Sq * (2 * hd * in_bytes + F32)
    return B * Hkv * (dq + dkv) + delta


def quant_bytes(n: int, *, fused_ef: bool | None) -> float:
    """HBM bytes of the quantiser kernels on an n-element f32 buffer.
    fused_ef=None: plain quantise.  True: the fused x+ef+residual pass.
    False: the TWO-PASS EF update (add, quantise, dequantise, subtract),
    each stage an HBM round trip — the fused kernel's baseline."""
    scales = F32 * n / compression.BLOCK
    if fused_ef is None:
        return n * F32 + n + scales                      # read x; write q, s
    if fused_ef:
        return 2 * n * F32 + n + scales + n * F32        # x, ef; q, s, r
    add = 3 * n * F32                                    # g + ef -> x
    quant = n * F32 + n + scales
    deq = n + scales + n * F32
    sub = 3 * n * F32                                    # x - deq -> r
    return add + quant + deq + sub


def wkv_flops(B, H, S, hd, chunk) -> float:
    """Per chunk: two (C,hd)@(hd,hd)-class dots (inter-chunk out + state
    update) and two (C,C,hd) dots (intra-chunk scores + scores@v)."""
    return B * H * (4.0 * S * hd * hd + 4.0 * S * chunk * hd)


def wkv_bytes(B, H, S, hd) -> float:
    return B * H * (4 * S * hd + S * hd + hd) * F32      # r,k,v,w; o; u


# ---------------------------------------------------------------------- #
# Measured rows
# ---------------------------------------------------------------------- #

def _row(name, shape_desc, flops, hbm_bytes, wall_s, backend) -> dict:
    rl = kernel_roofline(flops, hbm_bytes, TPU_V5E, wall_s=wall_s)
    return {"kernel": name, "shape": shape_desc, "backend": backend,
            "flops": flops, "hbm_bytes": hbm_bytes, **rl}


def kernel_rows(smoke: bool) -> list[dict]:
    backend = jax.default_backend()
    rows = []
    S = 256 if smoke else 512
    B, Hkv, G, hd, blk = 1, 2, 2, 64, 128
    H = Hkv * G
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hkv, hd), jnp.float32)
    kv_eff = _kv_eff(S, blk)
    desc = f"B{B} H{H} Hkv{Hkv} S{S} hd{hd} blk{blk} causal"

    def fwd(q, k, v):
        return ops.flash_attention(q, k, v, block_q=blk, block_k=blk)

    rows.append(_row(
        "flash_fwd", desc,
        flash_flops(B, H, S, kv_eff, hd, bwd=False),
        flash_fwd_bytes(B, Hkv, G, S, S, hd, blk),
        _time(fwd, q, k, v), backend))

    grad = jax.jit(jax.grad(lambda q, k, v: jnp.sum(fwd(q, k, v))))
    rows.append(_row(
        "flash_bwd", desc,
        flash_flops(B, H, S, kv_eff, hd, bwd=True),
        flash_bwd_bytes(B, Hkv, G, S, S, hd, blk, blk),
        _time(grad, q, k, v), backend))

    n = compression.QTILE * (1 if smoke else 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    ef = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32) * 1e-3
    rows.append(_row(
        "quantize_int8", f"n={n}",
        0.0 + 3 * n,                   # amax, scale, round ~ O(n) VPU work
        quant_bytes(n, fused_ef=None),
        _time(lambda a: ops.quantize_int8(a)[0], x), backend))
    rows.append(_row(
        "quantize_ef_int8", f"n={n}",
        0.0 + 6 * n,
        quant_bytes(n, fused_ef=True),
        _time(lambda a, e: ops.quantize_ef_int8(a, e)[2], x, ef), backend))

    Sw, hdw, Hw = (64, 32, 2) if smoke else (256, 32, 2)
    r = jax.random.normal(jax.random.PRNGKey(3), (B, Sw, Hw, hdw))
    kw = jax.random.normal(jax.random.PRNGKey(4), (B, Sw, Hw, hdw))
    vw = jax.random.normal(jax.random.PRNGKey(5), (B, Sw, Hw, hdw))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(6),
                                         (B, Sw, Hw, hdw)))
    u = jax.random.normal(jax.random.PRNGKey(7), (Hw, hdw))
    rows.append(_row(
        "wkv_scan", f"B{B} H{Hw} S{Sw} hd{hdw} chunk{wkv_mod.CHUNK}",
        wkv_flops(B, Hw, Sw, hdw, wkv_mod.CHUNK),
        wkv_bytes(B, Hw, Sw, hdw),
        _time(jax.jit(wkv_mod.wkv_chunked), r, kw, vw, w, u), backend))
    return rows


def _kv_eff(S: int, blk: int) -> float:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from roofline import attn_kv_eff

    return attn_kv_eff(S, True, None, block_skip=True, chunk=blk)


# ---------------------------------------------------------------------- #
# Compression-path traffic + acceptance checks
# ---------------------------------------------------------------------- #

def compression_path(smoke: bool) -> dict:
    n = compression.QTILE * (1 if smoke else 8)
    fused_b = quant_bytes(n, fused_ef=True)
    twopass_b = quant_bytes(n, fused_ef=False)
    x = jax.random.normal(jax.random.PRNGKey(8), (n,), jnp.float32)
    ef = jax.random.normal(jax.random.PRNGKey(9), (n,), jnp.float32) * 1e-3

    def two_pass(x, ef):
        g = x + ef
        q, s, pad = ops.quantize_int8(g)
        return g - ops.dequantize_int8(q, s, pad)

    ratio = twopass_b / fused_b
    return {
        "n": n,
        "fused_bytes_per_elem": fused_b / n,
        "twopass_bytes_per_elem": twopass_b / n,
        "modeled_traffic_ratio": ratio,
        "fused_wall_s": _time(lambda a, e: ops.quantize_ef_int8(a, e)[2],
                              x, ef),
        "twopass_wall_s": _time(two_pass, x, ef),
        "acceptance_min_ratio": 2.0,
        "passed": ratio >= 2.0,
    }


def acceptance(smoke: bool) -> dict:
    S = 256
    B, Hkv, G, hd, blk = 1, 2, 2, 32, 64
    H = Hkv * G
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hkv, hd), jnp.float32)

    def loss_pallas(q, k, v):
        o = fa.flash_attention(q, k, v, True, None, blk, blk, 0, None)
        return jnp.sum(jnp.sin(o))

    def loss_jnp(q, k, v):
        o = layers._flash(q, k, v, True, None, blk, blk, 0)
        return jnp.sum(jnp.sin(o))

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(loss_jnp, argnums=(0, 1, 2))(q, k, v)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(gp, gj))

    n = compression.QTILE
    x = jax.random.normal(jax.random.PRNGKey(11), (n,), jnp.float32) * 10
    ef = jax.random.normal(jax.random.PRNGKey(12), (n,), jnp.float32) * 1e-3
    qf, sf, rf, _ = ops.quantize_ef_int8(x, ef)
    q2, s2, pad = ops.quantize_int8(x + ef)
    r2 = (x + ef) - ops.dequantize_int8(q2, s2, pad)
    bitident = (bool(jnp.all(qf == q2)) and bool(jnp.all(sf == s2))
                and bool(jnp.all(rf == r2)))
    tol = 1e-4
    return {
        "flash_bwd_max_err": err,
        "flash_bwd_tol": tol,
        "flash_bwd_allclose": err <= tol,
        "fused_ef_bitidentical": bitident,
        "passed": err <= tol and bitident,
    }


def build_doc(smoke: bool = False) -> dict:
    rows = kernel_rows(smoke)
    comp = compression_path(smoke)
    acc = acceptance(smoke)
    best_f = max(r["achieved_flops_frac"] for r in rows)
    best_b = max(r["achieved_bw_frac"] for r in rows)
    fitted = refit_hw(TPU_V5E, flops_frac=best_f, bw_frac=best_b,
                      name=f"{TPU_V5E.name}_fit_{jax.default_backend()}")
    summary = []
    for r in rows:
        summary.append(
            f"{r['kernel']}: {r['bound']}-bound (intensity "
            f"{r['intensity']:.1f} vs ridge {r['ridge']:.0f} FLOP/B), "
            f"model {r['model_s'] * 1e6:.0f} us, wall "
            f"{r['wall_s'] * 1e3:.2f} ms on {r['backend']}")
    summary.append(
        f"fused EF: {comp['fused_bytes_per_elem']:.2f} B/elem vs two-pass "
        f"{comp['twopass_bytes_per_elem']:.2f} — modeled HBM traffic "
        f"{comp['modeled_traffic_ratio']:.2f}x (acceptance >= 2x: "
        f"{'PASS' if comp['passed'] else 'FAIL'}); measured "
        f"{comp['twopass_wall_s'] / comp['fused_wall_s']:.2f}x wall")
    summary.append(
        f"flash bwd vs jnp VJP: max grad err {acc['flash_bwd_max_err']:.2e} "
        f"(tol {acc['flash_bwd_tol']:g}: "
        f"{'PASS' if acc['flash_bwd_allclose'] else 'FAIL'}); fused EF "
        f"bit-identical to two-pass: "
        f"{'PASS' if acc['fused_ef_bitidentical'] else 'FAIL'}")
    return {
        "generated_by": "benchmarks/bench_kernels.py",
        "backend": jax.default_backend(),
        "hw": TPU_V5E.name,
        "kernels": rows,
        "compression_path": comp,
        "acceptance": acc,
        "refit": {
            "best_achieved_flops_frac": best_f,
            "best_achieved_bw_frac": best_b,
            "fitted_name": fitted.name,
            "fitted_peak_flops": fitted.peak_flops,
            "fitted_hbm_bw": fitted.hbm_bw,
        },
        "summary": summary,
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_kernels.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_kernels.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        if not doc["acceptance"]["passed"]:
            print("kernel acceptance failed (flash bwd grads or fused EF "
                  "bit-identity)", file=sys.stderr)
            return 1
        if not doc["compression_path"]["passed"]:
            print("fused EF modeled traffic ratio below the 2x acceptance "
                  "bar", file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_kernels.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_kernels.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
