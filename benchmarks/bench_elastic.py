"""Elastic-collectives benchmark: recovery latency and post-repair regret.

For each (topology, failure scenario, op) the benchmark runs the full
elastic loop on the simulation plane and decomposes recovery latency into
its terms, persisted to ``BENCH_elastic.json`` at the repo root:

  t_healthy_s        the collective before the failure
  stalled_ranks      ranks the fault-injected simulator reports starving
                     (the detector's signal)
  repair_wall_s      host time for ``Communicator.repair`` — plan-cache
                     surgery only, no tree rebuilds
  t_post_repair_s    the collective on the spliced plans
  t_fresh_s          the same collective on plans rebuilt from scratch
                     over the survivors
  regret             t_post_repair / t_fresh - 1

A second section quantifies the targeted drift re-probe: representative
pair count vs the all-pairs probe count of full discovery, and the wall
time of ``Communicator.refresh``.

``--smoke`` runs the fig8 subset and checks the committed artifact's
schema instead of overwriting it (see ``bench_schema.py``); CI runs this.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

from repro.core import Communicator
from repro.core import discovery as D
from repro.core.simulator import simulate_rounds
from repro.core.topology import (Level, Topology, paper_fig8_topology,
                                 tpu_v5e_multipod)

OPS = ("bcast", "allreduce")

SCENARIOS = {
    "fig8": (paper_fig8_topology, 64e3, {
        "coordinator": [16],
        "half-machine": list(range(16, 24)),
        "scattered": [5, 17, 33, 40],
        "whole-site-machine": list(range(16, 32)),
    }),
    "tpu-2pod-512": (tpu_v5e_multipod, 1e6, {
        "chip": [100],
        "board": list(range(16, 32)),
        "pod-coordinator": [256],
        "whole-pod": list(range(256, 512)),
    }),
}


def _run(comm, op, nbytes):
    return (comm.allreduce(nbytes) if op == "allreduce"
            else getattr(comm, op)(nbytes, root=0)).time


def recovery(topologies=("fig8", "tpu-2pod-512")) -> list[dict]:
    rows = []
    for tname in topologies:
        make, nbytes, fails = SCENARIOS[tname]
        topo = make()
        for sname, dead in fails.items():
            for op in OPS:
                comm = Communicator(topo, policy="paper", backend="sim")
                t_healthy = _run(comm, op, nbytes)
                plan = comm.plan(op, root=0, nbytes=nbytes)
                stalled = sum(
                    1 for t in simulate_rounds(
                        plan.lower(nbytes), topo,
                        fail_at={r: 0.0 for r in dead}).values()
                    if t == math.inf)
                tb = comm.cache_info().tree_builds
                w0 = time.perf_counter()
                rep = comm.repair(failed=dead)
                repair_wall = time.perf_counter() - w0
                assert comm.cache_info().tree_builds == tb
                t_post = _run(comm, op, nbytes)
                survivors = [m for m in range(topo.nprocs)
                             if m not in set(dead)]
                fresh = Communicator(topo, policy="paper", backend="sim",
                                     members=survivors)
                t_fresh = _run(fresh, op, nbytes)
                rows.append({
                    "topology": tname, "scenario": sname, "op": op,
                    "size_bytes": nbytes, "n_failed": len(dead),
                    "t_healthy_s": t_healthy,
                    "stalled_ranks": stalled,
                    "repair_wall_s": repair_wall,
                    "plans_repaired": rep.repaired,
                    "plans_evicted": rep.evicted,
                    "t_post_repair_s": t_post,
                    "t_fresh_s": t_fresh,
                    "regret": t_post / t_fresh - 1.0,
                })
    return rows


def drift(topologies=("fig8", "tpu-2pod-512")) -> list[dict]:
    rows = []
    for tname in topologies:
        make, nbytes, _ = SCENARIOS[tname]
        topo = make()
        pairs = D.representative_pairs(topo)
        drifted = Topology(topo.coords, [
            Level(topo.levels[0].name, topo.levels[0].latency * 3,
                  topo.levels[0].bandwidth / 3, topo.levels[0].overhead)
        ] + list(topo.levels[1:]))
        comm = Communicator(topo, policy="auto", backend="sim")
        _run(comm, "bcast", nbytes)
        probes = D.targeted_probes(drifted, pairs)
        w0 = time.perf_counter()
        rep = comm.refresh(probes)
        refresh_wall = time.perf_counter() - w0
        rows.append({
            "topology": tname, "nprocs": topo.nprocs,
            "targeted_pairs": len(pairs),
            "all_pairs": topo.nprocs * (topo.nprocs - 1),
            "probe_savings": 1.0 - len(pairs) / (topo.nprocs
                                                 * (topo.nprocs - 1)),
            "refreshed": rep.refreshed,
            "worst_drift": rep.worst,
            "refresh_wall_s": refresh_wall,
        })
    return rows


def summarize(rec_rows, drift_rows) -> list[str]:
    out = []
    for tname in sorted({r["topology"] for r in rec_rows}):
        worst = max(r["regret"] for r in rec_rows if r["topology"] == tname)
        wall = max(r["repair_wall_s"] for r in rec_rows
                   if r["topology"] == tname)
        out.append(f"{tname}: worst post-repair regret {worst * 100:.2f}%, "
                   f"repair wall time <= {wall * 1e3:.2f} ms")
    for r in drift_rows:
        out.append(f"{r['topology']}: drift re-probe {r['targeted_pairs']} "
                   f"pairs vs {r['all_pairs']} all-pairs "
                   f"({r['probe_savings'] * 100:.1f}% fewer)")
    return out


def build_doc(smoke: bool = False) -> dict:
    names = ("fig8",) if smoke else ("fig8", "tpu-2pod-512")
    rec = recovery(names)
    dri = drift(names)
    return {
        "generated_by": "benchmarks/bench_elastic.py",
        "policy": "paper",
        "recovery": rec,
        "drift": dri,
        "summary": summarize(rec, dri),
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_elastic.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_elastic.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_elastic.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_elastic.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
