"""Observability benchmark: tracing overhead, the measured-cost feedback
loop, and a sample end-to-end Chrome trace.  Persists ``BENCH_obs.json``.

Three sections:

``overhead_sweep``
    fig8 allreduce under the "auto" policy, 64 KiB–64 MiB, two modes per
    size: ``execute`` (the lowered program through ``simulate_rounds``,
    traced vs untraced — the tracer's raw hot-path cost) and
    ``plan+execute`` (cold-cache ``Communicator.allreduce`` — the pipeline
    a traced application step actually runs).  min-of-reps walls; the
    headline asserts both 64 MiB rows stay under the 5% budget.  The
    budget holds because tracing a live run costs ONE queued replay
    closure per program (``repro.obs.Tracer`` defers all event recording
    to trace-read time).
``feedback``
    The mis-modeled-link demo: the planner's model overstates WAN
    bandwidth 8x, so it picks a WAN-heavy segmented plan that is 17% worse
    ON THE TRUE NETWORK than the plan it would pick under honest costs.
    One traced 16 MiB allreduce executed on the truth topology feeds
    :class:`repro.obs.FeedbackLoop`; the refit recovers the true WAN
    bandwidth from the link intervals, and the re-planned regret drops to
    ~0 — both asserted in the headline.
``--trace-out PATH``
    Writes a sample trace (engine bucketed-overlap step + a small
    continuous-batching serve run on one tracer) — the CI artifact.

``--smoke`` runs a reduced sweep and checks the committed artifact's
schema instead of overwriting it (see ``bench_schema.py``); CI runs this.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import sys
import time

from repro.core import Communicator
from repro.core.engine import Engine
from repro.core.simulator import simulate_rounds
from repro.core.topology import paper_fig8_topology
from repro.obs import FeedbackLoop, Tracer

KIB, MIB = 1024.0, float(1 << 20)
FULL_SIZES = (64 * KIB, MIB, 8 * MIB, 64 * MIB)
SMOKE_SIZES = (MIB, 64 * MIB)
BUDGET_PCT = 5.0
FEEDBACK_NBYTES = 16 * MIB
WAN_OVERSTATE = 8.0


def _paired_overhead(fn_a, fn_b, reps: int) -> tuple[float, float, float]:
    """A/B overhead estimate robust to noisy shared machines: each rep
    times the pair back-to-back on PROCESS CPU time (background load
    excluded) and contributes one b/a ratio; the reported overhead is the
    MEDIAN of the per-pair ratios, so a burst of interference that lands
    on a single rep cannot swing the estimate.  Returns (median_a_s,
    median_b_s, median_ratio)."""
    ta, tb, ratios = [], [], []
    for _ in range(reps):
        t0 = time.process_time()
        fn_a()
        a = time.process_time() - t0
        t0 = time.process_time()
        fn_b()
        b = time.process_time() - t0
        ta.append(a)
        tb.append(b)
        ratios.append(b / a)
    return (statistics.median(ta), statistics.median(tb),
            statistics.median(ratios))


def overhead_sweep(sizes, reps: int) -> list[dict]:
    rows = []
    topo = paper_fig8_topology()
    comm = Communicator(topo, policy="auto", backend="sim")
    topo.comm_level_table()  # warm the tracer's level lookup
    for nb in sizes:
        low = comm.plan("allreduce", nbytes=nb).lower(nb)
        un, tr, ratio = _paired_overhead(
            lambda: simulate_rounds(low, topo),
            lambda: simulate_rounds(low, topo, tracer=Tracer(), label="x"),
            reps)
        rows.append({
            "mode": "execute", "size_mib": nb / MIB,
            "n_sends": len(low.sends),
            "untraced_ms": un * 1e3, "traced_ms": tr * 1e3,
            "overhead_pct": (ratio - 1.0) * 100.0,
        })

        plain = Communicator(paper_fig8_topology(), policy="auto",
                             backend="sim")
        traced = Communicator(paper_fig8_topology(), policy="auto",
                              backend="sim", tracer=Tracer())

        def cold_plain():
            plain.clear_cache()
            plain.allreduce(nb)

        def cold_traced():
            traced.clear_cache()
            traced.tracer = Tracer()
            traced.allreduce(nb)

        un, tr, ratio = _paired_overhead(cold_plain, cold_traced, reps)
        rows.append({
            "mode": "plan+execute", "size_mib": nb / MIB,
            "n_sends": len(low.sends),
            "untraced_ms": un * 1e3, "traced_ms": tr * 1e3,
            "overhead_pct": (ratio - 1.0) * 100.0,
        })
    return rows


def _plan_regret(comm: Communicator, truth, op: str, nbytes: float) -> float:
    """Time of the communicator's selected plan ON THE TRUTH topology,
    relative to the plan a truth-informed oracle selects (also priced on
    the truth).  0 = the model's selection is optimal despite its errors."""
    low = comm.plan(op, nbytes=nbytes).lower(nbytes)
    t_sel = max(simulate_rounds(low, truth).values())
    oracle = Communicator(truth, policy=comm.policy, backend="sim")
    best = oracle.plan(op, nbytes=nbytes).lower(nbytes)
    t_best = max(simulate_rounds(best, truth).values())
    return t_sel / t_best - 1.0


def feedback_section() -> dict:
    truth = paper_fig8_topology()
    model = paper_fig8_topology()
    model.levels = tuple(
        dataclasses.replace(l, bandwidth=l.bandwidth * WAN_OVERSTATE)
        if l.name == "wan" else l for l in model.levels)
    comm = Communicator(model, policy="auto", backend="sim")
    nb = FEEDBACK_NBYTES

    pre_regret = _plan_regret(comm, truth, "allreduce", nb)
    fb = FeedbackLoop(comm, threshold=0.15)
    pred_pre, meas_pre = fb.run("allreduce", nb, truth=truth)
    resid_pre = fb.residual_table()
    report = fb.maybe_refit()
    post_regret = _plan_regret(comm, truth, "allreduce", nb)
    pred_post, meas_post = fb.run("allreduce", nb, truth=truth)
    resid_post = fb.residual_table()

    wan = next(i for i, l in enumerate(truth.levels) if l.name == "wan")
    return {
        "op": "allreduce", "size_mib": nb / MIB,
        "wan_overstated_by": WAN_OVERSTATE,
        "refit": report.refit,
        "worst_drift": report.worst,
        "pre": {"regret": pre_regret, "predicted_s": pred_pre,
                "measured_s": meas_pre, "residuals": resid_pre},
        "post": {"regret": post_regret, "predicted_s": pred_post,
                 "measured_s": meas_post, "residuals": resid_post},
        "wan_bandwidth_truth": truth.levels[wan].bandwidth,
        "wan_bandwidth_refit": comm.topo.levels[wan].bandwidth,
    }


def write_sample_trace(path: str) -> dict:
    """One tracer through planner, engine, simulators, and scheduler —
    the end-to-end sample trace CI uploads."""
    from repro.serving import SLO, Scheduler, SimExecutor, make_requests

    tracer = Tracer()
    comm = Communicator(paper_fig8_topology(), policy="auto", backend="sim",
                        tracer=tracer)
    # a bucketed, overlapped gradient-sync step: 8 allreduce buckets
    # racing a fat weight broadcast under the priority policy
    eng = Engine(comm, policy="priority", age_rate=MIB)
    for _ in range(8):
        eng.issue("allreduce", 2 * MIB)
    eng.issue("bcast", 4 * MIB, root=0, priority=1.0)
    eng.wait_all()
    # a small continuous-batching serve run (request lifecycle spans)
    sch = Scheduler(SimExecutor(vocab=64, block_size=4), n_blocks=17,
                    block_size=4, max_slots=4, s_max=32,
                    prefill_token_budget=64, policy="priority",
                    compute_model=lambda pre, dec: 1e-3 * (1 + pre + dec),
                    tracer=tracer)
    sch.run(make_requests([0.0, 0.004, 0.008, 0.012], vocab=64,
                          prompt_len=6, gen_len=4, slo=SLO(), seed=0))
    tracer.save(path)
    doc = tracer.to_chrome()
    pids = sorted({e["pid"] for e in doc["traceEvents"]})
    return {"path": path, "n_events": len(doc["traceEvents"]), "pids": pids}


def build_doc(smoke: bool = False) -> dict:
    sizes = SMOKE_SIZES if smoke else FULL_SIZES
    reps = 11 if smoke else 15
    sweep = overhead_sweep(sizes, reps)
    fb = feedback_section()

    big = [r for r in sweep if r["size_mib"] == 64.0]
    worst_big = max(r["overhead_pct"] for r in big)
    overhead_ok = worst_big < BUDGET_PCT
    feedback_ok = (fb["refit"]
                   and fb["post"]["regret"] < fb["pre"]["regret"]
                   and fb["post"]["regret"] < 0.01)
    headline = {
        "overhead_pct_64mib_worst": worst_big,
        "budget_pct": BUDGET_PCT,
        "overhead_passed": overhead_ok,
        "pre_refit_regret": fb["pre"]["regret"],
        "post_refit_regret": fb["post"]["regret"],
        "feedback_passed": feedback_ok,
        "passed": overhead_ok and feedback_ok,
    }
    summary = [
        "tracing overhead (fig8 allreduce, median pair ratio of "
        f"{reps} reps, CPU time): worst 64 MiB row {worst_big:+.2f}% "
        f"(budget {BUDGET_PCT:g}%: "
        f"{'PASS' if overhead_ok else 'FAIL'})",
    ]
    for r in sweep:
        summary.append(
            f"  {r['size_mib']:g} MiB {r['mode']}: "
            f"{r['untraced_ms']:.3f} -> {r['traced_ms']:.3f} ms "
            f"({r['overhead_pct']:+.2f}%)")
    wan_pre = next(x["measured_over_model"] for x in fb["pre"]["residuals"]
                   if x["name"] == "wan")
    wan_post = next(x["measured_over_model"] for x in fb["post"]["residuals"]
                    if x["name"] == "wan")
    summary.append(
        f"feedback: wan overstated {WAN_OVERSTATE:g}x -> residual "
        f"{wan_pre:.3f}, plan regret {fb['pre']['regret'] * 100:.1f}%; "
        f"after refit residual {wan_post:.3f}, regret "
        f"{fb['post']['regret'] * 100:.1f}% "
        f"({'PASS' if feedback_ok else 'FAIL'})")
    return {
        "generated_by": "benchmarks/bench_obs.py",
        "overhead_sweep": sweep,
        "feedback": fb,
        "headline": headline,
        "summary": summary,
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    trace_out = None
    if "--trace-out" in argv:
        trace_out = argv[argv.index("--trace-out") + 1]
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_obs.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if trace_out:
        info = write_sample_trace(trace_out)
        print(f"# sample trace: {info['n_events']} events, "
              f"pids {info['pids']} -> {info['path']}")
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_obs.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        if not doc["headline"]["passed"]:
            print("observability acceptance failed:", doc["headline"],
                  file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_obs.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_obs.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
