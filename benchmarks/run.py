"""Benchmark aggregator — one function per paper table/figure.

  microbench  wall-clock us/call for the core tree/schedule machinery
  claims      paper §4 closed-form cost-model table
  fig8        paper Fig. 8: bcast sweep, 5 variants (simulator)
  collectives 5 collectives x 3 topologies x sizes (simulator)
  roofline    per (arch x shape x mesh) roofline terms from the dry-run

Prints ``name,us_per_call,derived`` CSV per the harness contract, then each
section's own CSV.
"""
from __future__ import annotations

import io
import time

from repro.core.costmodel import (binomial_bcast_cost, multilevel_bcast_cost,
                                  two_level_bcast_cost)
from repro.core.topology import WAN, SMP, paper_fig8_topology
from repro.core.trees import build_multilevel_tree, binomial_tree


def _timeit(fn, n=20) -> float:
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_microbench() -> list[str]:
    topo = paper_fig8_topology()
    rows = []
    rows.append(f"tree_multilevel_build,"
                f"{_timeit(lambda: build_multilevel_tree(topo, 0)):.1f},48procs")
    rows.append(f"tree_binomial_build,"
                f"{_timeit(lambda: binomial_tree(0, range(48))):.1f},48procs")
    from repro.core import schedule as S
    from repro.core.simulator import simulate
    t = build_multilevel_tree(topo, 0)
    rows.append(f"simulate_bcast,"
                f"{_timeit(lambda: simulate(S.bcast(t, 1e6), topo)):.1f},"
                f"48procs_1MB")
    return rows


def bench_claims() -> list[str]:
    """Paper §4: analytic binomial vs 2-level vs multilevel, C in 2..32."""
    rows = ["P,C,N_bytes,binomial_s,two_level_s,multilevel_s,speedup"]
    args = (WAN.latency, WAN.bandwidth, SMP.latency, SMP.bandwidth)
    for C in (2, 4, 8, 16, 32):
        P, N = 256, 64e3
        b = binomial_bcast_cost(P, C, N, *args)
        t2 = two_level_bcast_cost(P, C, N, *args)
        m = multilevel_bcast_cost(P, C, N, *args)
        rows.append(f"{P},{C},{N:.0f},{b:.4f},{t2:.4f},{m:.4f},{b/m:.2f}")
    return rows


def main() -> None:
    print("== microbench (name,us_per_call,derived) ==")
    for r in bench_microbench():
        print(r)

    print("\n== paper §4 closed-form claims ==")
    for r in bench_claims():
        print(r)

    print("\n== paper Fig. 8 reproduction (simulator) ==")
    from benchmarks import bench_bcast_fig8
    buf = io.StringIO()
    res = bench_bcast_fig8.run(out=buf)
    print(buf.getvalue(), end="")
    for line in bench_bcast_fig8.check(res):
        print("#", line)

    print("\n== collectives x topologies ==")
    from benchmarks import bench_collectives
    buf = io.StringIO()
    rows = bench_collectives.run(out=buf)
    print(buf.getvalue(), end="")
    for line in bench_collectives.summarize(rows):
        print("#", line)

    print("\n== roofline (from dry-run artifacts) ==")
    from benchmarks import roofline
    try:
        roofline.main()
    except FileNotFoundError:
        print("# run `python -m repro.launch.dryrun --all` first")


if __name__ == "__main__":
    main()
