"""Reproduction of the paper's Fig. 7/8 experiment on the postal-model
simulator: the broadcast timing application sweeping message sizes with
every rank taking a turn as root, comparing

  mpich-binomial   (topology-unaware, the MPICH default of the era)
  magpie-machine   (2-level, machine-boundary clustering)
  magpie-site      (2-level, site-boundary clustering)
  multilevel       (the paper, flat-at-WAN / binomial below)
  adaptive         (beyond-paper: per-level Bar-Noy/Kipnis shape selection)

Each variant is one :class:`repro.core.Communicator`: the baselines build
their trees against a collapsed/oblivious *view* while the simulator still
charges true per-edge costs (``view=`` parameter).

Topology: 16 procs on each of SDSC-SP, ANL-SP, ANL-O2K (sites SDSC/ANL),
link classes calibrated to 2002-era WAN/LAN/SMP.  Output: CSV
``size_bytes,variant,sum_over_roots_seconds`` — same metric as Fig. 8
(time to broadcast with each rank as root once).
"""
from __future__ import annotations

import sys

from repro.core import Communicator
from repro.core.topology import (paper_fig8_topology, magpie_machine_view,
                                 magpie_site_view)

SIZES = [1 << k for k in range(10, 21)]  # 1 KB .. 1 MB
ROOT_STRIDE = 4  # every 4th rank as root (48 roots -> 12; same shape, 4x faster)


def variants(topo) -> dict[str, Communicator]:
    return {
        "mpich-binomial": Communicator(topo, policy="oblivious"),
        "magpie-machine": Communicator(topo, policy="paper",
                                       view=magpie_machine_view(topo)),
        "magpie-site": Communicator(topo, policy="paper",
                                    view=magpie_site_view(topo)),
        "multilevel": Communicator(topo, policy="paper"),
        "adaptive": Communicator(topo, policy="adaptive"),
        # beyond-paper: segmented plans + large-message algorithms, argmin
        # over {tree} x {algorithm} x {segment size}
        "auto-segmented": Communicator(topo, policy="auto"),
    }


def run(out=sys.stdout) -> dict:
    topo = paper_fig8_topology()
    comms = variants(topo)
    results: dict[str, list[tuple[int, float]]] = {}
    print("size_bytes,variant,sum_over_roots_s", file=out)
    for nb in SIZES:
        for name, comm in comms.items():
            total = 0.0
            for root in range(0, topo.nprocs, ROOT_STRIDE):
                total += comm.bcast(float(nb), root=root).time
            results.setdefault(name, []).append((nb, total))
            print(f"{nb},{name},{total:.4f}", file=out)
    for name, comm in comms.items():
        # stderr: keeps the stdout stream pure CSV for naive consumers
        print(f"{name} plan cache: {comm.cache_info()}", file=sys.stderr)
    return results


def check(results: dict) -> list[str]:
    """Assertions mirroring the paper's qualitative claims."""
    msgs = []
    by = {k: dict(v) for k, v in results.items()}
    for nb in SIZES[4:]:  # >= 16 KB: the regime the paper highlights
        ml, site = by["multilevel"][nb], by["magpie-site"][nb]
        mach, binm = by["magpie-machine"][nb], by["mpich-binomial"][nb]
        ok = ml <= site <= mach <= binm * 1.001
        msgs.append(f"N={nb:>8}: ml={ml:.3f} site={site:.3f} "
                    f"mach={mach:.3f} bin={binm:.3f} {'OK' if ok else 'VIOLATION'}")
    for nb in SIZES:
        assert by["adaptive"][nb] <= by["multilevel"][nb] * 1.01, nb
    return msgs


if __name__ == "__main__":
    res = run()
    for line in check(res):
        print("#", line)
