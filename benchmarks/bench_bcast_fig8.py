"""Reproduction of the paper's Fig. 7/8 experiment on the postal-model
simulator: the broadcast timing application sweeping message sizes with
every rank taking a turn as root, comparing

  mpich-binomial   (topology-unaware, the MPICH default of the era)
  magpie-machine   (2-level, machine-boundary clustering)
  magpie-site      (2-level, site-boundary clustering)
  multilevel       (the paper, flat-at-WAN / binomial below)
  adaptive         (beyond-paper: per-level Bar-Noy/Kipnis shape selection)

Topology: 16 procs on each of SDSC-SP, ANL-SP, ANL-O2K (sites SDSC/ANL),
link classes calibrated to 2002-era WAN/LAN/SMP.  Output: CSV
``size_bytes,variant,sum_over_roots_seconds`` — same metric as Fig. 8
(time to broadcast with each rank as root once).
"""
from __future__ import annotations

import sys

from repro.core import schedule as S
from repro.core.simulator import simulate
from repro.core.topology import (paper_fig8_topology, magpie_machine_view,
                                 magpie_site_view)
from repro.core.trees import (binomial_tree, build_multilevel_tree,
                              PAPER_POLICY, adaptive_policy)

SIZES = [1 << k for k in range(10, 21)]  # 1 KB .. 1 MB
ROOT_STRIDE = 4  # every 4th rank as root (48 roots -> 12; same shape, 4x faster)


def variants(topo):
    return {
        "mpich-binomial": lambda root, nb: binomial_tree(
            root, range(topo.nprocs)),
        "magpie-machine": lambda root, nb: build_multilevel_tree(
            magpie_machine_view(topo), root),
        "magpie-site": lambda root, nb: build_multilevel_tree(
            magpie_site_view(topo), root),
        "multilevel": lambda root, nb: build_multilevel_tree(
            topo, root, policy=PAPER_POLICY),
        "adaptive": lambda root, nb: build_multilevel_tree(
            topo, root, policy=adaptive_policy(topo, nb)),
    }


def run(out=sys.stdout) -> dict:
    topo = paper_fig8_topology()
    results: dict[str, list[tuple[int, float]]] = {}
    print("size_bytes,variant,sum_over_roots_s", file=out)
    for nb in SIZES:
        for name, mk in variants(topo).items():
            total = 0.0
            for root in range(0, topo.nprocs, ROOT_STRIDE):
                tree = mk(root, nb)
                total += max(simulate(S.bcast(tree, nb), topo).values())
            results.setdefault(name, []).append((nb, total))
            print(f"{nb},{name},{total:.4f}", file=out)
    return results


def check(results: dict) -> list[str]:
    """Assertions mirroring the paper's qualitative claims."""
    msgs = []
    by = {k: dict(v) for k, v in results.items()}
    for nb in SIZES[4:]:  # >= 16 KB: the regime the paper highlights
        ml, site = by["multilevel"][nb], by["magpie-site"][nb]
        mach, binm = by["magpie-machine"][nb], by["mpich-binomial"][nb]
        ok = ml <= site <= mach <= binm * 1.001
        msgs.append(f"N={nb:>8}: ml={ml:.3f} site={site:.3f} "
                    f"mach={mach:.3f} bin={binm:.3f} {'OK' if ok else 'VIOLATION'}")
    for nb in SIZES:
        assert by["adaptive"][nb] <= by["multilevel"][nb] * 1.01, nb
    return msgs


if __name__ == "__main__":
    res = run()
    for line in check(res):
        print("#", line)
