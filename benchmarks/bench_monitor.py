"""Health-monitoring benchmark: contention-aware cost attribution and
mid-run auto-refit under production traffic.  Persists ``BENCH_monitor.json``.

Two sections:

``contended_feedback``
    The planner's model overstates WAN bandwidth 8x while a busy
    multi-program engine window runs (overlapping member sets, so transfers
    genuinely share directed links — mean WAN overlap > 1).  The traced
    intervals are contention-stretched; feeding them through
    :func:`repro.obs.contention.deconvolve` recovers isolated-equivalent
    durations, and :class:`~repro.obs.FeedbackLoop` refits the WAN class to
    the SAME bandwidth a lone-collective trace yields (agreement asserted).
    The control that skips deconvolution fits a biased bandwidth from the
    identical trace.  Plan regret on the true network drops from >=10% to
    <=2% — the acceptance criterion.

``drift_serving``
    Open-loop serving on the paper's grid: every decode step runs one
    tensor-parallel allreduce per request over a SITE-SPANNING replica
    (the computational-grid setting).  Mid-run the WAN degrades 8x
    (``engine.truth`` swap); the stale model keeps picking a WAN-heavy
    plan whose step time exceeds the compute budget, so p99 TTFT climbs.
    The attached :class:`~repro.obs.HealthMonitor` sees the drift in the
    deconvolved residuals within a few checks, refits mid-run, and the
    informed replan drops the collective back UNDER the compute time —
    steady-state p99 TTFT returns to within 10% of pre-drift while the
    unmonitored baseline stays degraded.

``--smoke`` checks the committed artifact's schema and asserts the
headline instead of overwriting it; ``--snapshot-out PATH`` writes the
monitored run's final health snapshot (the CI artifact).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

import numpy as np

from repro.core import Communicator
from repro.core.engine import Engine
from repro.core.simulator import simulate_rounds
from repro.core.topology import paper_fig8_topology
from repro.obs import FeedbackLoop, HealthMonitor, Tracer, occupancy
from repro.serving import (Scheduler, SimExecutor, make_requests,
                           poisson_arrivals)

MIB = float(1 << 20)
WAN_OVERSTATE = 8.0           # contended_feedback: model bw / truth bw
REGRET_NBYTES = 16 * MIB

WAN_DEGRADE = 8.0             # drift_serving: healthy bw / degraded bw
GATHER_NBYTES = 65536.0       # per-request tensor-parallel allreduce
COMPUTE_S = 0.33              # per-step compute; masks the INFORMED plan
DRIFT_STEP = 60               # engine.truth swap, in scheduler steps
RATE, HORIZON, TAIL_FROM = 1.5, 120.0, 70.0


def _wan_scaled(factor: float):
    t = paper_fig8_topology()
    t.levels = tuple(
        dataclasses.replace(l, bandwidth=l.bandwidth * factor)
        if l.name == "wan" else l for l in t.levels)
    return t


def _wan_index(topo) -> int:
    return next(i for i, l in enumerate(topo.levels) if l.name == "wan")


def _plan_regret(comm: Communicator, truth, op: str, nbytes: float) -> float:
    low = comm.plan(op, nbytes=nbytes).lower(nbytes)
    t_sel = max(simulate_rounds(low, truth).values())
    oracle = Communicator(truth, policy=comm.policy, backend="sim")
    best = oracle.plan(op, nbytes=nbytes).lower(nbytes)
    return t_sel / max(simulate_rounds(best, truth).values()) - 1.0


def _busy_engine_trace(model, truth) -> Tracer:
    """A production-like window: collectives over overlapping member sets
    share directed links inside each flush, so the traced intervals are
    contention-stretched (what naive feedback would misread as drift
    everywhere)."""
    comm = Communicator(model, backend="sim", policy="auto")
    tr = Tracer()
    eng = Engine(comm, policy="fifo", truth=truth, tracer=tr)
    sets = [tuple(range(48)), tuple(range(0, 32)), tuple(range(16, 48)),
            tuple(range(0, 16)) + tuple(range(32, 48))]
    for _ in range(3):
        for i, mem in enumerate(sets):
            eng.issue("allreduce", (1 + i) * MIB, members=mem)
            eng.issue("bcast", 2 * MIB, members=mem, root=mem[0])
        eng.wait_all()
    return tr


def contended_feedback_section() -> dict:
    truth = paper_fig8_topology()
    model = _wan_scaled(WAN_OVERSTATE)
    wan = _wan_index(truth)
    tr = _busy_engine_trace(model, truth)
    occ = occupancy(tr)
    overlap = {truth.levels[k].name: v["mean_overlap"]
               for k, v in occ.items()}

    def fit_from(deconvolve: bool):
        comm = Communicator(_wan_scaled(WAN_OVERSTATE), backend="sim",
                            policy="auto")
        fb = FeedbackLoop(comm, threshold=0.15)
        pre = _plan_regret(comm, truth, "allreduce", REGRET_NBYTES)
        n = fb.observe_trace(tr, deconvolve=deconvolve)
        report = fb.maybe_refit()
        post = _plan_regret(comm, truth, "allreduce", REGRET_NBYTES)
        return comm.topo.levels[wan].bandwidth, pre, post, n, report

    bw_deconv, pre_regret, post_regret, n_samples, rep = fit_from(True)
    bw_biased, _, _, _, _ = fit_from(False)

    # the lone-collective reference (PR 8's feeding path, no contention)
    comm_lone = Communicator(_wan_scaled(WAN_OVERSTATE), backend="sim",
                             policy="auto")
    fb_lone = FeedbackLoop(comm_lone, threshold=0.15)
    fb_lone.run("allreduce", REGRET_NBYTES, truth=truth)
    fb_lone.maybe_refit()
    bw_lone = comm_lone.topo.levels[wan].bandwidth

    bw_truth = truth.levels[wan].bandwidth
    return {
        "wan_overstated_by": WAN_OVERSTATE,
        "n_samples": n_samples,
        "refit": rep.refit,
        "mean_overlap": overlap,
        "wan_bandwidth_truth": bw_truth,
        "wan_bandwidth_deconvolved_fit": bw_deconv,
        "wan_bandwidth_lone_fit": bw_lone,
        "wan_bandwidth_biased_fit": bw_biased,
        "deconvolved_vs_lone_rel_err": abs(bw_deconv / bw_lone - 1.0),
        "biased_vs_truth_rel_err": abs(bw_biased / bw_truth - 1.0),
        "pre_refit_regret": pre_regret,
        "post_refit_regret": post_regret,
    }


class _StepClock:
    """Constant per-step compute cost that doubles as the drift injector:
    the scheduler calls it exactly once per step, so swapping
    ``engine.truth`` at call ``drift_step`` degrades the network mid-run
    for monitored and unmonitored runs identically."""

    def __init__(self, engine, drift_step: int, drift_truth):
        self.engine = engine
        self.drift_step = drift_step
        self.drift_truth = drift_truth
        self.n = 0

    def __call__(self, prefill_tokens: int, n_deciding: int) -> float:
        self.n += 1
        if self.n == self.drift_step:
            self.engine.truth = self.drift_truth
        return COMPUTE_S


def _serve_run(monitored: bool, degraded) -> tuple[list, object, object]:
    healthy = paper_fig8_topology()
    comm = Communicator(paper_fig8_topology(), backend="sim", policy="auto")
    eng = Engine(comm, policy="fifo", truth=healthy)
    mon = HealthMonitor(engine=eng, threshold=0.4, min_samples=6,
                        check_every=2, window=256) if monitored else None
    # grid data-parallel: each slot's tensor-parallel replica spans two
    # sites (2 ranks each) — the paper's wide-area collective setting
    replicas = [(2 * g, 2 * g + 1, 16 + 2 * g, 16 + 2 * g + 1)
                for g in range(8)]
    arrivals = poisson_arrivals(RATE, HORIZON, seed=3)
    reqs = make_requests(arrivals, vocab=64, prompt_len=4, gen_len=6, seed=0)
    sch = Scheduler(SimExecutor(vocab=64, block_size=4),
                    n_blocks=1 + 64, block_size=4, max_slots=8, s_max=16,
                    compute_model=_StepClock(eng, DRIFT_STEP, degraded),
                    engine=eng, replicas=replicas,
                    gather_bytes=GATHER_NBYTES, gather_op="allreduce",
                    monitor=mon)
    sch.run(reqs)
    return reqs, mon, eng


def _p99(xs) -> float:
    return float(np.percentile(np.asarray(xs, float), 99)) \
        if xs else float("nan")


def drift_serving_section() -> dict:
    degraded = _wan_scaled(1.0 / WAN_DEGRADE)
    t_drift = DRIFT_STEP * COMPUTE_S
    out: dict = {
        "wan_degraded_by": WAN_DEGRADE,
        "drift_step": DRIFT_STEP,
        "compute_s": COMPUTE_S,
        "gather_nbytes": GATHER_NBYTES,
        "rate_req_s": RATE,
    }
    snapshot = None
    for label, monitored in (("baseline", False), ("monitored", True)):
        reqs, mon, eng = _serve_run(monitored, degraded)
        done = [r for r in reqs if r.ttft is not None]
        pre = [r.ttft for r in done if r.finish_s < t_drift]
        tail = [r.ttft for r in done if r.arrival_s > TAIL_FROM]
        row = {
            "n_done": len(done),
            "pre_drift_p99_ttft_s": _p99(pre),
            "tail_p99_ttft_s": _p99(tail),
            "tail_over_pre": _p99(tail) / _p99(pre) - 1.0,
        }
        if mon is not None:
            detected = next((e.step for e in mon.events
                             if e.kind == "drift"), None)
            row["detected_step"] = detected
            row["detection_latency_steps"] = (
                None if detected is None else detected - DRIFT_STEP)
            row["refits"] = mon.refits
            wan = _wan_index(degraded)
            row["wan_bandwidth_refit"] = eng.comm.topo.levels[wan].bandwidth
            row["wan_bandwidth_truth"] = degraded.levels[wan].bandwidth
            snapshot = mon.snapshot()
        out[label] = row
    out["snapshot"] = snapshot
    return out


def build_doc(smoke: bool = False) -> dict:
    del smoke  # both legs run the full (deterministic, ~1 min) scenario
    contended = contended_feedback_section()
    drift = drift_serving_section()

    contended_ok = (
        contended["refit"]
        and contended["mean_overlap"]["wan"] > 1.05
        and contended["pre_refit_regret"] >= 0.10
        and contended["post_refit_regret"] <= 0.02
        and contended["deconvolved_vs_lone_rel_err"] <= 0.02)
    mon_row, base_row = drift["monitored"], drift["baseline"]
    drift_ok = (
        mon_row["detection_latency_steps"] is not None
        and mon_row["detection_latency_steps"] <= 16
        and mon_row["refits"] >= 1
        and mon_row["tail_over_pre"] <= 0.10
        and base_row["tail_over_pre"] >= 0.25)
    headline = {
        "pre_refit_regret": contended["pre_refit_regret"],
        "post_refit_regret": contended["post_refit_regret"],
        "deconvolved_vs_lone_rel_err":
            contended["deconvolved_vs_lone_rel_err"],
        "biased_vs_truth_rel_err": contended["biased_vs_truth_rel_err"],
        "contended_passed": contended_ok,
        "detection_latency_steps": mon_row["detection_latency_steps"],
        "monitored_tail_over_pre": mon_row["tail_over_pre"],
        "baseline_tail_over_pre": base_row["tail_over_pre"],
        "drift_passed": drift_ok,
        "passed": contended_ok and drift_ok,
    }
    summary = [
        "contended feedback (wan overstated "
        f"{WAN_OVERSTATE:g}x, mean wan overlap "
        f"{contended['mean_overlap']['wan']:.2f}): deconvolved fit matches "
        f"lone fit within {contended['deconvolved_vs_lone_rel_err']:.1%} "
        f"(biased control off by "
        f"{contended['biased_vs_truth_rel_err']:.1%}); plan regret "
        f"{contended['pre_refit_regret']:.1%} -> "
        f"{contended['post_refit_regret']:.1%} "
        f"({'PASS' if contended_ok else 'FAIL'})",
        "drift serving (wan degrades "
        f"{WAN_DEGRADE:g}x at step {DRIFT_STEP}): detected "
        f"{mon_row['detection_latency_steps']} step(s) later, "
        f"{mon_row['refits']} refit(s); steady-state p99 TTFT "
        f"{mon_row['tail_over_pre']:+.1%} vs pre-drift (baseline "
        f"{base_row['tail_over_pre']:+.1%}) "
        f"({'PASS' if drift_ok else 'FAIL'})",
    ]
    return {
        "generated_by": "benchmarks/bench_monitor.py",
        "contended_feedback": contended,
        "drift_serving": drift,
        "headline": headline,
        "summary": summary,
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    snapshot_out = None
    if "--snapshot-out" in argv:
        snapshot_out = argv[argv.index("--snapshot-out") + 1]
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_monitor.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if snapshot_out:
        with open(snapshot_out, "w") as f:
            json.dump(doc["drift_serving"]["snapshot"], f, indent=1)
            f.write("\n")
        print(f"# health snapshot -> {snapshot_out}")
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_monitor.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        if not doc["headline"]["passed"]:
            print("monitoring acceptance failed:", doc["headline"],
                  file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_monitor.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_monitor.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
