"""All five paper collectives (+ allreduce/allgather extensions) across
topologies and regimes — one row per (op, topology, size, variant), driven
entirely through the public :class:`repro.core.Communicator` API.

Also reports the observed trade-off table: where multilevel wins (latency /
message-count bound) and where bandwidth concentration loses (large gather/
scatter onto one slow link) — the honest version of the paper's Table.

Run as a script, it PERSISTS ``BENCH_collectives.json`` at the repo root —
the Fig. 8 replication plus a 1 KiB–256 MiB large-message sweep (unsegmented
multilevel vs the segmented/algorithm-switching "auto" plans) — so the perf
trajectory is tracked from PR 2 on.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro.core import OPS, Communicator
from repro.core.topology import (Topology, WAN, LAN, SMP,
                                 paper_fig8_topology, tpu_v5e_multipod)

# variant name -> Communicator tree-selection policy
VARIANTS = {
    "binomial-oblivious": "oblivious",
    "multilevel": "paper",
    "adaptive": "adaptive",
    "segmented-auto": "auto",  # {tree} x {algorithm} x {segment} argmin
}


def many_clusters():
    site = [i // 16 for i in range(64)]
    mach = [i // 4 for i in range(64)]
    return Topology(np.stack([site, mach], 1), [WAN, LAN, SMP])


TOPOLOGIES = {
    "fig8": paper_fig8_topology(),
    "many-clusters": many_clusters(),
    "tpu-2pod": tpu_v5e_multipod(pods=2, boards=8, chips_per_board=4),
}


def run_op(comm: Communicator, op: str, nbytes: float):
    """One collective through the public API (uniform over the seven ops)."""
    if op == "barrier":
        return comm.barrier()
    if OPS[op].rootful:
        return getattr(comm, op)(nbytes, root=0)
    return getattr(comm, op)(nbytes)


def run(out=sys.stdout) -> list[dict]:
    rows = []
    print("topology,op,size_bytes,variant,seconds", file=out)
    for tname, topo in TOPOLOGIES.items():
        comms = {v: Communicator(topo, policy=p, backend="sim")
                 for v, p in VARIANTS.items()}
        for oname, spec in OPS.items():
            for nb in (1e3, 64e3):
                for vname, comm in comms.items():
                    t = run_op(comm, oname, nb).time
                    rows.append({"topology": tname, "op": oname,
                                 "size": nb, "variant": vname, "s": t})
                    print(f"{tname},{oname},{nb:.0f},{vname},{t:.6f}",
                          file=out)
                if not spec.sized:
                    break  # barrier has no size sweep
        for vname, comm in comms.items():
            # stderr: keeps the stdout stream pure CSV for naive consumers
            print(f"{tname}/{vname} plan cache: {comm.cache_info()}",
                  file=sys.stderr)
    return rows


def summarize(rows) -> list[str]:
    """Win/loss table for multilevel vs oblivious."""
    out = []
    for t in TOPOLOGIES:
        wins = losses = 0
        for op in OPS:
            for nb in (1e3, 64e3):
                sel = {r["variant"]: r["s"] for r in rows
                       if r["topology"] == t and r["op"] == op
                       and r["size"] in (nb, 1e3)}
                if not sel or "multilevel" not in sel:
                    continue
                if sel["multilevel"] <= sel["binomial-oblivious"]:
                    wins += 1
                else:
                    losses += 1
        out.append(f"{t}: multilevel wins {wins}, loses {losses} "
                   f"(losses are bandwidth-concentration cases)")
    return out


def large_message_sweep(sizes=None) -> list[dict]:
    """1 KiB – 256 MiB bcast/allreduce on the paper's Fig. 8 topology:
    unsegmented multilevel baseline vs the auto-selected segmented plan
    (algorithm + segment size chosen by the simulator argmin)."""
    topo = paper_fig8_topology()
    paper = Communicator(topo, policy="paper")
    auto = Communicator(topo, policy="auto")
    sizes = sizes or [float(1 << k) for k in range(10, 29)]  # 1KiB..256MiB
    rows = []
    for op in ("bcast", "allreduce"):
        for nb in sizes:
            base = (paper.bcast(nb, root=0) if op == "bcast"
                    else paper.allreduce(nb)).time
            fast = (auto.bcast(nb, root=0) if op == "bcast"
                    else auto.allreduce(nb)).time
            plan = auto.plan(op, root=0 if op == "bcast" else None,
                             nbytes=nb)
            rows.append({
                "op": op, "size_bytes": nb,
                "multilevel_unsegmented_s": base, "auto_s": fast,
                "speedup": base / fast if fast else None,
                "algorithm": plan.algorithm,
                "segment": plan.segment,
            })
    return rows


def build_doc(rows: list[dict] | None = None,
              sweep_sizes=None) -> dict:
    """The persisted document; ``sweep_sizes`` restricts the large-message
    sweep (smoke runs)."""
    from bench_bcast_fig8 import run as fig8_run

    if rows is None:
        rows = run(out=open(os.devnull, "w"))
    sweep = large_message_sweep(sweep_sizes)
    fig8 = {name: [[int(nb), t] for nb, t in series]
            for name, series in fig8_run(out=open(os.devnull, "w")).items()}
    return {
        "generated_by": "benchmarks/bench_collectives.py",
        "fig8_bcast_sum_over_roots": fig8,
        "collectives": rows,
        "large_message_sweep": sweep,
        "summary": summarize(rows),
    }


def _default_path() -> str:
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_collectives.json")


def persist(path: str | None = None, rows: list[dict] | None = None) -> dict:
    """Run everything and write ``BENCH_collectives.json``; pass ``rows``
    from an earlier :func:`run` to avoid re-simulating the table."""
    doc = build_doc(rows=rows)
    with open(path or _default_path(), "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    if "--smoke" in sys.argv[1:]:
        # Reduced run + schema guard: regenerate a small document and check
        # its shape against the committed artifact instead of overwriting it
        # (see bench_schema.py) — CI's drift tripwire.
        from bench_schema import check_against_committed

        doc = build_doc(sweep_sizes=[1024.0, 65536.0, float(1 << 20)])
        drifts = check_against_committed(doc, _default_path())
        if drifts:
            print("BENCH_collectives.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            raise SystemExit(1)
        print("# smoke: schema matches committed BENCH_collectives.json")
        raise SystemExit(0)
    rows = run()
    for line in summarize(rows):
        print("#", line)
    doc = persist(rows=rows)
    big = [r for r in doc["large_message_sweep"]
           if r["size_bytes"] == float(64 << 20)]
    for r in big:
        print(f"# 64MiB {r['op']}: {r['multilevel_unsegmented_s']:.2f}s -> "
              f"{r['auto_s']:.2f}s ({r['speedup']:.1f}x, {r['algorithm']})")
    print("# wrote BENCH_collectives.json")
